"""Batched serving example: continuous batching over a small model, with
RelShard occupancy re-planning.

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax

from repro.configs import get_smoke_config
from repro.core.relshard import plan_model
from repro.launch.mesh import make_host_mesh, mesh_axes
from repro.models import lm
from repro.models.config import ShapeConfig
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = get_smoke_config("tinyllama_1_1b")
    mesh = make_host_mesh(1, 1)
    axes = mesh_axes(mesh)
    shape = ShapeConfig("serve", 96, 4, "decode")
    plan = plan_model(cfg, axes, shape, fsdp=False)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, plan, None, params, max_batch=4, max_seq=96,
                      mesh_axes=axes, shape=shape)

    for rid in range(7):
        eng.submit(Request(rid, prompt=[1 + rid, 5, 9], max_new_tokens=16))
    steps = 0
    while eng.queue or eng.occupancy():
        emitted = eng.step()
        steps += 1
        if steps % 10 == 0:
            eng.maybe_replan()
    print(f"served 7 requests in {steps} batched decode steps "
          f"(continuous batching, max_batch=4)")


if __name__ == "__main__":
    main()
