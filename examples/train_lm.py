"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps on CPU with checkpoint/restart, printing the loss curve.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.core.relshard import plan_model
from repro.launch.mesh import make_host_mesh, mesh_axes
from repro.models.config import ShapeConfig
from repro.training.optimizer import OptConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/reljoin_train_lm")
    args = ap.parse_args()

    # ~100M params: tinyllama scaled to 12 layers x 896 wide.
    cfg = dataclasses.replace(
        get_config("tinyllama_1_1b"), n_layers=12, d_model=896, n_heads=14,
        n_kv_heads=7, d_ff=2688, vocab=8192, name="tinyllama-100m")
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ~{n_params/1e6:.0f}M params")

    mesh = make_host_mesh(1, 1)
    shape = ShapeConfig("train", 256, 8, "train")
    plan = plan_model(cfg, mesh_axes(mesh), shape, fsdp=False)
    out = train(cfg, plan, None, steps=args.steps, global_batch=8,
                seq_len=256, opt_cfg=OptConfig(lr=1e-3, warmup_steps=30),
                ckpt_dir=args.ckpt, ckpt_every=100, log_every=20)
    first, last = out["history"][0][1], out["history"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.5 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
