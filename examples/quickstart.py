"""Quickstart: RelJoin in 60 seconds.

1. Build a tiny star schema, 2. run one query under every selection
strategy, 3. see why RelJoin picks what it picks (the k vs k0 criterion),
4. run a query straight from SQL text.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import CostParams, k0_threshold
from repro.sql import (Executor, all_queries, default_strategies, generate,
                       parse_sql)
from repro.sql.logical import signature


def main():
    catalog = generate(scale=0.1, p=8, seed=0)
    plan = all_queries()["q2_chain7"]  # the paper's q72-shaped chain
    params = CostParams(p=8, w=1.0)
    print(f"k0 threshold (p=8, w=1): {k0_threshold(params):.1f}\n")

    for strat in default_strategies():
        res = Executor(catalog, strat).execute(plan)
        methods = ",".join(m.value.replace("_", "")[:9]
                           for m in res.methods())
        print(f"{strat.name:16s} rows={res.rows:5d} "
              f"workload={res.workload() / 2 ** 20:8.1f}MB "
              f"net={res.network_bytes / 2 ** 20:6.2f}MB "
              f"wall={res.wall_time_s:5.2f}s  [{methods}]")

    print("\nRelJoin decisions (adaptive runtime statistics):")
    res = Executor(catalog, default_strategies()[-1]).execute(plan)
    for i, d in enumerate(res.decisions):
        k = (max(d.left_stats.size_bytes, d.right_stats.size_bytes)
             / max(min(d.left_stats.size_bytes, d.right_stats.size_bytes), 1))
        print(f"  join {i}: {d.selection.method.value:15s} k={k:8.1f} "
              f"({d.selection.reason})")

    print("\nSame engine, straight from SQL text:")
    plan = parse_sql("""
        SELECT s_state, SUM(ss_net_profit)
        FROM store_sales
        JOIN store ON ss_store_sk = s_store_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 11)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY s_state
    """)
    print(f"  plan: {signature(plan)}")
    res = Executor(catalog, default_strategies()[-1]).execute(plan)
    print(f"  rows={res.rows} methods={[m.value for m in res.methods()]}")


if __name__ == "__main__":
    main()
