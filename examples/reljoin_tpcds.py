"""Full engine scenario: the TPC-DS-shaped suite under all four selection
strategies, reporting the paper's headline numbers (workload reduction,
per-query winners, PSTS).

    PYTHONPATH=src python examples/reljoin_tpcds.py [--scale 0.3]
"""

import argparse

from repro.core import compute_psts
from repro.sql import Executor, all_queries, default_strategies, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--p", type=int, default=8)
    args = ap.parse_args()

    catalog = generate(scale=args.scale, p=args.p, seed=0)
    queries = all_queries()
    suites = {}
    for strat in default_strategies():
        rows = {}
        for q, plan in queries.items():
            rows[q] = Executor(catalog, strat).execute(plan)
        suites[strat.name] = rows
        tot = sum(r.workload() for r in rows.values())
        wall = sum(r.wall_time_s for r in rows.values())
        print(f"{strat.name:16s} total workload {tot/2**20:9.1f}MB  "
              f"wall {wall:6.1f}s")

    rel, aqe = suites["RelJoin(w=1)"], suites["AQE"]
    wins = sum(rel[q].workload() <= min(s[q].workload()
               for s in suites.values()) for q in queries)
    print(f"\nRelJoin best-or-tied on {wins}/{len(queries)} queries")
    rep = compute_psts(
        [m for q in queries for m in rel[q].methods()],
        [m for q in queries for m in aqe[q].methods()],
        sum(rel[q].workload() for q in queries),
        sum(aqe[q].workload() for q in queries))
    print(f"PSTS (workload, AQE baseline): {rep.psts:.2f} "
          f"(join diff {rep.pct_join_diff:.1f}%, "
          f"workload diff {rep.pct_time_diff:.1f}%)")


if __name__ == "__main__":
    main()
