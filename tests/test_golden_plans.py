"""Golden-plan snapshot tests: the physical-plan decisions of q1-q37 are
pinned in a checked-in JSON fixture so cost-model / planner edits can't
silently regress them.

Per query, the fixture records:

  * for every default strategy (ShuffleSort, ShuffleHash, AQE, RelJoin):
    the executed per-join (method, swapped_sides) sequence on the standard
    test catalog (scale 0.1, p=4, seed 42 — the session fixture),
  * for Reorder(RelJoin) on the mis-ordered planner targets (q13-q15):
    the executed methods — pinning the adaptive DP's chosen order,
  * for Reorder(RelJoin) on the cyclic queries (q35-q37): the executed
    methods — pinning whether the hypercube multi-way plan is selected on
    this catalog's geometry (and, in the same entry, that the default
    non-reordering strategies still run the binary + residual-eqcol
    fallback path),
  * the static planner audit: whether ``optimize`` reordered each query and
    the canonical signature of the emitted plan (the DP join order).

Snapshots are compared field-by-field (byte-identical selections). This PR
records them with runtime filters OFF — FilteredStrategy changes nothing
unless wrapped in, so these snapshots also prove the filter machinery left
q1-q18 untouched.

Regenerate deliberately with:

    GOLDEN_UPDATE=1 PYTHONPATH=src python -m pytest tests/test_golden_plans.py
"""

import json
import os
import pathlib

import pytest

from repro.sql import (Executor, RelJoinStrategy, ReorderingStrategy,
                       all_queries, cyclic_queries, default_strategies,
                       filtered_queries, misordered_queries, optimize,
                       skewed_queries, text_queries)
from repro.sql.logical import signature

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "golden_plans.json"

#: q1-q37: baseline + planner-target + skew-target + filter-target suites
#: plus the text-only SQL queries (q24+, incl. the service suite's
#: deliberately-overlapping q33/q34) and the cyclic hypercube targets
#: (q35-q37, hand-built: their closing edges have no SQL form).
#: (Skewed queries run on the uniform catalog here: their *selection*
#: snapshot is the uniform-key one; bench_skew owns the skewed behaviour.)


def golden_queries():
    out = dict(all_queries())
    out.update(misordered_queries())
    out.update(skewed_queries())
    out.update(filtered_queries())
    out.update(text_queries())
    out.update(cyclic_queries())
    return out


def _decisions(res):
    return [{"method": d.selection.method.value,
             "swapped": bool(d.selection.swapped_sides)}
            for d in res.decisions]


def build_snapshot(catalog) -> dict:
    queries = golden_queries()
    snap = {"catalog": {"scale": 0.1, "p": 4, "seed": 42}, "queries": {}}
    strategies = default_strategies()
    for qname in sorted(queries):
        plan = queries[qname]
        entry = {"strategies": {}}
        for strat in strategies:
            res = Executor(catalog, strat).execute(plan)
            entry["strategies"][strat.name] = _decisions(res)
        if qname in misordered_queries() or qname in cyclic_queries():
            res = Executor(catalog,
                           ReorderingStrategy(RelJoinStrategy())
                           ).execute(plan)
            entry["strategies"]["Reorder(RelJoin(w=1))"] = _decisions(res)
        opt = optimize(plan, catalog)
        entry["dp"] = {"reordered": bool(opt.reordered),
                       "signature": signature(opt.plan)}
        snap["queries"][qname] = entry
    return snap


@pytest.fixture(scope="module")
def snapshot(catalog):
    return build_snapshot(catalog)


def test_fixture_exists_or_update():
    if os.environ.get("GOLDEN_UPDATE"):
        pytest.skip("regeneration run")
    assert FIXTURE.exists(), (
        "golden fixture missing — regenerate with GOLDEN_UPDATE=1")


def test_golden_plans(snapshot):
    if os.environ.get("GOLDEN_UPDATE"):
        FIXTURE.parent.mkdir(parents=True, exist_ok=True)
        FIXTURE.write_text(json.dumps(snapshot, indent=1, sort_keys=True)
                           + "\n")
        pytest.skip(f"regenerated {FIXTURE}")
    want = json.loads(FIXTURE.read_text())
    assert snapshot["catalog"] == want["catalog"]
    assert sorted(snapshot["queries"]) == sorted(want["queries"])
    for qname, got in snapshot["queries"].items():
        exp = want["queries"][qname]
        for sname, decs in exp["strategies"].items():
            assert got["strategies"][sname] == decs, (qname, sname)
        assert got["dp"] == exp["dp"], qname


def test_snapshot_covers_q1_to_q37(snapshot):
    assert len(snapshot["queries"]) == 37
