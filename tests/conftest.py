"""Shared fixtures: catalogs and strategy sets are session-scoped because
building them (and warming the XLA compile cache on their shapes) dominates
test wall time; every consumer treats them as read-only."""

import pytest

from repro.sql import default_strategies, generate


@pytest.fixture(scope="session")
def catalog():
    """The standard small test catalog (read-only)."""
    return generate(scale=0.1, p=4, seed=42)


@pytest.fixture(scope="session")
def skewed_catalogs():
    """(uniform, zipf-skewed) pair with matching seed (read-only)."""
    return (generate(scale=0.1, p=4, seed=7, skew=0.0),
            generate(scale=0.1, p=4, seed=7, skew=1.2))


@pytest.fixture(scope="session")
def strategies():
    return default_strategies()


@pytest.fixture(scope="session")
def zipf_catalogs():
    """{zipf_exponent: catalog} at p=8 for the skew-aware suite (read-only).
    p=8 (vs the standard fixture's p=4) gives the hot key enough partitions
    to tilt: the straggler factor at Zipf 1.2 is ~2x there."""
    return {z: generate(scale=0.1, p=8, seed=11, skew=z)
            for z in (0.0, 1.2, 1.4)}
