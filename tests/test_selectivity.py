"""Selectivity derivation and calendar correctness.

Two satellites share these pins: ``derive_selectivity`` must compute exact
op-aware kept fractions from the column domains (declared values always
winning), and the synthetic 360-day calendar must make every declared /
derived date-predicate selectivity *measurable* — the fraction of rows a
predicate actually keeps in a generated catalog matches the estimate (the
old 365-day layout wrapped days 360-364 into month 0, so ``d_month = 0``
kept 35/365 while the estimate said 1/12).
"""

import numpy as np
import pytest

from repro.sql import derive_selectivity, generate
from repro.sql.datagen import TABLE_COLUMNS
from repro.sql.logical import Filter, Scan, effective_selectivity, walk
from repro.sql.queries import (all_queries, filtered_queries,
                               misordered_queries, skewed_queries,
                               text_queries)
from repro.sql.selectivity import DEFAULT_SELECTIVITY


def _f(column, op, value=0.0, value2=0.0, values=(), selectivity=None):
    return Filter(Scan("x"), column, op, value, value2, values, selectivity)


# ---------------------------------------------------------------------------
# derive_selectivity units
# ---------------------------------------------------------------------------


def test_declared_selectivity_wins():
    assert derive_selectivity(_f("d_month", "eq", 6, selectivity=0.42)) \
        == 0.42


@pytest.mark.parametrize("op, v, v2, vals, want", [
    ("eq", 6, 0, (), 1 / 12),
    ("eq", 6.5, 0, (), 0.0),          # non-integral literal hits nothing
    ("eq", 12, 0, (), 0.0),           # out of the [0, 12) domain
    ("ne", 6, 0, (), 11 / 12),
    ("lt", 3, 0, (), 3 / 12),
    ("le", 3, 0, (), 4 / 12),
    ("gt", 3, 0, (), 8 / 12),
    ("ge", 3, 0, (), 9 / 12),
    ("between", 3, 5, (), 3 / 12),
    ("in", 0, 0, (1.0, 3.0, 5.0), 3 / 12),
    ("in", 0, 0, (1.0, 99.0), 1 / 12),  # out-of-domain members drop out
])
def test_integer_domain_fractions(op, v, v2, vals, want):
    got = derive_selectivity(_f("d_month", op, v, v2, vals))
    assert got == pytest.approx(want)


@pytest.mark.parametrize("op, v, v2, want", [
    ("lt", 74_000, 0, 0.3),       # (74000 - 20000) / 180000
    ("ge", 150_000, 0, 5 / 18),
    ("between", 20_000, 110_000, 0.5),
    ("eq", 50_000, 0, 0.0),       # point predicates have measure zero
    ("ne", 50_000, 0, 1.0),
])
def test_float_domain_fractions(op, v, v2, want):
    got = derive_selectivity(_f("c_income", op, v, v2))
    assert got == pytest.approx(want)


def test_key_domains_static_and_override():
    # d_date_sk resolves through STATIC_KEY_DOMAINS (360-row date_dim)
    assert derive_selectivity(_f("d_date_sk", "lt", 90)) \
        == pytest.approx(0.25)
    # an explicit key_domains mapping (e.g. a live catalog's) wins
    assert derive_selectivity(_f("d_date_sk", "lt", 90),
                              key_domains={"d_date_sk": 180}) \
        == pytest.approx(0.5)


def test_unknown_column_falls_back_to_default():
    assert derive_selectivity(_f("mystery", "lt", 7)) == DEFAULT_SELECTIVITY


def test_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown filter op"):
        derive_selectivity(_f("d_month", "like", 1))


# ---------------------------------------------------------------------------
# Calendar correctness: measured kept fractions match the estimates.
# ---------------------------------------------------------------------------


def _column_table(column):
    return next(t for t, cols in TABLE_COLUMNS.items() if column in cols)


def _measured_fraction(catalog, f):
    table = catalog.tables[_column_table(f.column)]
    vals = np.asarray(table.column(f.column))[np.asarray(table.valid)]
    if f.op == "eq":
        mask = vals == f.value
    elif f.op == "ne":
        mask = vals != f.value
    elif f.op == "lt":
        mask = vals < f.value
    elif f.op == "le":
        mask = vals <= f.value
    elif f.op == "gt":
        mask = vals > f.value
    elif f.op == "ge":
        mask = vals >= f.value
    elif f.op == "between":
        mask = (vals >= f.value) & (vals <= f.value2)
    elif f.op == "in":
        mask = np.isin(vals, np.asarray(f.values))
    else:
        raise AssertionError(f.op)
    return mask.mean()


@pytest.fixture(scope="module")
def catalog010():
    return generate(scale=0.1, p=4, seed=42)


def _suite_filters():
    queries = {**all_queries(), **misordered_queries(), **skewed_queries(),
               **filtered_queries(), **text_queries()}
    seen = {}
    for plan in queries.values():
        for node in walk(plan):
            if isinstance(node, Filter):
                key = (node.column, node.op, node.value, node.value2,
                       node.values)
                seen.setdefault(key, node)
    return list(seen.values())


#: date_dim's deterministic layout makes date predicates exact; uniform
#: random payload columns need a sampling tolerance.
_EXACT_TABLES = ("date_dim",)


def test_every_date_predicate_measures_its_declared_selectivity(catalog010):
    checked = 0
    for f in _suite_filters():
        if _column_table(f.column) != "date_dim":
            continue
        measured = _measured_fraction(catalog010, f)
        assert measured == pytest.approx(effective_selectivity(f),
                                         abs=1e-9), (f.column, f.op)
        checked += 1
    assert checked >= 5  # the suite exercises several date predicates


def test_suite_filter_estimates_track_measured_fractions(catalog010):
    """Non-date predicates: estimates are sampling-accurate, not exact."""
    for f in _suite_filters():
        if _column_table(f.column) in _EXACT_TABLES:
            continue
        measured = _measured_fraction(catalog010, f)
        assert measured == pytest.approx(effective_selectivity(f),
                                         abs=0.03), (f.column, f.op)


def test_calendar_layout_is_exact(catalog010):
    """360 days, 12 x 30-day months, one year — no wrap-around remainder."""
    dd = catalog010.tables["date_dim"]
    valid = np.asarray(dd.valid)
    month = np.asarray(dd.column("d_month"))[valid]
    year = np.asarray(dd.column("d_year"))[valid]
    moy = np.asarray(dd.column("d_moy"))[valid]
    assert month.size == 360
    counts = np.bincount(month.astype(int), minlength=12)
    assert np.all(counts == 30)
    assert np.all(year == 2000)
    assert np.all(np.bincount(moy.astype(int), minlength=30) == 12)
