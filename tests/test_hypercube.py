"""Hypercube multi-way shuffle join, bottom to top: the cost-model units
(replication factors, share optimization, the strictly-cheaper selection
gate), cyclic-core detection, the end-to-end executor path where Algorithm 1
picks the cube from cost alone on a cyclic query and the result matches the
forced-binary arm, and the shard_map distributed twin over multi-axis
meshes. The 8-device twin cases run in the multi-device CI tier
(XLA_FLAGS=--xla_force_host_platform_device_count=8), whose matrix also
sets REPRO_MESH_SHAPE={flat,cube} to pin both mesh factorizations of the
same program; they skip where fewer devices exist.
"""

import math
import os
import zlib

import numpy as np
import pytest

import jax

from repro.core import cost_model as cm
from repro.core.cost_model import CostParams, JoinMethod
from repro.core.selection import select_hypercube
from repro.core.stats import TableStats
from repro.joins import from_numpy, partition_round_robin
from repro.joins.distributed import (dist_hypercube_join, make_cube_mesh,
                                     place_cube)
from repro.joins.methods import (HypercubeLink, HypercubeSpec,
                                 hypercube_multiway_join)
from repro.joins.ref import ref_multiway_join, rows_as_set
from repro.sql import Aggregate, Executor, Filter, Join, Scan, cyclic_queries
from repro.sql.logical import cyclic_core
from repro.sql.strategies import ReorderingStrategy

PARAMS = CostParams(p=8, w=1.0)


# ---------------------------------------------------------------------------
# Cost model units.
# ---------------------------------------------------------------------------

def test_cube_replication_factors():
    """f = p / prod(owned shares), down to 1 for all-axis owners and up to
    p for a relation owning nothing (full broadcast)."""
    dims = (2, 4)
    assert cm.cube_replication(dims, [0, 1]) == 1
    assert cm.cube_replication(dims, [0]) == 4
    assert cm.cube_replication(dims, [1]) == 2
    assert cm.cube_replication(dims, []) == 8


def test_factorizations_enumerate_all_ordered_shapes():
    shapes = set(cm._factorizations(8, 2))
    assert shapes == {(1, 8), (2, 4), (4, 2), (8, 1)}
    for dims in cm._factorizations(12, 3):
        assert math.prod(dims) == 12


def test_two_relation_flat_cube_reproduces_shuffle_hash():
    """At f = 1 for two relations the multi-way cost IS Eq. 10's
    shuffle-hash cost — the binary method is the cube's degenerate case."""
    sa, sb = 3.2e6, 4.1e5
    assert cm.hypercube_shuffle_cost([sa, sb], [1.0, 1.0], PARAMS) == (
        pytest.approx(cm.shuffle_hash_cost(sa, sb, PARAMS)))


def test_cube_shares_protect_the_largest_relation():
    """Triangle memberships: the optimizer gives the big probe's axes the
    whole budget so its replication factor stays 1."""
    memberships = [[0, 1], [1, 2], [0, 2]]  # R{a,b}, S{b,c}, T{a,c}
    sizes = [1e9, 1e6, 1e6]
    dims = cm.cube_shares(8, 3, memberships, sizes, PARAMS)
    assert math.prod(dims) == 8
    assert cm.cube_replication(dims, memberships[0]) == 1


def test_binary_interface_refuses_the_multiway_method():
    """method_cost prices only binary joins; the multi-way member is inf
    there so no binary selection path can ever pick it by accident."""
    c = cm.method_cost(JoinMethod.HYPERCUBE_SHUFFLE, 1e6, 1e5, 1e4, 1e3,
                       PARAMS)
    assert c == math.inf


def test_select_hypercube_strictly_cheaper_gate():
    stats = [TableStats(1e8, 1e6), TableStats(1e6, 1e4),
             TableStats(1e6, 1e4)]
    memberships = [[0, 1], [1, 2], [0, 2]]
    sel = select_hypercube(stats, memberships, 3, binary_cost=1e12,
                           params=PARAMS)
    assert sel is not None and sel.method is JoinMethod.HYPERCUBE_SHUFFLE
    assert "cyclic core" in sel.reason
    # Not strictly cheaper -> the binary plan stands.
    assert select_hypercube(stats, memberships, 3, binary_cost=sel.cost,
                            params=PARAMS) is None
    assert select_hypercube(stats, memberships, 3, binary_cost=0.0,
                            params=PARAMS) is None


def test_select_hypercube_distrusts_invalid_statistics():
    """Paper §4.4: sizes at/above the watermark are not trustworthy; the
    multi-way quote refuses rather than gamble a p-way replication on
    them."""
    bad = [TableStats(float("inf"), 1e6), TableStats(1e6, 1e4),
           TableStats(1e6, 1e4)]
    assert select_hypercube(bad, [[0, 1], [1, 2], [0, 2]], 3,
                            binary_cost=1e18, params=PARAMS) is None


# ---------------------------------------------------------------------------
# Cyclic-core detection.
# ---------------------------------------------------------------------------

def test_cyclic_core_shapes():
    tri = [(0, 1), (1, 2), (0, 2)]
    assert cyclic_core(3, tri) == frozenset({0, 1, 2})
    # Star and chain strip to nothing.
    assert cyclic_core(4, [(0, 1), (0, 2), (0, 3)]) == frozenset()
    assert cyclic_core(4, [(0, 1), (1, 2), (2, 3)]) == frozenset()
    # A pendant leaf hanging off a triangle is not part of the core.
    assert cyclic_core(4, tri + [(2, 3)]) == frozenset({0, 1, 2})
    # A doubled edge is still acyclic: the core is a simple-graph 2-core.
    assert cyclic_core(2, [(0, 1), (1, 0)]) == frozenset()


# ---------------------------------------------------------------------------
# End-to-end: Algorithm 1 picks the cube from cost alone.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cube_catalog():
    from repro.sql import generate
    return generate(scale=0.05, p=8, seed=0)


def test_q35_cube_selected_from_cost_and_matches_binary(cube_catalog):
    """The crown jewel: on the cyclic q35 the planner quotes the hypercube
    against the DP's best binary tree, selects it on relative cost alone
    (no hints anywhere), the verification gates stay clean, and the row
    set is identical to the forced-binary arm's."""
    q = cyclic_queries()["q35_triangle"]
    hyper = Executor(cube_catalog, ReorderingStrategy(),
                     verify=True).execute(q)
    assert [d.selection.method for d in hyper.decisions] == (
        [JoinMethod.HYPERCUBE_SHUFFLE])
    assert "cyclic core" in hyper.decisions[0].selection.reason
    binary = Executor(cube_catalog, ReorderingStrategy(), verify=True,
                      hypercube=False).execute(q)
    assert JoinMethod.HYPERCUBE_SHUFFLE not in (
        [d.selection.method for d in binary.decisions])
    assert rows_as_set(hyper.table.to_numpy()) == (
        rows_as_set(binary.table.to_numpy()))


def test_two_relation_eqcol_stays_binary(cube_catalog):
    """An eqcol predicate over an acyclic 2-relation region has no cyclic
    core: the region runs on the ordinary binary path and the closing
    equality is applied as a residual filter."""
    s = Aggregate(Scan("catalog_sales"), "cs_bill_customer_sk",
                  (("cs_item_sk", "max"),))
    j = Join(Scan("store_sales"), s, "ss_customer_sk",
             "cs_bill_customer_sk")
    f = Filter(j, "ss_item_sk", "eqcol", column2="max_cs_item_sk")
    q = Aggregate(f, "ss_store_sk", (("ss_quantity", "sum"),))
    res = Executor(cube_catalog, ReorderingStrategy(), verify=True).execute(q)
    assert JoinMethod.HYPERCUBE_SHUFFLE not in (
        [d.selection.method for d in res.decisions])
    # The residual equality really filtered: survivors obey it.
    out = res.table.to_numpy()
    assert all(len(v) == len(next(iter(out.values()))) for v in out.values())


# ---------------------------------------------------------------------------
# Distributed twin: shard_map over multi-axis meshes.
# ---------------------------------------------------------------------------

def _triangle_tables(p):
    rng = np.random.default_rng(zlib.crc32(b"hc-dist"))
    r = {"ra": rng.integers(0, 20, 160).astype(np.int32),
         "rb": rng.integers(0, 24, 160).astype(np.int32),
         "v": np.arange(160, dtype=np.int32)}
    s = {"sb": np.arange(24, dtype=np.int32),
         "s_c": rng.integers(0, 4, 24).astype(np.int32)}
    t = {"ta": np.arange(20, dtype=np.int32),
         "t_c": rng.integers(0, 4, 20).astype(np.int32)}
    tabs = [partition_round_robin(from_numpy(c, capacity=192), p)
            for c in (r, s, t)]
    spec = HypercubeSpec(
        dims=(), axis_keys=(((0, "ra"), (1, "rb")), ((1, "sb"),),
                            ((0, "ta"),)),
        links=(HypercubeLink(1, "rb", "sb"), HypercubeLink(2, "ra", "ta")),
        checks=(("s_c", "t_c"),))
    want = rows_as_set(ref_multiway_join(
        (r, s, t), [(1, "rb", "sb"), (2, "ra", "ta")], spec.checks))
    return tabs, spec, want


def _mesh_dims():
    """The multi-device CI matrix leg: REPRO_MESH_SHAPE=flat pins the
    degenerate one-axis factorization, cube the genuine 2x4 cube."""
    return (8, 1) if os.environ.get("REPRO_MESH_SHAPE") == "flat" else (2, 4)


def test_dist_twin_single_device_mesh():
    tabs, spec, want = _triangle_tables(1)
    import dataclasses
    spec = dataclasses.replace(spec, dims=(1, 1))
    mesh = make_cube_mesh((1, 1))
    placed = tuple(place_cube(t, mesh) for t in tabs)
    out = dist_hypercube_join(placed, spec, mesh, capacity_factor=16.0)
    assert rows_as_set(out.to_numpy()) == want


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_twin_matches_global_view_and_oracle():
    """The shard_map twin on the real 8-device mesh (shape from the CI
    matrix) equals both the global-view executor path and the numpy
    oracle — the collectives are a faithful re-expression, not a
    different algorithm."""
    import dataclasses
    dims = _mesh_dims()
    tabs, spec, want = _triangle_tables(8)
    spec = dataclasses.replace(spec, dims=dims)
    mesh = make_cube_mesh(dims)
    placed = tuple(place_cube(t, mesh) for t in tabs)
    out = dist_hypercube_join(placed, spec, mesh, capacity_factor=16.0)
    assert rows_as_set(out.to_numpy()) == want
    glob, _ = hypercube_multiway_join(list(tabs), spec,
                                      capacity_factor=16.0)
    assert rows_as_set(glob.to_numpy()) == want
