"""Join engine tests: all five methods vs the numpy oracle, join types,
exchange accounting, slot scatter, and the 8-device shard_map executor."""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.core.cost_model import JoinMethod
from repro.joins import (broadcast, from_numpy, partition_round_robin,
                         run_equi_join, shuffle)
from repro.joins.local_join import hash_join, sort_join
from repro.joins.ref import ref_equi_join, rows_as_set
from repro.joins.slots import slot_scatter

EQUI = [JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_HASH,
        JoinMethod.SHUFFLE_SORT, JoinMethod.BROADCAST_NL,
        JoinMethod.CARTESIAN]


def make_tables(seed=0, na=400, nb=50, p=4, key_range_mult=2):
    rng = np.random.default_rng(seed)
    b = from_numpy({"k": rng.permutation(nb).astype(np.int32),
                    "payload": rng.integers(0, 99, nb).astype(np.int32)})
    a = from_numpy({"k": rng.integers(0, nb * key_range_mult, na
                                      ).astype(np.int32),
                    "v": rng.uniform(0, 1, na).astype(np.float32)})
    return a, b, partition_round_robin(a, p), partition_round_robin(b, p)


@pytest.mark.parametrize("method", EQUI)
def test_methods_match_oracle(method):
    a, b, A, B = make_tables()
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    out, rep = run_equi_join(method, A, B, "k", "k")
    assert rows_as_set(out.to_numpy()) == want
    assert rep.output_rows == len(want)


@pytest.mark.parametrize("method", [JoinMethod.BROADCAST_HASH,
                                    JoinMethod.SHUFFLE_HASH,
                                    JoinMethod.SHUFFLE_SORT])
@pytest.mark.parametrize("jt", ["left_semi", "left_anti"])
def test_join_types(method, jt):
    a, b, A, B = make_tables(seed=3)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k",
                                     join_type=jt))
    out, _ = run_equi_join(method, A, B, "k", "k", join_type=jt)
    assert rows_as_set(out.to_numpy()) == want


def test_left_outer_preserves_probe_rows():
    a, b, A, B = make_tables(seed=5)
    out, _ = run_equi_join(JoinMethod.BROADCAST_HASH, A, B, "k", "k",
                           join_type="left_outer")
    assert out.count() == a.count()


@pytest.mark.parametrize("p", [1, 2, 4, 8])
def test_parallelism_sweep(p):
    a, b, _, _ = make_tables(seed=p)
    A, B = partition_round_robin(a, p), partition_round_robin(b, p)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    for method in (JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_HASH,
                   JoinMethod.SHUFFLE_SORT):
        out, _ = run_equi_join(method, A, B, "k", "k")
        assert rows_as_set(out.to_numpy()) == want, method


def test_kernel_backed_hash_join_matches():
    a, b, A, B = make_tables(seed=11, na=256, nb=32)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    out, _ = run_equi_join(JoinMethod.SHUFFLE_HASH, A, B, "k", "k",
                           use_kernel=True)
    assert rows_as_set(out.to_numpy()) == want


def test_skewed_keys_still_correct():
    # 80% of probe rows hit one hot key (paper §3.7: skew robustness).
    rng = np.random.default_rng(13)
    nb, na = 32, 500
    b = from_numpy({"k": np.arange(nb, dtype=np.int32),
                    "x": np.ones(nb, np.int32)})
    keys = np.where(rng.uniform(size=na) < 0.8, 7,
                    rng.integers(0, nb, na)).astype(np.int32)
    a = from_numpy({"k": keys, "v": np.ones(na, np.float32)})
    A, B = partition_round_robin(a, 4), partition_round_robin(b, 4)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    # Skewed shuffles need more slot capacity: capacity_factor covers it.
    out, rep = run_equi_join(JoinMethod.SHUFFLE_HASH, A, B, "k", "k",
                             capacity_factor=4.0)
    assert rows_as_set(out.to_numpy()) == want
    assert all(e.overflow_rows == 0 for e in rep.exchanges)


def test_exchange_workloads_match_model():
    """Measured broadcast bytes = Eq.1 exactly; shuffle ~= Eq.5."""
    a, b, A, B = make_tables(seed=2, na=2000, nb=64, p=4)
    full, rep = broadcast(B)
    assert rep.network_bytes == (4 - 1) * b.count() * b.row_bytes
    _, rep = shuffle(A, "k")
    model = (4 - 1) / 4 * a.count() * a.row_bytes
    assert rep.network_bytes == pytest.approx(model, rel=0.15)
    assert rep.overflow_rows == 0


def test_slot_scatter_properties():
    rng = np.random.default_rng(1)
    dest = jnp.asarray(rng.integers(0, 4, 100), jnp.int32)
    valid = jnp.asarray(rng.uniform(size=100) < 0.7)
    out = slot_scatter(dest, valid, 4, 50)
    idx = np.asarray(out.idx)
    placed = idx[idx >= 0]
    # Every valid row placed exactly once, in its destination's row.
    assert len(placed) == len(set(placed.tolist())) == int(valid.sum())
    d, v = np.asarray(dest), np.asarray(valid)
    for dd in range(4):
        rows = idx[dd][idx[dd] >= 0]
        assert all(d[r] == dd and v[r] for r in rows)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 200), nd=st.integers(1, 8), cap=st.integers(1, 64),
       seed=st.integers(0, 999))
def test_slot_scatter_conservation(n, nd, cap, seed):
    rng = np.random.default_rng(seed)
    dest = jnp.asarray(rng.integers(0, nd, n), jnp.int32)
    valid = jnp.asarray(rng.uniform(size=n) < 0.8)
    out = slot_scatter(dest, valid, nd, cap)
    placed = int((np.asarray(out.idx) >= 0).sum())
    assert placed + int(out.overflow) == int(valid.sum())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), na=st.integers(1, 300),
       nb=st.integers(1, 100))
def test_local_joins_agree(seed, na, nb):
    """Hash join and sort join are interchangeable local methods (§5.3)."""
    rng = np.random.default_rng(seed)
    ak = jnp.asarray(rng.integers(0, nb * 2, na), jnp.int32)
    av = jnp.asarray(rng.uniform(size=na) < 0.9)
    bk = jnp.asarray(rng.permutation(nb * 2)[:nb], jnp.int32)
    bv = jnp.asarray(rng.uniform(size=nb) < 0.9)
    h = hash_join(ak, av, bk, bv)
    s = sort_join(ak, av, bk, bv)
    np.testing.assert_array_equal(np.asarray(h.found), np.asarray(s.found))
    np.testing.assert_array_equal(np.asarray(h.match_idx),
                                  np.asarray(s.match_idx))


@pytest.mark.slow
def test_distributed_shard_map_executor():
    """Real collectives on 8 placeholder devices (subprocess so the main
    process keeps its single-device view)."""
    helper = Path(__file__).parent / "helpers" / "run_distributed.py"
    proc = subprocess.run([sys.executable, str(helper)], capture_output=True,
                          text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                          cwd=str(Path(__file__).parent.parent))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "DISTRIBUTED_OK" in proc.stdout
