"""Trip-count-aware HLO cost analyzer tests (the §Roofline measurement
substrate) — including the scan-undercount bug it exists to fix."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = _compile(scanned, x, ws)
    # XLA's own analysis counts the body once (the bug we fix). Older jax
    # returns a one-element list of dicts, newer a bare dict.
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # rel tolerance: some versions add a handful of loop-bookkeeping flops.
    assert ca["flops"] == pytest.approx(2 * 128 ** 3, rel=1e-3)
    # ours counts trip_count * body:
    assert analyze(c.as_text()).flops == pytest.approx(8 * 2 * 128 ** 3)


def test_nested_scan_multipliers_compose():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def nested(x, ws):
        def outer(x, wpair):
            return jax.lax.scan(body, x, wpair)[0], None
        return jax.lax.scan(outer, x, ws.reshape(4, 2, 128, 128))[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = _compile(nested, x, ws)
    assert analyze(c.as_text()).flops == pytest.approx(8 * 2 * 128 ** 3)


def test_unrolled_matches_scan():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fu = analyze(_compile(unrolled, x, ws).as_text()).flops
    fs = analyze(_compile(scanned, x, ws).as_text()).flops
    assert fu == pytest.approx(fs)


def test_bytes_include_dot_operands():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, a, a)
    s = analyze(c.as_text())
    # at least reads a, b and writes result
    assert s.bytes_accessed >= 3 * 256 * 256 * 4


def test_collective_multiplier_synthetic():
    hlo = """
ENTRY %main.1 (p: f32[4,8]) -> f32[4,8] {
  %p = f32[4,8]{1,0} parameter(0)
  ROOT %w = (s32[], f32[4,8]{1,0}) while(%p), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
}

%body.1 (q: f32[4,8]) -> f32[4,8] {
  %q = f32[4,8]{1,0} parameter(0)
  ROOT %ar = f32[4,8]{1,0} all-reduce(%q), replica_groups={{0,1,2,3}}
}

%cond.1 (r: f32[4,8]) -> pred[] {
  %r = f32[4,8]{1,0} parameter(0)
  ROOT %c = pred[] constant(1)
}
"""
    s = analyze(hlo)
    ring = 3 / 4
    assert s.collective_wire_bytes["all-reduce"] == pytest.approx(
        5 * 2 * 4 * 8 * 4 * ring)


def test_grad_flops_exceed_forward():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ff = analyze(_compile(loss, w, x).as_text()).flops
    fg = analyze(_compile(jax.grad(loss), w, x).as_text()).flops
    assert fg >= 2 * ff  # backward has ~2x the matmuls
