"""Offline-safe stand-in for ``hypothesis``.

When the real ``hypothesis`` package is installed (the ``[test-fuzz]``
extra), this module re-exports it untouched and nothing changes. When it is
absent — the default offline CI image — a deterministic shim with the same
surface (``given``, ``settings``, ``strategies``) runs each property test
over a fixed, reproducible grid of examples:

  * every strategy contributes its boundary values first (min, then max),
  * the remaining draws come from a PRNG seeded by the test's qualname, so
    failures are stable across runs and machines,
  * the number of examples is ``min(settings.max_examples, grid cap)`` —
    the cap keeps JAX property tests (whose example *shapes* drive
    recompilation) from dominating tier-1 wall time.

Usage in test modules (drop-in for the hypothesis import):

    from helpers.hypothesis_compat import given, settings
    from helpers.hypothesis_compat import strategies as st
"""

from __future__ import annotations

import functools
import math
import os
import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    #: Grid cap for the shim (override via env for deeper local fuzzing).
    #: Kept small: in JAX property tests every distinct example *shape*
    #: costs a compilation, and the grid is deterministic anyway.
    MAX_GRID_EXAMPLES = int(os.environ.get("HYPOTHESIS_COMPAT_MAX_EXAMPLES",
                                           "8"))

    class _Strategy:
        """One drawable dimension of a property test's example grid."""

        def draw(self, i: int, rng: random.Random):
            raise NotImplementedError

    class _Floats(_Strategy):
        def __init__(self, min_value: float, max_value: float):
            self.lo = float(min_value)
            self.hi = float(max_value)

        def draw(self, i, rng):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            # Log-uniform when the range spans decades (size/cardinality
            # strategies), else uniform.
            if self.lo > 0 and self.hi / self.lo > 1e3:
                return math.exp(rng.uniform(math.log(self.lo),
                                            math.log(self.hi)))
            return rng.uniform(self.lo, self.hi)

    class _Integers(_Strategy):
        def __init__(self, min_value: int, max_value: int):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def draw(self, i, rng):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Lists(_Strategy):
        def __init__(self, elements: _Strategy, min_size: int, max_size: int):
            self.elements = elements
            self.min_size = int(min_size)
            self.max_size = int(max_size)

        def draw(self, i, rng):
            if i == 0:
                n = self.min_size
            elif i == 1:
                n = self.max_size
            else:
                n = rng.randint(self.min_size, self.max_size)
            return [self.elements.draw(i + j + 2, rng) for j in range(n)]

    class _StrategiesModule:
        """Shim for ``hypothesis.strategies`` (the subset the suite uses)."""

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False, **_ignored):
            return _Floats(min_value, max_value)

        @staticmethod
        def integers(min_value=0, max_value=1 << 30, **_ignored):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_ignored):
            return _Lists(elements, min_size, max_size)

    strategies = _StrategiesModule()

    def settings(max_examples: int = MAX_GRID_EXAMPLES, deadline=None,
                 **_ignored):
        """Records ``max_examples`` on the (possibly given-wrapped) test."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Runs the test over the deterministic example grid."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = min(getattr(wrapper, "_compat_max_examples",
                                MAX_GRID_EXAMPLES), MAX_GRID_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for i in range(n):
                    args = [s.draw(i, rng) for s in arg_strategies]
                    kwargs = {k: s.draw(i, rng)
                              for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsifying example #{i}: args={args!r} "
                            f"kwargs={kwargs!r}: {e}") from e
                return None

            # pytest must not see the original parameters as fixtures:
            # drop the __wrapped__ signature forwarding and publish an
            # empty signature.
            import inspect
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
