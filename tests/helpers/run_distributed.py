"""Subprocess helper: validates the shard_map join executor on 8 placeholder
host devices (run by tests/test_distributed_join.py). Exits non-zero on any
mismatch with the numpy oracle."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.joins import from_numpy, partition_round_robin  # noqa: E402
from repro.joins.distributed import (dist_broadcast_hash_join,  # noqa: E402
                                     dist_shuffle_hash_join,
                                     dist_shuffle_sort_join, make_join_mesh,
                                     place)
from repro.joins.ref import ref_equi_join, rows_as_set  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8, jax.devices()
    mesh = make_join_mesh(8)
    rng = np.random.default_rng(7)

    nb, na = 64, 1000
    b = from_numpy({"k": rng.permutation(nb).astype(np.int32),
                    "payload": rng.integers(0, 99, nb).astype(np.int32)})
    a = from_numpy({"k": rng.integers(0, nb * 2, na).astype(np.int32),
                    "v": rng.uniform(0, 1, na).astype(np.float32)})
    A = place(partition_round_robin(a, 8), mesh)
    B = place(partition_round_robin(b, 8), mesh)

    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    for name, fn in [("shuffle_hash", dist_shuffle_hash_join),
                     ("shuffle_sort", dist_shuffle_sort_join),
                     ("broadcast_hash", dist_broadcast_hash_join)]:
        if name == "broadcast_hash":
            out = fn(A, B, "k", "k", mesh)
        else:
            out = fn(A, B, "k", "k", mesh)
        got = rows_as_set(out.to_numpy())
        assert got == want, f"{name}: {len(got)} rows vs oracle {len(want)}"
        print(f"{name}: OK ({len(got)} rows, 8 devices)")
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
