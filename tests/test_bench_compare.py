"""``benchmarks/run.py --compare`` regression gate: the zero-baseline
absolute-delta fallback.

Regression context: the comparator used to track only rows with
``us_per_call > 0`` and gate on ``old > 0`` — so any metric whose
baseline was 0.0 (derived rows, warm-cache passes like PR 5's
``reduce_bytes == 0`` repeat runs) either never entered the comparison or
auto-passed no matter how large the new value grew. Zero baselines now
participate and regress through an absolute threshold instead of an
(undefined) ratio.
"""

import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.run import (_tracked_metrics, compare_artifacts,  # noqa: E402
                            new_benchmarks)


def _bundle(path: pathlib.Path, rows, seconds=1.0, bench="demo"):
    payload = [{"bench": bench, "profile": "smoke", "kwargs": {},
                "seconds": seconds,
                "rows": [{"name": n, "us_per_call": us, "derived": ""}
                         for n, us in rows]}]
    path.write_text(json.dumps(payload))
    return path


def test_zero_rows_are_tracked(tmp_path):
    """Zero-valued rows enter the metric set (they used to be dropped)."""
    old = _bundle(tmp_path / "old.json", [("warm", 0.0), ("timed", 5.0)])
    metrics = _tracked_metrics(
        {"demo": json.loads(old.read_text())[0]})
    assert metrics["demo/warm:us_per_call"] == 0.0
    assert metrics["demo/timed:us_per_call"] == 5.0


def test_zero_baseline_blowup_is_caught(tmp_path):
    """A 0 -> large jump must regress via the absolute fallback — this is
    exactly the case the old ratio gate silently auto-passed."""
    old = _bundle(tmp_path / "old.json", [("warm", 0.0)])
    new = _bundle(tmp_path / "new.json", [("warm", 5000.0)])
    offenses = compare_artifacts(str(old), str(new), threshold=0.10,
                                 abs_threshold=100.0)
    assert len(offenses) == 1
    assert "zero baseline" in offenses[0]


def test_zero_baseline_small_drift_passes(tmp_path):
    """Zero baseline with new value under the absolute gate: no offense
    (and in particular no ZeroDivisionError computing a ratio)."""
    old = _bundle(tmp_path / "old.json", [("warm", 0.0)])
    new = _bundle(tmp_path / "new.json", [("warm", 50.0)])
    assert compare_artifacts(str(old), str(new), threshold=0.10,
                             abs_threshold=100.0) == []


def test_ratio_gate_unchanged_for_positive_baselines(tmp_path):
    old = _bundle(tmp_path / "old.json", [("timed", 100.0)])
    slow = _bundle(tmp_path / "slow.json", [("timed", 120.0)])
    ok = _bundle(tmp_path / "ok.json", [("timed", 105.0)])
    assert len(compare_artifacts(str(old), str(slow), threshold=0.10)) == 1
    assert compare_artifacts(str(old), str(ok), threshold=0.10) == []


def test_vanished_metric_is_an_offense(tmp_path):
    old = _bundle(tmp_path / "old.json", [("warm", 0.0), ("timed", 5.0)])
    new = _bundle(tmp_path / "new.json", [("timed", 5.0)])
    offenses = compare_artifacts(str(old), str(new))
    assert len(offenses) == 1 and "missing" in offenses[0]


def test_seconds_always_tracked(tmp_path):
    old = _bundle(tmp_path / "old.json", [], seconds=10.0)
    new = _bundle(tmp_path / "new.json", [], seconds=20.0)
    offenses = compare_artifacts(str(old), str(new), threshold=0.5)
    assert len(offenses) == 1 and "demo:seconds" in offenses[0]


@pytest.mark.parametrize("old_us,new_us,n", [(0.0, 0.0, 0), (5.0, 5.0, 0)])
def test_identical_bundles_clean(tmp_path, old_us, new_us, n):
    old = _bundle(tmp_path / "old.json", [("row", old_us)])
    new = _bundle(tmp_path / "new.json", [("row", new_us)])
    assert len(compare_artifacts(str(old), str(new))) == n


def test_new_only_benchmark_is_surfaced_not_an_offense(tmp_path):
    """A benchmark present only in NEW (freshly registered, never
    baselined) used to be skipped silently — it must now be reported as
    informational while still passing the regression gate."""
    old = _bundle(tmp_path / "old.json", [("timed", 5.0)])
    new_payloads = json.loads(
        _bundle(tmp_path / "tmp.json", [("timed", 5.0)]).read_text())
    new_payloads += json.loads(_bundle(
        tmp_path / "tmp2.json", [("fresh_row", 3.0)],
        bench="brand_new").read_text())
    new = tmp_path / "new.json"
    new.write_text(json.dumps(new_payloads))
    assert compare_artifacts(str(old), str(new)) == []
    assert new_benchmarks(str(old), str(new)) == ["brand_new"]
    # Symmetric sanity: nothing is "new" when comparing a file to itself.
    assert new_benchmarks(str(new), str(new)) == []
