"""The estimator-accuracy harness: every golden query (q1–q37), run under
the reordering RelJoin strategy, must keep its worst estimated-vs-measured
cardinality q-error at every exchange boundary under a documented ceiling.

The ceilings are *claims*, not slack: most queries sit at 1.0x–1.2x
because their filters, group-bys and joins are all histogram-covered
(``Catalog.column_stats``). The few documented outliers say exactly what
the estimator cannot see — a regression that pushes any query past its
ceiling means an estimator path lost its histogram backing.

Also here: the cross-query ``FilterCache`` seeding regression — measured
build-side stats stored with cached payloads make a *static* executor's
sigma estimates runtime-accurate (the quote changes; the rows never do).
"""

import pytest

from repro.joins.ref import rows_as_set
from repro.sql import (Executor, FilterCache, FilteredStrategy,
                       RelJoinStrategy, ReorderingStrategy, all_queries,
                       cyclic_queries, filtered_queries, misordered_queries,
                       skewed_queries, text_queries)
from repro.sql.logical import Filter, Join, Scan

#: Default worst-boundary q-error ceiling: estimates within 1.5x of
#: measured at every exchange boundary.
DEFAULT_CEILING = 1.5

#: Documented exceptions, with the estimator blind spot each one names.
#: Measured worst q-errors (scale 0.1, p=4, seed 42) in parentheses.
CEILINGS = {
    # Aggregate-over-aggregate: the outer group key's NDV histogram
    # describes the base table, not the inner aggregate's output (2.70).
    "q4_agg_agg": 3.5,
    # Fact-fact join: independence assumption on two Zipf fact tables
    # sharing a key — correlation the per-column histograms can't carry
    # (1.66).
    "q13_fact_fact_first": 2.0,
    # Cyclic closers: hypercube regions finish with eqcol predicates
    # (col = col), which have no per-column histogram form — they fall
    # back to the declared closing selectivity (60 / 12 / 60).
    "q35_triangle": 75.0,
    "q36_triangle_shared_axis": 16.0,
    "q37_four_clique": 75.0,
}


def golden_queries():
    out = dict(all_queries())
    out.update(misordered_queries())
    out.update(skewed_queries())
    out.update(filtered_queries())
    out.update(text_queries())
    out.update(cyclic_queries())
    return out


@pytest.mark.parametrize("qname", sorted(golden_queries()))
def test_worst_boundary_q_error_under_ceiling(catalog, qname):
    plan = golden_queries()[qname]
    ex = Executor(catalog, strategy=ReorderingStrategy(RelJoinStrategy()),
                  verify=True)
    res = ex.execute(plan)
    ceiling = CEILINGS.get(qname, DEFAULT_CEILING)
    assert res.cardinalities, f"{qname} recorded no exchange boundaries"
    worst = res.max_q_error
    assert worst <= ceiling, (
        f"{qname}: worst boundary q-error {worst:.3f} exceeds the "
        f"documented ceiling {ceiling} — an estimator path lost its "
        "histogram backing")


def test_most_queries_are_near_exact(catalog):
    """The headline claim behind the histogram tentpole: with per-column
    statistics the *bulk* of the suite estimates within 1.2x at every
    boundary — not just under the per-query ceilings."""
    strategy = ReorderingStrategy(RelJoinStrategy())
    near_exact = 0
    queries = golden_queries()
    for qname, plan in queries.items():
        res = Executor(catalog, strategy=strategy).execute(plan)
        if res.max_q_error <= 1.2:
            near_exact += 1
    assert near_exact >= 30, (
        f"only {near_exact}/{len(queries)} queries estimate within 1.2x — "
        "histogram coverage regressed broadly")


def test_every_record_is_a_genuine_prediction(catalog):
    """Cardinality records must come from the estimated channel, never
    echo the measurement: under an inflated est_error the static
    estimates move, proving no record is measured-as-estimated."""
    plan = golden_queries()["q1_star3"]
    strategy = ReorderingStrategy(RelJoinStrategy())
    honest = Executor(catalog, strategy=strategy, adaptive=False)
    skewed = Executor(catalog, strategy=strategy, adaptive=False,
                      est_error=3.0)
    r1, r2 = honest.execute(plan), skewed.execute(plan)
    assert [c.measured for c in r1.cardinalities] == \
        [c.measured for c in r2.cardinalities]
    assert any(a.estimated != b.estimated
               for a, b in zip(r1.cardinalities, r2.cardinalities))


# -- FilterCache measured-stats seeding (the PR's bugfix satellite) ---------


def _filtered_join_plan():
    """store_sales ⋈ (item filtered to i_item_sk < 150): selective build
    side, so the runtime-filter planner quotes (and applies) a filter."""
    return Join(Scan("store_sales"),
                Filter(Scan("item"), "i_item_sk", "lt", 150.0),
                "ss_item_sk", "i_item_sk")


def test_warm_cache_seeds_static_sigma_estimates(catalog):
    """A static (adaptive=False) executor with a deliberately inflated
    est_error quotes runtime filters off wrong sigma estimates — unless
    the cross-query FilterCache already holds the *measured* build-side
    stats for the same predicate chain, in which case the sigma estimate
    snaps to runtime-accurate. Only the quote changes: rows are identical
    warm vs cold."""
    plan = _filtered_join_plan()
    cache = FilterCache()
    warm_strategy = FilteredStrategy(RelJoinStrategy(), cache=cache)

    # Cold static run: sigma comes from the (inflated) estimated stats.
    cold = Executor(catalog, strategy=FilteredStrategy(RelJoinStrategy()),
                    adaptive=False, est_error=2.5).execute(plan)
    assert cold.filters, "scenario must plan a runtime filter"

    # Adaptive run primes the cache with measured build-side stats.
    primed = Executor(catalog, strategy=warm_strategy).execute(plan)
    assert primed.filters

    # Warm static run: same inflated est_error, but the cached measured
    # stats win — the sigma estimate matches the adaptive run's.
    warm = Executor(catalog, strategy=warm_strategy, adaptive=False,
                    est_error=2.5).execute(plan)
    assert warm.filters
    assert warm.filters[0].plan.sigma_est == \
        pytest.approx(primed.filters[0].plan.sigma_est)
    assert warm.filters[0].plan.sigma_est != \
        pytest.approx(cold.filters[0].plan.sigma_est)

    # The estimate is the only thing that moved.
    assert warm.rows == cold.rows == primed.rows
    assert rows_as_set(warm.table.to_numpy()) == \
        rows_as_set(cold.table.to_numpy())


def test_cold_cache_changes_nothing(catalog):
    """An empty cache is inert: quotes and rows are byte-identical to the
    cache-free strategy."""
    plan = _filtered_join_plan()
    uncached = Executor(catalog,
                        strategy=FilteredStrategy(RelJoinStrategy()),
                        adaptive=False, est_error=2.5).execute(plan)
    fresh = Executor(catalog,
                     strategy=FilteredStrategy(RelJoinStrategy(),
                                               cache=FilterCache()),
                     adaptive=False, est_error=2.5).execute(plan)
    assert [f.plan for f in fresh.filters] == \
        [f.plan for f in uncached.filters]
    assert fresh.rows == uncached.rows
