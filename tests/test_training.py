"""Training substrate tests: loss decreases, checkpoint/restart determinism,
elastic re-sharding, optimizer correctness, data pipeline determinism."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.relshard import plan_model
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeConfig
from repro.training import checkpoint as ck
from repro.training.data import DataConfig, batch_for_step
from repro.training.optimizer import (OptConfig, apply_updates,
                                      init_opt_state)
from repro.training.train_loop import train

SHAPE = ShapeConfig("t", 64, 4, "train")
MESH1 = (("data", 1), ("model", 1))


def small_cfg():
    return dataclasses.replace(get_smoke_config("tinyllama_1_1b"),
                               n_layers=2, d_model=64, d_ff=128, vocab=256)


def test_loss_decreases():
    cfg = small_cfg()
    plan = plan_model(cfg, MESH1, SHAPE, fsdp=False)
    out = train(cfg, plan, None, steps=40, global_batch=4, seq_len=64,
                opt_cfg=OptConfig(lr=2e-3, warmup_steps=5), log_every=5)
    hist = out["history"]
    assert hist[-1][1] < hist[0][1] - 0.3, hist


def test_data_pipeline_deterministic():
    dc = DataConfig(vocab=256, seq_len=32, global_batch=4, seed=3)
    b1 = batch_for_step(dc, 7)
    b2 = batch_for_step(dc, 7)
    b3 = batch_for_step(dc, 8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_checkpoint_restart_exact(tmp_path):
    """Kill-and-restart must reproduce the exact same training state."""
    cfg = small_cfg()
    plan = plan_model(cfg, MESH1, SHAPE, fsdp=False)
    opt = OptConfig(lr=1e-3, warmup_steps=5)
    d = str(tmp_path / "ck")
    # run 20 steps with a checkpoint at 10
    full = train(cfg, plan, None, steps=20, global_batch=4, seq_len=64,
                 opt_cfg=opt, ckpt_dir=d, ckpt_every=10, resume=False,
                 log_every=100)
    # fresh process-equivalent: resume from step 10 and run to 20
    resumed = train(cfg, plan, None, steps=20, global_batch=4, seq_len=64,
                    opt_cfg=opt, ckpt_dir=d, ckpt_every=100, resume=True,
                    log_every=100)
    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-4,
                                   atol=2e-4)


def test_checkpoint_atomicity(tmp_path):
    """A half-written checkpoint directory must never be selected."""
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "step_00000009"))  # no manifest => ignored
    tree = {"a": jnp.ones((3,)), "b": {"c": jnp.zeros((2, 2))}}
    ck.save(d, 5, tree)
    assert ck.latest_step(d) == 5
    restored, _ = ck.restore(d, 5, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones(3))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ck")
    ck.save(d, 1, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        ck.restore(d, 1, {"a": jnp.ones((4,))})


def test_elastic_resharding(tmp_path):
    """Save on one mesh, restore onto a different mesh (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    arr = jnp.arange(16.0).reshape(4, 4)
    ck.save(d, 1, {"w": arr})
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ck.restore(d, 1, {"w": arr}, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(arr))
    assert restored["w"].sharding == sh["w"]


def test_adamw_reduces_loss_on_quadratic():
    opt = OptConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(opt, params)
    for _ in range(100):
        g = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = apply_updates(opt, params, state, g)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adafactor_state_is_factored():
    opt = OptConfig(name="adafactor", lr=0.01)
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    state = init_opt_state(opt, params)
    assert state["fact"]["w"]["vr"].shape == (8,)
    assert state["fact"]["w"]["vc"].shape == (16,)
    assert state["fact"]["b"]["v"].shape == (16,)
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2, _ = apply_updates(opt, params, state, g)
    assert float(p2["w"][0, 0]) < 1.0


def test_grad_compression_flag():
    opt = OptConfig(lr=0.01, grad_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = init_opt_state(opt, params)
    g = {"w": jnp.full((4, 4), 0.137)}
    p2, _, m = apply_updates(opt, params, state, g)
    assert np.isfinite(float(m["grad_norm"]))
    assert float(p2["w"][0, 0]) < 1.0
