"""Skew-aware join selection tests: the straggler cost model, the measured
skew statistic, salted-method selection on the skewed queries (q16-q18),
straggler-byte reduction vs RelJoin, skew-0 parity, and the executor's
overflow-retry regression under Zipf-1.4 hot partitions."""

import math

from repro.core import cost_model as cm
from repro.core.cost_model import CostParams, JoinMethod
from repro.core.selection import JoinProperties, select_join_method
from repro.core.stats import TableStats
from repro.joins.exchange import key_skew, shuffle
from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (Executor, ForcedStrategy, RelJoinStrategy,
                       ReorderingStrategy, SkewAwareStrategy, generate,
                       skewed_queries)

P8 = CostParams(p=8, w=1.0)


# ---------------------------------------------------------------------------
# Cost model: straggler scaling
# ---------------------------------------------------------------------------

def test_skewed_shuffle_costs_reduce_to_paper_at_one():
    sa, sb, ca, cb = 1000.0, 300.0, 7000.0, 1400.0
    assert (cm.shuffle_hash_cost(sa, sb, P8, 1.0, 1.0)
            == cm.shuffle_hash_cost(sa, sb, P8))
    assert (cm.shuffle_sort_cost(sa, sb, ca, cb, P8, 1.0, 1.0)
            == cm.shuffle_sort_cost(sa, sb, ca, cb, P8))


def test_shuffle_costs_monotone_in_skew():
    sa, sb = 1000.0, 300.0
    prev = 0.0
    for s in (1.0, 1.5, 2.0, 4.0):
        c = cm.shuffle_hash_cost(sa, sb, P8, skew_a=s)
        assert c > prev
        prev = c
    # Broadcast-family costs are skew-invariant by construction.
    for s in (1.0, 2.0, 4.0):
        assert (cm.method_cost(JoinMethod.BROADCAST_HASH, sa, sb, 100, 30,
                               P8, skew_a=s)
                == cm.broadcast_hash_cost(sa, sb, P8))


def test_salted_strictly_worse_without_skew():
    """At skew 1 the replication surcharge buys nothing: Algorithm 1 must
    never pick the salted method on uniform statistics."""
    for sa, sb in ((1000.0, 300.0), (5000.0, 100.0), (100.0, 100.0)):
        assert (cm.salted_shuffle_hash_cost(sa, sb, P8, skew_a=1.0)
                > cm.shuffle_hash_cost(sa, sb, P8))


def test_salted_wins_under_enough_skew():
    sa, sb = 1000.0, 300.0
    s = 2.5
    assert (cm.salted_shuffle_hash_cost(sa, sb, P8, skew_a=s)
            < cm.shuffle_hash_cost(sa, sb, P8, skew_a=s))


def test_k0_skew_variant_matches_raw_costs():
    """k0(s) must agree with the raw C_bh vs C_sh comparison (both sides
    charged at the straggler), like Eq. 13 does at s=1."""
    for p in (4, 8, 20):
        for w in (0.5, 1.0, 2.0):
            params = CostParams(p=p, w=w)
            assert cm.k0_threshold(params, 1.0) == cm.k0_threshold(params)
            for s in (1.0, 1.3, 2.0, 4.0):
                k0 = cm.k0_threshold(params, s)
                sb = 1000.0
                for k in (0.5, 2.0, 10.0, 40.0, 100.0):
                    if not math.isfinite(k0) or abs(k - k0) < 1e-6 * max(k0, 1):
                        continue
                    bh = cm.broadcast_hash_cost(k * sb, sb, params)
                    sh = cm.shuffle_hash_cost(k * sb, sb, params, s, s)
                    assert (bh < sh) == (k > k0), (p, w, s, k, k0)


def test_k0_drops_with_skew():
    """Skew makes broadcasting win earlier: k0(s) is decreasing in s."""
    k0s = [cm.k0_threshold(P8, s) for s in (1.0, 1.5, 2.0, 4.0)]
    assert all(a > b for a, b in zip(k0s, k0s[1:]))


def test_default_salt_factor_bounds():
    assert cm.default_salt_factor(1.0, P8) == 2
    assert cm.default_salt_factor(2.9, P8) == 3
    assert cm.default_salt_factor(50.0, P8) == P8.p  # capped at p


# ---------------------------------------------------------------------------
# Selection: Algorithm 1 extension
# ---------------------------------------------------------------------------

def _stats(size, skew=1.0):
    return TableStats(size, size / 32.0, skew=skew)


def test_selection_salted_only_under_skew():
    props = JoinProperties()
    uniform = select_join_method(_stats(320e3), _stats(190e3), props, P8)
    assert uniform.method is JoinMethod.SHUFFLE_HASH
    skewed = select_join_method(_stats(320e3, skew=2.5), _stats(190e3),
                                props, P8)
    assert skewed.method is JoinMethod.SALTED_SHUFFLE_HASH
    assert skewed.salt_r == 3
    # the full cost table is audited, including the salted entry
    assert (skewed.costs[JoinMethod.SALTED_SHUFFLE_HASH]
            < skewed.costs[JoinMethod.SHUFFLE_HASH])


def test_selection_no_salting_on_swapped_sides():
    """The A role landing on the plan's right side makes salting
    unexecutable (the engine salts left, replicates right): even with a
    salted-favourable skew there, selection must stay in the paper's set."""
    sel = select_join_method(_stats(100e3), _stats(300e3, skew=3.0),
                             JoinProperties(), P8)
    assert sel.swapped_sides
    assert sel.method is not JoinMethod.SALTED_SHUFFLE_HASH


def test_selection_extreme_skew_flips_to_broadcast():
    """Skew far beyond what r <= p salt buckets can flatten (s >> p): the
    residual straggler still loses to the skew-invariant broadcast, even at
    k below the uniform k0."""
    params = CostParams(p=8, w=1.0)
    k = 10.0  # k0(1) = 15 at p=8, w=1
    assert k < cm.k0_threshold(params)
    sel = select_join_method(_stats(k * 10e3, skew=20.0), _stats(10e3),
                             JoinProperties(), params)
    assert sel.method is JoinMethod.BROADCAST_HASH
    assert (sel.costs[JoinMethod.SALTED_SHUFFLE_HASH]
            > sel.costs[JoinMethod.BROADCAST_HASH])


# ---------------------------------------------------------------------------
# Measured skew statistic
# ---------------------------------------------------------------------------

def test_key_skew_uniform_snaps_to_one(zipf_catalogs):
    t = zipf_catalogs[0.0].table("store_sales")
    assert key_skew(t, "ss_customer_sk", 8) == 1.0


def test_key_skew_detects_zipf(zipf_catalogs):
    t = zipf_catalogs[1.2].table("store_sales")
    s = key_skew(t, "ss_customer_sk", 8)
    assert s > 1.5


# ---------------------------------------------------------------------------
# End-to-end: the skewed queries q16-q18
# ---------------------------------------------------------------------------

def _rows(res):
    return rows_as_set(res.table.to_numpy())


def test_skew_zero_selections_identical_to_reljoin(zipf_catalogs):
    """Acceptance: at skew 0 SkewAwareStrategy's selections are
    byte-for-byte RelJoinStrategy's."""
    cat = zipf_catalogs[0.0]
    for qname, plan in skewed_queries().items():
        base = Executor(cat, RelJoinStrategy()).execute(plan)
        skew = Executor(cat, SkewAwareStrategy()).execute(plan)
        assert skew.methods() == base.methods(), qname
        assert rows_close(_rows(skew), _rows(base)), qname


def test_skewed_queries_select_salted_and_cut_straggler(zipf_catalogs):
    """Acceptance: at Zipf 1.2, every skewed query uses the salted method at
    least once, preserves results, and lands fewer straggler bytes than
    RelJoin's plain shuffle plan."""
    cat = zipf_catalogs[1.2]
    for qname, plan in skewed_queries().items():
        base = Executor(cat, RelJoinStrategy()).execute(plan)
        skew = Executor(cat, SkewAwareStrategy()).execute(plan)
        assert JoinMethod.SALTED_SHUFFLE_HASH in skew.methods(), qname
        assert JoinMethod.SALTED_SHUFFLE_HASH not in base.methods(), qname
        assert rows_close(_rows(skew), _rows(base)), qname
        assert skew.straggler_bytes < base.straggler_bytes, qname


def test_reordering_wrapper_forwards_skew_awareness(zipf_catalogs):
    """Reorder(SkewAware) must keep skew handling: the wrapper forwards the
    executor-facing flags and the skew statistic is still measured. (It may
    legitimately *avoid* the salted method — pruning/reordering can shrink
    or resequence the hot join so plain shuffle wins — but the skew
    machinery must be live, and results must match the unreordered plan.)"""
    strat = ReorderingStrategy(SkewAwareStrategy())
    assert strat.skew_aware and strat.skew_floor == 1.1
    plan = skewed_queries()["q16_hot_customer"]
    res = Executor(zipf_catalogs[1.2], strat).execute(plan)
    assert any(d.left_stats.skew > 1 or d.right_stats.skew > 1
               for d in res.decisions)
    base = Executor(zipf_catalogs[1.2], SkewAwareStrategy()).execute(plan)
    assert rows_close(_rows(res), _rows(base))


def test_skew_overrides_target_single_column():
    """Per-column skew targeting: only ss_customer_sk is hot, so q16's
    customer join salts while the key's siblings stay uniform."""
    cat = generate(scale=0.1, p=8, seed=11, skew=0.0,
                   skew_overrides={"ss_customer_sk": 1.3})
    ss = cat.table("store_sales")
    assert key_skew(ss, "ss_customer_sk", 8) > 1.3
    assert key_skew(ss, "ss_item_sk", 8) == 1.0
    res = Executor(cat, SkewAwareStrategy()).execute(
        skewed_queries()["q16_hot_customer"])
    assert JoinMethod.SALTED_SHUFFLE_HASH in res.methods()


def test_skew_statistic_reaches_selection(zipf_catalogs):
    """The audit trail carries the measured skew: the salted decision's
    probe-side statistic must show the straggler factor it priced."""
    cat = zipf_catalogs[1.2]
    res = Executor(cat, SkewAwareStrategy()).execute(
        skewed_queries()["q16_hot_customer"])
    d = res.decisions[0]
    assert d.selection.method is JoinMethod.SALTED_SHUFFLE_HASH
    assert d.left_stats.skew > 1.5
    assert d.selection.salt_r >= 2


# ---------------------------------------------------------------------------
# Regression: executor overflow retry under Zipf-1.4 hot partitions
# ---------------------------------------------------------------------------

def test_overflow_retry_geometric_doubling(zipf_catalogs):
    """A Zipf-1.4 shuffle whose hot partition exceeds the default
    capacity_factor=2.0 slot budget must succeed via the executor's
    geometric-doubling retry and preserve results."""
    cat = zipf_catalogs[1.4]
    # (a) the raw exchange at factor 2.0 genuinely overflows — the retry
    # path is exercised, not skipped.
    _, rep = shuffle(cat.table("store_sales"), "ss_customer_sk", 2.0)
    assert rep.overflow_rows > 0
    # (b) the executor absorbs it: forced plain shuffle vs the salted plan
    # must both complete and agree.
    plan = skewed_queries()["q16_hot_customer"]
    forced = Executor(cat, ForcedStrategy(JoinMethod.SHUFFLE_HASH),
                      capacity_factor=2.0).execute(plan)
    salted = Executor(cat, SkewAwareStrategy(),
                      capacity_factor=2.0).execute(plan)
    assert forced.rows > 0
    assert rows_close(_rows(forced), _rows(salted))
