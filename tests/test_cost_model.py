"""Unit + property tests for the RelJoin cost model (paper §3)."""

import math

import pytest
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.core import cost_model as cm
from repro.core.cost_model import CostParams, JoinMethod

MB = 2 ** 20


def test_k0_matches_paper_testbed():
    # Paper Table 3: w=1 and hence k0=39 (p=20).
    assert cm.k0_threshold(CostParams(p=20, w=1.0)) == pytest.approx(39.0)


def test_paper_q39b_example():
    # §5.2: join with |A|~40MB, |B|~0.13MB -> C_bh = 45.2MB, C_ss = 78.4MB.
    params = CostParams(p=20, w=1.0)
    c_bh = cm.broadcast_hash_cost(40 * MB, 0.13 * MB, params)
    assert c_bh / MB == pytest.approx(45.2, rel=0.01)
    # The C_ss figure implies the aggregated intermediate had a ~= p rows
    # (log term ~ 0); Eq. 8 then gives 78.25MB ~= the paper's 78.4MB.
    c_ss = cm.shuffle_sort_cost(40 * MB, 0.13 * MB, 20, 20, params)
    assert c_ss / MB == pytest.approx(78.4, rel=0.01)


def test_eq4_expansion():
    # C_broadcastHash = w*C_broadcast + C_build + C_probe.
    params = CostParams(p=7, w=2.5)
    sa, sb = 1000.0, 300.0
    lhs = cm.broadcast_hash_cost(sa, sb, params)
    rhs = (params.w * cm.broadcast_workload(sb, params)
           + cm.build_workload_broadcast(sb, params)
           + cm.probe_workload(sa, sb, 100, 30))
    assert lhs == pytest.approx(rhs)


def test_eq10_expansion():
    params = CostParams(p=7, w=2.5)
    sa, sb = 1000.0, 300.0
    lhs = cm.shuffle_hash_cost(sa, sb, params)
    rhs = (params.w * cm.shuffle_workload(sa, sb, params)
           + cm.build_workload_shuffle(sb)
           + cm.probe_workload(sa, sb, 100, 30))
    assert lhs == pytest.approx(rhs)


def test_eq8_expansion():
    params = CostParams(p=7, w=2.5)
    sa, sb, ca, cb = 1000.0, 300.0, 7000.0, 1400.0
    lhs = cm.shuffle_sort_cost(sa, sb, ca, cb, params)
    rhs = (params.w * cm.shuffle_workload(sa, sb, params)
           + cm.sort_workload(sa, sb, ca, cb, params)
           + cm.merge_workload(sa, sb))
    assert lhs == pytest.approx(rhs)


def test_probe_best_and_worst_case():
    # §3.2.3: l_fan=0 -> |A| ; l_fan=b -> |A| + a|B|.
    sa, sb, a, b = 100.0, 50.0, 10.0, 5.0
    assert cm.probe_workload(sa, sb, a, b, l_fan=0.0) == sa
    assert cm.probe_workload(sa, sb, a, b, l_fan=b) == sa + a * sb


sizes = st.floats(min_value=1.0, max_value=1e12, allow_nan=False)
cards = st.floats(min_value=1.0, max_value=1e10, allow_nan=False)
ps = st.integers(min_value=2, max_value=4096)
ws = st.floats(min_value=1e-5, max_value=1e5, allow_nan=False)


@settings(max_examples=200, deadline=None)
@given(sb=sizes, ca=cards, cb=cards, p=ps, w=ws, k=st.floats(1.0, 1e6))
def test_threshold_consistent_with_costs(sb, ca, cb, p, w, k):
    """Eq. 13 must agree with the raw Eq. 4 / Eq. 10 comparison everywhere."""
    params = CostParams(p=p, w=w)
    sa = k * sb
    bh = cm.broadcast_hash_cost(sa, sb, params)
    sh = cm.shuffle_hash_cost(sa, sb, params)
    k0 = cm.k0_threshold(params)
    if k > k0 * (1 + 1e-9):
        assert bh < sh
    elif k < k0 * (1 - 1e-9):
        assert bh >= sh


@settings(max_examples=200, deadline=None)
@given(sa=sizes, sb=sizes, ca=cards, cb=cards, p=ps, w=ws)
def test_hash_never_worse_than_sort(sa, sb, ca, cb, p, w):
    """§3.6.1: C'_build + C_probe < C_sort + C_merge under the paper's
    a, b >> p assumption (partitions hold at least a few rows), so shuffle
    hash <= shuffle sort."""
    params = CostParams(p=p, w=w)
    if ca < 2 * p or cb < 2 * p:  # paper's problem setting: a >> p, b >> p
        return
    assert (cm.shuffle_hash_cost(sa, sb, params)
            <= cm.shuffle_sort_cost(sa, sb, ca, cb, params) + 1e-6)


@settings(max_examples=200, deadline=None)
@given(sa=sizes, sb=sizes, p=ps, w=ws,
       ca=st.floats(min_value=1e4, max_value=1e10),
       cb=st.floats(min_value=1e4, max_value=1e10))
def test_nl_family_dominated(sa, sb, ca, cb, p, w):
    """§3.5: with a >> p, NL joins are strictly worse than hash twins."""
    if ca < 100 * p:  # paper assumption a >> p
        return
    params = CostParams(p=p, w=w)
    assert (cm.broadcast_nl_cost(sa, sb, ca, params)
            > cm.broadcast_hash_cost(sa, sb, params))
    assert (cm.cartesian_cost(sa, sb, ca, params)
            > cm.shuffle_hash_cost(sa, sb, params))


@settings(max_examples=100, deadline=None)
@given(sa=sizes, sb=sizes, ca=cards, cb=cards, p=ps, w=ws)
def test_costs_positive_and_monotone_in_sizes(sa, sb, ca, cb, p, w):
    params = CostParams(p=p, w=w)
    for m in JoinMethod:
        c = cm.method_cost(m, sa, sb, ca, cb, params)
        if m is JoinMethod.HYPERCUBE_SHUFFLE:
            # Multi-way: priced by hypercube_shuffle_cost over n relations,
            # never through the binary interface.
            assert c == math.inf
            continue
        c2 = cm.method_cost(m, sa * 2, sb, ca, cb, params)
        assert c > 0 and math.isfinite(c)
        assert c2 >= c


@settings(max_examples=100, deadline=None)
@given(p=ps, w=ws)
def test_k0_increases_with_p(p, w):
    """§3.6.2: larger parallelism -> broadcasting costs more -> higher k0."""
    k1 = cm.k0_threshold(CostParams(p=p, w=w))
    k2 = cm.k0_threshold(CostParams(p=p + 1, w=w))
    assert k2 > k1


def test_invalid_params_rejected():
    with pytest.raises(ValueError):
        CostParams(p=0)
    with pytest.raises(ValueError):
        CostParams(p=4, w=-1.0)
