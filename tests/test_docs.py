"""Docs stay true: the cost-model equation map covers the module's whole
public surface, ``__all__`` itself can't rot, and no markdown link or
referenced repo path dangles.

These are the safety nets behind the ``docs/`` satellite: a cost function
added without a row in docs/cost_model.md — or a doc reorganization that
breaks a cross-link — fails tier-1, not a reader.
"""

import inspect
import pathlib
import re

import pytest

import repro.core.cost_model as cost_model
import repro.sql.binder as sql_binder
import repro.sql.parser as sql_parser
import repro.sql.plan_analysis as plan_analysis
import repro.sql.printer as sql_printer
import repro.sql.selectivity as sql_selectivity
import repro.sql.service as sql_service

ROOT = pathlib.Path(__file__).parent.parent
DOCS = ROOT / "docs"


def _public_surface(module):
    """Names the module actually defines publicly (functions, classes,
    upper-case constants) — the ground truth ``__all__`` must match."""
    names = set()
    for name, obj in vars(module).items():
        if name.startswith("_"):
            continue
        if inspect.isfunction(obj) or inspect.isclass(obj):
            if getattr(obj, "__module__", None) == module.__name__:
                names.add(name)
        elif name.isupper():
            names.add(name)
    return names


def test_cost_model_all_matches_public_surface():
    assert set(cost_model.__all__) == _public_surface(cost_model)


def test_cost_model_doc_covers_every_public_name():
    """docs/cost_model.md documents every name in cost_model.__all__ —
    the acceptance criterion of the docs satellite. Names must appear in
    backticks so the doc references them as code, not in passing."""
    doc = (DOCS / "cost_model.md").read_text()
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", doc))
    missing = set(cost_model.__all__) - documented
    assert not missing, (
        f"docs/cost_model.md is missing {sorted(missing)} — every public "
        "cost-model name needs a row in the equation map")


def test_plan_analysis_all_matches_public_surface():
    assert set(plan_analysis.__all__) == _public_surface(plan_analysis)


def test_plan_analysis_doc_covers_every_rule_and_name():
    """docs/plan_analysis.md documents every rule in the RULES registry
    (as a `### `-headed section, so each rule gets invariant + failure
    example, not a passing mention) and backticks every public name."""
    doc = (DOCS / "plan_analysis.md").read_text()
    for rule_id in plan_analysis.RULES:
        assert f"### `{rule_id}`" in doc, (
            f"docs/plan_analysis.md has no section for {rule_id}")
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", doc))
    missing = set(plan_analysis.__all__) - documented
    assert not missing, (
        f"docs/plan_analysis.md is missing {sorted(missing)}")


def test_rule_registry_is_consistent():
    """Registry hygiene: ids key their own Rule objects, severities are
    from the documented vocabulary, invariants are real sentences."""
    for rule_id, rule in plan_analysis.RULES.items():
        assert rule.rule_id == rule_id
        assert rule.severity in ("error", "perf"), rule_id
        assert len(rule.invariant) > 20, rule_id


@pytest.mark.parametrize("module", [sql_parser, sql_binder, sql_printer,
                                    sql_selectivity],
                         ids=lambda m: m.__name__)
def test_sql_frontend_all_matches_public_surface(module):
    assert set(module.__all__) == _public_surface(module)


def test_sql_frontend_doc_covers_every_public_name():
    """docs/sql_frontend.md backticks every public name of the front end
    (parser, binder, printer, selectivity) — grammar, lowering table and
    binder rules must name the code they describe."""
    doc = (DOCS / "sql_frontend.md").read_text()
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", doc))
    surface = (set(sql_parser.__all__) | set(sql_binder.__all__)
               | set(sql_printer.__all__) | set(sql_selectivity.__all__))
    missing = surface - documented
    assert not missing, (
        f"docs/sql_frontend.md is missing {sorted(missing)}")


def test_service_all_matches_public_surface():
    assert set(sql_service.__all__) == _public_surface(sql_service)


def test_serving_doc_covers_every_public_name():
    """docs/serving.md backticks every public service name (plus the
    PlanCache it documents the key discipline of) — the lifecycle
    description must name the code that implements each step."""
    doc = (DOCS / "serving.md").read_text()
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", doc))
    missing = (set(sql_service.__all__) | {"PlanCache"}) - documented
    assert not missing, (
        f"docs/serving.md is missing {sorted(missing)} — every public "
        "service name needs a place in the lifecycle doc")


def test_architecture_links_to_statistics():
    """The architecture page must point readers at the statistics /
    checkpoint-re-optimization page (the PR-10 subsystem doc)."""
    arch = (DOCS / "architecture.md").read_text()
    assert "](statistics.md)" in arch, (
        "docs/architecture.md no longer links to docs/statistics.md")


def test_statistics_doc_covers_the_stats_surface():
    """docs/statistics.md backticks every load-bearing statistics name:
    the shapes, the estimator entry points, and the re-opt machinery."""
    doc = (DOCS / "statistics.md").read_text()
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_.]*)`", doc))
    required = {"ColumnSummary", "ColumnStats", "column_stats_from_summary",
                "build_summary", "merge_summaries", "filter_summary",
                "q_error", "derive_selectivity", "stats_retain_fraction",
                "ReoptDecision", "CardinalityRecord", "R2_REOPT_DISCIPLINE",
                "MCV_TOP_K", "HISTOGRAM_BUCKETS"}
    missing = required - documented
    assert not missing, (
        f"docs/statistics.md is missing {sorted(missing)}")


def test_architecture_links_to_serving():
    """The single-query architecture page must point readers at the
    multi-tenant serving page (and the link must resolve, which
    test_markdown_links_resolve separately enforces)."""
    arch = (DOCS / "architecture.md").read_text()
    assert "](serving.md)" in arch, (
        "docs/architecture.md no longer links to docs/serving.md")


def _markdown_files():
    return [ROOT / "README.md", *sorted(DOCS.glob("*.md"))]


def test_markdown_links_resolve():
    """Every relative markdown link in README.md and docs/*.md points at
    a file that exists (anchors and external URLs are out of scope)."""
    broken = []
    for md in _markdown_files():
        for text, target in re.findall(r"\[([^\]]*)\]\(([^)]+)\)",
                                       md.read_text()):
            target = target.split("#")[0]
            if not target or target.startswith(("http://", "https://")):
                continue
            if not (md.parent / target).exists():
                broken.append(f"{md.name}: [{text}]({target})")
    assert not broken, f"dangling markdown links: {broken}"


def test_documented_repo_paths_exist():
    """Backticked repo paths (src/..., tests/..., benchmarks/..., docs/...)
    quoted in the docs must exist — module renames must update the docs
    in the same PR."""
    pat = re.compile(r"`((?:src|tests|benchmarks|docs|examples)/[\w./-]+)`")
    missing = []
    for md in _markdown_files():
        for path in pat.findall(md.read_text()):
            if not (ROOT / path).exists():
                missing.append(f"{md.name}: {path}")
    assert not missing, f"docs reference nonexistent paths: {missing}"
