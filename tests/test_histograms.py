"""Per-column histogram statistics: distributed-build invariance and
estimator-fraction correctness (``repro.core.stats``).

The contract mirrors the runtime-filter kinds' distributed-equivalence
tests (``test_distributed_filters.py``): a ``ColumnSummary`` is a pure
function of the value *multiset*, so per-partition builds merged in any
order — at any device count — equal the global build exactly, and
``ColumnStats.fraction`` answers every predicate op consistently with the
exact reference ``filter_summary``.
"""

import numpy as np
import pytest

from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st
from repro.core.stats import (HISTOGRAM_BUCKETS, MCV_TOP_K, ColumnStats,
                              ColumnSummary, build_summary,
                              column_stats_from_summary, filter_summary,
                              merge_summaries, q_error, split_summary,
                              summary_from_pairs)


def _values(seed=11, n=4000, domain=300):
    """Zipf-skewed integer column: heavy MCVs + a long uniform tail."""
    rng = np.random.default_rng(seed)
    zipf = np.minimum(rng.zipf(1.3, n // 2), domain)
    tail = rng.integers(1, domain + 1, n - n // 2)
    return np.concatenate([zipf, tail]).astype(np.int64)


# -- build/merge invariance -------------------------------------------------


def test_build_is_order_invariant():
    vals = _values()
    shuffled = vals.copy()
    np.random.default_rng(0).shuffle(shuffled)
    assert build_summary(vals) == build_summary(shuffled)


def test_summary_from_pairs_normalizes_duplicates_and_drops_nonpositive():
    a = summary_from_pairs([3.0, 1.0, 3.0, 2.0], [2.0, 1.0, 5.0, 0.0])
    b = summary_from_pairs([1.0, 3.0], [1.0, 7.0])
    assert a == b
    assert a.values == (1.0, 3.0)
    assert a.counts == (1.0, 7.0)


def test_merge_equals_global_build_any_grouping():
    vals = _values()
    whole = build_summary(vals)
    for cuts in ([1000], [700, 1300, 3999], list(range(500, 4000, 500))):
        parts = [build_summary(chunk)
                 for chunk in np.split(vals, cuts)]
        assert merge_summaries(parts) == whole
        assert merge_summaries(list(reversed(parts))) == whole


@pytest.mark.parametrize("p", [1, 8])
def test_merge_of_split_roundtrips(p):
    """merge(split(h, p)) ≡ h — partition-count invariance {1, 8}, the
    same contract the distributed filter builds pin."""
    whole = build_summary(_values())
    assert merge_summaries(list(split_summary(whole, p))) == whole


def test_merge_is_idempotent_on_singletons():
    whole = build_summary(_values(seed=5, n=512))
    assert merge_summaries([whole]) == whole


# -- finalization determinism ----------------------------------------------


def test_finalize_is_deterministic_and_bounded():
    stats = column_stats_from_summary(build_summary(_values()))
    again = column_stats_from_summary(build_summary(_values()))
    assert stats == again
    assert len(stats.mcv) <= MCV_TOP_K
    assert len(stats.buckets) <= HISTOGRAM_BUCKETS
    # MCVs are the true top-K by count (value tie-break), exact counts.
    vals = _values()
    uniq, counts = np.unique(vals, return_counts=True)
    by_weight = sorted(zip(uniq, counts), key=lambda vc: (-vc[1], vc[0]))
    assert stats.mcv == tuple((float(v), float(c))
                              for v, c in by_weight[:MCV_TOP_K])


def test_buckets_partition_the_non_mcv_mass():
    vals = _values()
    stats = column_stats_from_summary(build_summary(vals))
    mcv_rows = sum(c for _, c in stats.mcv)
    bucket_rows = sum(rows for _, _, rows, _ in stats.buckets)
    assert mcv_rows + bucket_rows == pytest.approx(len(vals))
    # Buckets are ordered, non-overlapping, bounds inclusive.
    for (lo, hi, rows, ndv) in stats.buckets:
        assert lo <= hi and rows > 0 and ndv > 0
    for (_, hi, _, _), (lo2, _, _, _) in zip(stats.buckets,
                                             stats.buckets[1:]):
        assert hi < lo2


# -- empty relation ---------------------------------------------------------


def test_empty_relation_estimates_zero():
    empty = build_summary([])
    assert empty.total == 0.0
    stats = column_stats_from_summary(empty)
    assert stats == ColumnStats(0.0, 0.0, (), (), True)
    for op in ("eq", "ne", "lt", "le", "gt", "ge", "between", "in"):
        assert stats.fraction(op, 1.0, 2.0, (1.0, 2.0)) == 0.0
    assert filter_summary(empty, "le", 10.0).total == 0.0


# -- estimator fractions vs the exact reference -----------------------------


@pytest.mark.parametrize("op,args", [
    ("eq", (17.0, 0.0, ())),
    ("eq", (1.0, 0.0, ())),          # the heaviest MCV — exact hit
    ("ne", (1.0, 0.0, ())),
    ("lt", (40.0, 0.0, ())),
    ("le", (40.0, 0.0, ())),
    ("gt", (200.0, 0.0, ())),
    ("ge", (200.0, 0.0, ())),
    ("between", (25.0, 180.0, ())),
    ("in", (0.0, 0.0, (1.0, 2.0, 999.0))),
])
def test_fraction_tracks_exact_reference(op, args):
    """The histogram's fractional answer stays within a small q-error of
    the exact multiset answer — and is exact for MCV hits."""
    vals = _values()
    summary = build_summary(vals)
    stats = column_stats_from_summary(summary)
    value, value2, values = args
    est = stats.fraction(op, value, value2, values) * summary.total
    exact = filter_summary(summary, op, value, value2, values).total
    assert q_error(est, exact) <= 1.35, (op, args, est, exact)


def test_mcv_point_lookup_is_exact():
    vals = _values()
    summary = build_summary(vals)
    stats = column_stats_from_summary(summary)
    for v, c in stats.mcv:
        assert stats.fraction("eq", v) * summary.total == pytest.approx(c)


def test_integral_rejects_non_integer_point_predicates():
    stats = column_stats_from_summary(build_summary(_values()),
                                      integral=True)
    assert stats.fraction("eq", 17.5) == 0.0
    assert stats.fraction("in", values=(17.5, 0.25)) == 0.0


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=400),
       st.integers(min_value=0, max_value=10_000))
def test_range_fractions_are_monotone_and_clamped(domain, seed):
    """Property: le-fractions are monotone in the threshold and always in
    [0, 1]; complement ops agree (fraction(gt) == 1 - fraction(le))."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, domain + 1, 600)
    stats = column_stats_from_summary(build_summary(vals))
    prev = 0.0
    for cut in np.linspace(0, domain + 1, 9):
        f = stats.fraction("le", float(cut))
        assert 0.0 <= f <= 1.0
        assert f >= prev - 1e-12
        assert stats.fraction("gt", float(cut)) == pytest.approx(1.0 - f)
        prev = f


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=8))
def test_distributed_finalize_equals_global(p):
    """Finalizing the merged per-partition summaries is identical to
    finalizing the global build — stats never depend on row placement."""
    whole = build_summary(_values(seed=23, n=1500, domain=120))
    parts = split_summary(whole, p)
    assert (column_stats_from_summary(merge_summaries(list(parts)))
            == column_stats_from_summary(whole))
