"""Runtime-filter framework: zone-map / semi-join kinds + kind selection.

Covers the three layers the pluggable framework spans:

  * kernels — the tiled min/max reduce (``key_range``) against its numpy
    reference, and the exact distinct-key machinery in ``core.psts``
    (no false positives OR negatives, order/duplication invariance);
  * planner — per-edge kind quoting: zone map only for band-shaped build
    keys, semi-join winning small exact key sets, bloom as the dense
    default, the strict cost gate at sigma = 1, and the ``kinds``
    restriction reproducing bloom-only behaviour;
  * executor — q22 picks zone_map, q23 picks semi_join, both preserve
    results and cut probe-shuffle bytes; plus the aggregate group-key
    sigma regression (filters planned even without header FK metadata).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cost_model import (CostParams, ZONE_MAP_BITS,
                                   cached_filter_cost, semi_join_cost,
                                   zone_map_cost)
from repro.core.psts import distinct_count, key_set, semi_join_mask
from repro.joins.ref import rows_as_set, rows_close
from repro.kernels.zone_map import key_range, key_range_ref, range_probe
from repro.sql import (Executor, FilterCache, FilteredStrategy,
                       RelJoinStrategy, filter_cache_key, filtered_queries,
                       generate, plan_runtime_filters)
from repro.sql.datagen import Catalog
from repro.sql.logical import (Aggregate, Filter, Join, JoinEdge, Project,
                               Scan, key_band_fraction, key_retain_fraction)
from repro.core.stats import TableStats


# ---------------------------------------------------------------------------
# Kernel: tiled min/max reduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,seed", [(1, 0), (7, 1), (64, 2), (1000, 3),
                                    (4096, 4)])
def test_key_range_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(-(1 << 30), 1 << 30, n).astype(np.int32)
    valid = rng.random(n) < 0.6
    got = np.asarray(key_range(jnp.asarray(keys), jnp.asarray(valid)))
    assert (got == key_range_ref(keys, valid)).all()
    # valid=None counts every row
    got_all = np.asarray(key_range(jnp.asarray(keys)))
    assert (got_all == key_range_ref(keys)).all()


def test_key_range_empty_interval_rejects_all():
    """All-invalid build -> empty interval (lo > hi) -> probe keeps none:
    the degenerate-build contract shared with the zero bloom filter."""
    keys = np.arange(100, dtype=np.int32)
    lo_hi = key_range(jnp.asarray(keys), jnp.zeros(100, bool))
    assert int(lo_hi[0]) > int(lo_hi[1])
    mask = np.asarray(range_probe(jnp.asarray(keys), lo_hi))
    assert not mask.any()


def test_range_probe_no_false_negatives():
    """Every build key passes its own zone map; outside keys may pass only
    if they fall inside the band (false positives), never the reverse."""
    rng = np.random.default_rng(7)
    build = rng.integers(100, 200, 500).astype(np.int32)
    lo_hi = key_range(jnp.asarray(build))
    assert np.asarray(range_probe(jnp.asarray(build), lo_hi)).all()
    probe = rng.integers(0, 400, 2000).astype(np.int32)
    mask = np.asarray(range_probe(jnp.asarray(probe), lo_hi))
    inside = (probe >= build.min()) & (probe <= build.max())
    assert (mask == inside).all()


# ---------------------------------------------------------------------------
# Distinct-key machinery (core.psts) / exact semi-join reducer
# ---------------------------------------------------------------------------


def test_key_set_dedup_and_order_invariance():
    rng = np.random.default_rng(0)
    base = rng.integers(-1000, 1000, 300).astype(np.int32)
    dup = np.repeat(base, 3)
    a, na = key_set(jnp.asarray(dup))
    b, nb = key_set(jnp.asarray(rng.permutation(dup)))
    want = np.unique(base)
    assert int(na) == int(nb) == len(want)
    assert (np.asarray(a)[:len(want)] == want).all()
    # Serialized prefix is a pure function of the key *set*.
    assert (np.asarray(a)[:len(want)] == np.asarray(b)[:len(want)]).all()
    assert distinct_count(jnp.asarray(dup)) == len(want)


def test_semi_join_mask_is_exact():
    """No false positives AND no false negatives — the property that
    distinguishes the exact reducer from bloom's fpr floor."""
    rng = np.random.default_rng(1)
    build = rng.integers(0, 500, 120).astype(np.int32)
    valid = rng.random(120) < 0.5
    ks, n = key_set(jnp.asarray(build), jnp.asarray(valid))
    probe = rng.integers(-100, 700, 5000).astype(np.int32)
    mask = np.asarray(semi_join_mask(jnp.asarray(probe), ks, n))
    assert (mask == np.isin(probe, build[valid])).all()


def test_semi_join_mask_empty_build_rejects_all():
    ks, n = key_set(jnp.asarray(np.arange(8, dtype=np.int32)),
                    jnp.zeros(8, bool))
    assert int(n) == 0
    mask = np.asarray(semi_join_mask(jnp.arange(100, dtype=jnp.int32),
                                     ks, n))
    assert not mask.any()


# ---------------------------------------------------------------------------
# Band / key-retain analysis on logical leaves
# ---------------------------------------------------------------------------


def test_key_band_fraction_requires_range_on_key():
    date = Scan("date_dim")
    on_key = Filter(date, "d_date_sk", "lt", 90, selectivity=0.25)
    off_key = Filter(date, "d_month", "eq", 6, selectivity=1 / 12)
    assert key_band_fraction(on_key, "d_date_sk") == pytest.approx(0.25)
    # A predicate on another column does not make the key set a band.
    assert key_band_fraction(off_key, "d_date_sk") is None
    # Stacked: the band tightens only with the key's own predicates.
    both = Filter(on_key, "d_month", "eq", 6, selectivity=1 / 12)
    assert key_band_fraction(both, "d_date_sk") == pytest.approx(0.25)
    # Band analysis descends projections.
    proj = Project(on_key, ("d_date_sk",))
    assert key_band_fraction(proj, "d_date_sk") == pytest.approx(0.25)


def test_key_retain_fraction_sees_through_aggregates():
    """Group keys survive grouping: a filter on the group key below the
    Aggregate still thins the key set the leaf exposes — this is the
    pushdown-through-aggregates sigma fix."""
    agg = Aggregate(Filter(Scan("catalog_sales"), "cs_item_sk", "lt", 200,
                           selectivity=0.1), "cs_item_sk",
                    (("cs_sales_price", "sum"),))
    assert key_retain_fraction(agg, "cs_item_sk") == pytest.approx(0.1)
    # A filter on a non-key column below the aggregate is conservative 1.0.
    agg2 = Aggregate(Filter(Scan("catalog_sales"), "cs_quantity", "lt", 10,
                            selectivity=0.1), "cs_item_sk",
                     (("cs_sales_price", "sum"),))
    assert key_retain_fraction(agg2, "cs_item_sk") == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Planner: per-edge kind selection
# ---------------------------------------------------------------------------


def _stats(size, card):
    return TableStats(float(size), float(card))


_EDGE = [JoinEdge(0, 1, "fk", "pk")]
_PARAMS = CostParams(p=8, w=1.0)


def test_planner_picks_zone_map_for_banded_build():
    probe, build = _stats(1 << 20, 32_768), _stats(2_048, 128)
    leaves = [Scan("fact"),
              Filter(Scan("dim"), "pk", "lt", 128, selectivity=0.25)]
    planned = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.25],
                                   _PARAMS, leaves=leaves)
    assert len(planned) == 1 and planned[0].kind == "zone_map"
    assert planned[0].m_bits == ZONE_MAP_BITS
    assert planned[0].cost == pytest.approx(zone_map_cost(_PARAMS))


def test_planner_picks_semi_join_for_tiny_exact_sets():
    """5 distinct keys: 160 bits exact vs the 256-bit bloom minimum."""
    probe, build = _stats(1 << 20, 32_768), _stats(80, 5)
    leaves = [Scan("fact"),
              Filter(Scan("dim"), "payload", "eq", 0, selectivity=0.08)]
    planned = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.08],
                                   _PARAMS, leaves=leaves)
    assert len(planned) == 1 and planned[0].kind == "semi_join"
    assert planned[0].cost == pytest.approx(semi_join_cost(5, _PARAMS))
    assert planned[0].keep_est == pytest.approx(0.08)


def test_planner_defaults_to_bloom_for_large_scattered_sets():
    probe, build = _stats(1 << 20, 32_768), _stats(1 << 14, 1_024)
    leaves = [Scan("fact"),
              Filter(Scan("dim"), "payload", "lt", 1, selectivity=0.1)]
    planned = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.1],
                                   _PARAMS, leaves=leaves)
    assert len(planned) == 1 and planned[0].kind == "bloom"


def test_planner_kind_restriction_reproduces_bloom_only():
    probe, build = _stats(1 << 20, 32_768), _stats(2_048, 128)
    leaves = [Scan("fact"),
              Filter(Scan("dim"), "pk", "lt", 128, selectivity=0.25)]
    planned = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.25],
                                   _PARAMS, leaves=leaves, kinds=("bloom",))
    assert len(planned) == 1 and planned[0].kind == "bloom"


def test_planner_plans_nothing_at_sigma_one_for_every_kind():
    """The parity guarantee generalizes: an unfiltered build offers no
    kind anything to cut (the banded case keeps band >= sigma = 1)."""
    probe, build = _stats(1 << 20, 32_768), _stats(1 << 14, 1_024)
    leaves = [Scan("fact"), Scan("dim")]
    assert plan_runtime_filters(_EDGE, [probe, build], [1.0, 1.0],
                                _PARAMS, leaves=leaves) == []


# ---------------------------------------------------------------------------
# Executor: end-to-end kind selection on q22/q23
# ---------------------------------------------------------------------------


def _rows(res):
    return rows_as_set(res.table.to_numpy())


def test_q22_selects_zone_map(catalog):
    plan = filtered_queries()["q22_zone_map_window"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert [f.plan.kind for f in filt.filters] == ["zone_map"]
    assert rows_close(_rows(filt), _rows(base))
    assert filt.probe_shuffle_bytes < 0.5 * base.probe_shuffle_bytes
    # The zone map's wire size undercuts any bloom array by construction.
    assert filt.filters[0].plan.m_bits == ZONE_MAP_BITS


def test_q23_selects_semi_join(catalog):
    plan = filtered_queries()["q23_semi_join_stores"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert [f.plan.kind for f in filt.filters] == ["semi_join"]
    assert rows_close(_rows(filt), _rows(base))
    assert filt.probe_shuffle_bytes < 0.5 * base.probe_shuffle_bytes
    # Exact reducer: measured keep equals the true match fraction, no
    # false-positive slack on top.
    f = filt.filters[0]
    assert f.rows_after <= f.rows_before


def test_bloom_only_configuration_still_filters(catalog):
    """kinds=("bloom",) reproduces PR-3 behaviour on the new queries: a
    bloom filter is planned (it still beats no filter), just not the
    cheaper specialized kind."""
    plan = filtered_queries()["q22_zone_map_window"]
    filt = Executor(catalog,
                    FilteredStrategy(kinds=("bloom",))).execute(plan)
    assert [f.plan.kind for f in filt.filters] == ["bloom"]


# ---------------------------------------------------------------------------
# Cross-query filter cache: key normalization + hit/miss/invalidation
# ---------------------------------------------------------------------------


def test_filter_cache_key_normalizes_predicate_order():
    """Conjunctive filters commute, so stacking order must not split the
    cache: F1(F2(scan)) and F2(F1(scan)) share an entry. Projections are
    transparent (they never change the key column's values)."""
    f1 = dict(column="d_month", op="eq", value=6.0, selectivity=1 / 12)
    f2 = dict(column="d_date_sk", op="lt", value=90.0, selectivity=0.25)
    a = Filter(Filter(Scan("date_dim"), **f1), **f2)
    b = Filter(Filter(Scan("date_dim"), **f2), **f1)
    ka = filter_cache_key(a, "d_date_sk", "bloom", 1024, 7)
    kb = filter_cache_key(b, "d_date_sk", "bloom", 1024, 7)
    assert ka is not None and ka == kb
    proj = Project(a, ("d_date_sk",))
    assert filter_cache_key(proj, "d_date_sk", "bloom", 1024, 7) == ka
    # Different kind / size params are different payloads.
    assert filter_cache_key(a, "d_date_sk", "zone_map", 64, 0) != ka
    assert filter_cache_key(a, "d_date_sk", "bloom", 2048, 7) != ka


def test_filter_cache_key_rejects_non_scan_leaves():
    """Aggregated subqueries' key sets depend on subtree execution — the
    normalization does not capture that, so they are uncacheable."""
    agg = Aggregate(Scan("catalog_sales"), "cs_item_sk",
                    (("cs_sales_price", "sum"),))
    assert filter_cache_key(agg, "cs_item_sk", "bloom", 1024, 7) is None


def test_planner_quotes_cache_hits_without_build_terms():
    """A cached kind is quoted at cached_filter_cost (broadcast only);
    with an empty cache the quote — and the planned filter — is
    byte-identical to the uncached planner's."""
    probe, build = _stats(1 << 20, 32_768), _stats(2_048, 128)
    leaves = [Scan("fact"),
              Filter(Scan("dim"), "pk", "lt", 128, selectivity=0.25)]
    cold = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.25],
                                _PARAMS, leaves=leaves, cache=FilterCache())
    bare = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.25],
                                _PARAMS, leaves=leaves)
    assert cold == bare and not cold[0].cached
    cache = FilterCache()
    rf = cold[0]
    cache.store(filter_cache_key(leaves[1], rf.build_key, rf.kind,
                                 rf.m_bits, rf.k),
                payload="sentinel", build_stats=build)
    warm = plan_runtime_filters(_EDGE, [probe, build], [1.0, 0.25],
                                _PARAMS, leaves=leaves, cache=cache)
    assert warm[0].cached
    assert warm[0].cost == pytest.approx(
        cached_filter_cost(rf.m_bits, _PARAMS))
    assert warm[0].cost < rf.cost


def test_executor_cache_hit_miss_and_zero_rebuild(catalog):
    """End to end: the first run misses and populates, the repeat run
    reuses every payload (zero reduce bytes) with identical results."""
    plan = filtered_queries()["q19_filtered_customer"]
    cache = FilterCache()
    strat = FilteredStrategy(cache=cache)
    cold = Executor(catalog, strat).execute(plan)
    assert cold.filters and cold.cached_filters == 0
    assert cache.misses == len(cold.filters) and cache.hits == 0
    assert cold.filter_reduce_bytes > 0
    warm = Executor(catalog, strat).execute(plan)
    assert warm.cached_filters == len(warm.filters) == len(cold.filters)
    assert warm.filter_reduce_bytes == 0.0
    assert cache.hits == len(warm.filters)
    assert rows_close(_rows(warm), _rows(cold))
    # Every stored payload carries the measured build-side stats.
    stored = [cache.build_stats(k) for k in cache._entries]
    assert stored and all(s is not None and s.cardinality > 0
                          for s in stored)
    assert cache.build_stats(None) is None  # uncacheable key -> no stats


def test_filter_cache_invalidates_on_catalog_change(catalog):
    """Payloads built against one catalog version must never filter
    another: regenerated data invalidates every entry."""
    plan = filtered_queries()["q19_filtered_customer"]
    cache = FilterCache()
    strat = FilteredStrategy(cache=cache)
    Executor(catalog, strat).execute(plan)
    assert len(cache) > 0
    other = generate(scale=0.1, p=4, seed=43)
    res = Executor(other, strat).execute(plan)
    assert cache.invalidations == 1
    assert res.cached_filters == 0          # nothing stale was reused
    # Back on the original catalog: the entries built against it are gone
    # too (validity is a binding, not a per-catalog pool).
    res2 = Executor(catalog, strat).execute(plan)
    assert res2.cached_filters == 0 and cache.invalidations == 2


def test_two_catalogs_sharing_a_version_never_share_payloads(catalog):
    """Regression: ``FilterCache.sync`` used to bind by version integer
    alone, so two distinct Catalog instances that happened to share a
    version number silently reused each other's payloads — wrong rows
    (a payload filters against the *other* catalog's customer data), not
    just a perf miss. The binding is now the full identity fingerprint
    (version + generation uid), so a forced version collision must still
    invalidate."""
    plan = filtered_queries()["q19_filtered_customer"]
    cache = FilterCache()
    strat = FilteredStrategy(cache=cache)
    Executor(catalog, strat).execute(plan)
    assert len(cache) > 0
    other = generate(scale=0.1, p=4, seed=43)
    other.version = catalog.version     # version collision, different data
    assert other.uid != catalog.uid
    base = Executor(other, RelJoinStrategy()).execute(plan)
    res = Executor(other, strat).execute(plan)
    assert cache.invalidations == 1     # uid mismatch invalidated
    assert res.cached_filters == 0      # nothing foreign was reused
    assert rows_close(_rows(res), _rows(base))


def test_masked_build_side_is_not_cached(catalog):
    """A payload built from a build table that was itself masked by
    another runtime filter of the same query must NOT be stored under
    the chain-only cache key: a later query reusing it would drop rows
    that only the first query's extra join excludes (false negatives).

    Snowflake shape: household's zone map masks customer first, then the
    fact<-customer bloom is built from the *masked* customer — that
    second payload is the poisoned one."""
    cust = Filter(Scan("customer"), "c_region", "eq", 3, selectivity=0.125)
    hh = Filter(Scan("household"), "hd_demo_sk", "lt", 300,
                selectivity=0.1)
    snowflake = Join(Scan("store_sales"),
                     Join(cust, hh, "c_hdemo_sk", "hd_demo_sk"),
                     "ss_customer_sk", "c_customer_sk")
    two_way = Join(Scan("store_sales"), cust,
                   "ss_customer_sk", "c_customer_sk")
    cache = FilterCache()
    strat = FilteredStrategy(cache=cache)
    res1 = Executor(catalog, strat).execute(snowflake)
    # The scenario is real: both filters planned, customer masked before
    # the fact<-customer payload is built from it.
    assert len(res1.filters) == 2
    assert [f.plan.build_key for f in res1.filters] == ["hd_demo_sk",
                                                        "c_customer_sk"]
    # Only household's (clean) payload may be stored.
    assert len(cache) == 1
    # The two-way query must rebuild customer's filter from its true
    # static chain and produce exactly the uncached result.
    base = Executor(catalog, RelJoinStrategy()).execute(two_way)
    res2 = Executor(catalog, strat).execute(two_way)
    assert res2.filters and all(not f.cached for f in res2.filters)
    assert rows_close(_rows(res2), _rows(base))


def test_cold_cache_selections_identical_to_uncached(catalog):
    """The cold-cache byte-identity claim, end to end on q19-q23: an
    empty cache changes no quote, no kind, no method selection."""
    for qname, plan in filtered_queries().items():
        bare = Executor(catalog, FilteredStrategy()).execute(plan)
        cold = Executor(catalog, FilteredStrategy(cache=FilterCache())
                        ).execute(plan)
        assert [f.plan for f in cold.filters] == [f.plan for f in
                                                  bare.filters], qname
        assert cold.methods() == bare.methods(), qname


# ---------------------------------------------------------------------------
# Regression: filter pushdown through aggregates (sigma estimation)
# ---------------------------------------------------------------------------


def test_aggregate_group_key_filter_plans_without_key_metadata(catalog):
    """A filter below an Aggregate on its group key must still yield a
    runtime filter when the catalog has no header FK metadata for the key
    (derived/external sources): sigma comes from the key-aware retain
    fraction, which sees through the grouping. Before the fix this fell
    back to sigma = 1.0 and nothing was planned."""
    nometa = Catalog(catalog.tables, catalog.p,
                     {k: v for k, v in catalog.key_domains.items()
                      if k != "cs_item_sk"})
    leaf = Aggregate(Filter(Scan("catalog_sales"), "cs_item_sk", "lt", 200,
                            selectivity=0.1), "cs_item_sk",
                     (("cs_sales_price", "sum"),))
    plan = Aggregate(Join(Scan("store_sales"), leaf, "ss_item_sk",
                          "cs_item_sk"),
                     "ss_store_sk", (("ss_sales_price", "sum"),))
    base = Executor(nometa, RelJoinStrategy()).execute(plan)
    filt = Executor(nometa, FilteredStrategy()).execute(plan)
    assert filt.filters, "group-key filter below aggregate was not planned"
    assert filt.filters[0].plan.sigma_est == pytest.approx(0.1)
    assert rows_close(_rows(filt), _rows(base))
