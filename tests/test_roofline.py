"""Roofline machinery tests: HLO collective parser on synthetic and real
modules, term arithmetic, and the model-FLOPs accounting."""

import jax
import pytest

from repro.configs import get_config
from repro.launch.roofline import (Roofline, model_flops,
                                   parse_collective_bytes)
from repro.models.config import SHAPE_BY_NAME

SYNTH = """
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024] %x), replica_groups={{0,1,2,3}}
  %ag = bf16[64,512]{1,0} all-gather(bf16[16,512] %y), replica_groups={{0,1,2,3}}
  %rs = f32[4,256]{1,0} reduce-scatter(f32[16,256] %z), replica_groups={{0,1,2,3}}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8] %w), source_target_pairs={{0,1}}
  %aa = bf16[32,32]{1,0} all-to-all(bf16[32,32] %v), replica_groups={{0,1,2,3}}
"""


def test_parser_synthetic_module():
    out = parse_collective_bytes(SYNTH, n_devices=4)
    ring = 3 / 4
    assert out["all-reduce"] == pytest.approx(2 * 16 * 1024 * 4 * ring)
    assert out["all-gather"] == pytest.approx(64 * 512 * 2 * ring)
    assert out["reduce-scatter"] == pytest.approx(4 * 256 * 4 * 3)
    assert out["collective-permute"] == pytest.approx(8 * 8 * 4)
    assert out["all-to-all"] == pytest.approx(32 * 32 * 2 * ring)


def test_parser_ignores_non_collectives():
    txt = "%d = f32[128,128]{1,0} dot(f32[128,128] %a, f32[128,128] %b)"
    assert parse_collective_bytes(txt, 4) == {}


def test_parser_on_real_compiled_module():
    """Compile a sharded psum on host devices and find its all-reduce."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under forced host platform)")


def test_roofline_terms_arithmetic():
    r = Roofline(flops=197e12 * 10, hbm_bytes=819e9, collective_bytes=50e9,
                 chips=10, per_collective={})
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.1)
    assert r.collective_s == pytest.approx(0.1)
    assert r.bound == "compute"
    assert r.step_time_s() == pytest.approx(1.0)


def test_model_flops_dense_vs_moe():
    dense = get_config("granite_8b")
    moe = get_config("qwen3_moe_235b_a22b")
    shape = SHAPE_BY_NAME["train_4k"]
    fd = model_flops(dense, shape)
    # 6 * N * D within 5%
    n = dense.param_count()
    assert fd == pytest.approx(6 * n * 4096 * 256, rel=1e-6)
    # MoE counts ACTIVE params only
    fm = model_flops(moe, shape)
    assert fm < 6 * moe.param_count() * 4096 * 256 * 0.25


def test_decode_flops_per_token():
    cfg = get_config("tinyllama_1_1b")
    shape = SHAPE_BY_NAME["decode_32k"]
    f = model_flops(cfg, shape)
    assert f == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
