"""Serving engine tests: continuous batching, request lifecycle, admission
ordering, and the adaptive re-planning hook."""

import collections
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.relshard import plan_model
from repro.models import lm
from repro.models.config import ShapeConfig
from repro.serving.engine import Request, ServeEngine

MESH1 = (("data", 1), ("model", 1))


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(get_smoke_config("tinyllama_1_1b"),
                              n_layers=2, d_model=64, d_ff=128, vocab=128)
    shape = ShapeConfig("serve", 64, 4, "decode")
    plan = plan_model(cfg, MESH1, shape, fsdp=False)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, plan, None, params, max_batch=4, max_seq=64,
                       mesh_axes=MESH1, shape=shape)


def test_requests_complete(engine):
    for rid in range(6):
        engine.submit(Request(rid, prompt=[1 + rid, 2], max_new_tokens=5))
    reqs = list(engine.queue)
    steps = 0
    while (engine.queue or engine.occupancy()) and steps < 500:
        engine.step()
        steps += 1
    assert steps < 500
    for r in reqs:
        assert r.done and len(r.out) == 5
        assert all(0 <= t < 128 for t in r.out)


def test_continuous_batching_overlaps(engine):
    """More requests than slots: the engine must interleave, never exceed
    max_batch occupancy, and still finish everything."""
    reqs = [Request(100 + i, prompt=[3, 4], max_new_tokens=3)
            for i in range(9)]
    for r in reqs:
        engine.submit(r)
    max_occ = 0
    steps = 0
    while (engine.queue or engine.occupancy()) and steps < 500:
        engine.step()
        max_occ = max(max_occ, engine.occupancy())
        steps += 1
    assert max_occ <= 4
    assert all(r.done for r in reqs)


def test_many_request_admission_order(engine):
    """A deep backlog admits strictly in submission order (FIFO): with 4
    slots and 3-token outputs, slot grants happen in waves, and every wave
    must take the oldest queued requests. The queue is a deque — popleft
    admission is O(1), so a deep backlog drains without the quadratic
    list.pop(0) scan this regression-tests against."""
    assert isinstance(engine.queue, collections.deque)
    reqs = [Request(200 + i, prompt=[2], max_new_tokens=3)
            for i in range(25)]
    for r in reqs:
        engine.submit(r)
    admitted = []
    seen = set()
    steps = 0
    while (engine.queue or engine.occupancy()) and steps < 500:
        engine.step()
        for slot in engine.slots:
            if slot is not None and slot.rid not in seen:
                seen.add(slot.rid)
                admitted.append(slot.rid)
        steps += 1
    assert all(r.done for r in reqs)
    # Every request not yet observed in a slot was admitted+completed
    # within one step window; the observed admission order must still be
    # a subsequence-consistent FIFO: sorted ascending by submission.
    assert admitted == sorted(admitted)


def test_maybe_replan_returns_plan_or_none(engine):
    engine.submit(Request(999, prompt=[5], max_new_tokens=2))
    engine.step()
    out = engine.maybe_replan()
    assert out is None or out.embed_strategy in ("replicate",
                                                 "vocab_parallel")
