"""Planner tests: DP optimality vs exhaustive enumeration, rewrite
correctness (results preserved on the whole query suite), the mis-ordered
queries' modeled-workload wins, and no suite-level network regression with
reordering enabled."""

import itertools

import pytest

from repro.core.cost_model import CostParams
from repro.core.stats import TableStats
from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (Aggregate, Executor, Filter, Join, RelJoinStrategy,
                       ReorderingStrategy, Scan, all_queries, every_query,
                       extract_join_graph, misordered_queries, optimize)
from repro.sql.logical import JoinEdge, augment_edges, leaf_retain_fraction
from repro.sql.planner import (catalog_schema, enumerate_join_order,
                               estimate_leaf_stats, modeled_tree_cost, _step)

P = CostParams(p=8, w=1.0)


# ---------------------------------------------------------------------------
# DP vs exhaustive enumeration (<= 4 relations)
# ---------------------------------------------------------------------------

def _stats(size_kb, card):
    return TableStats(size_kb * 1024.0, card)


def _exhaustive_best(stats, retain, edges, params):
    """Brute-force the cheapest feasible left-deep order."""
    from repro.core.stats import estimate_join
    n = len(stats)
    best = None
    for perm in itertools.permutations(range(n)):
        cur, cost, ok = stats[perm[0]], 0.0, True
        joined = {perm[0]}
        for r in perm[1:]:
            if not any(e.build == r and e.probe in joined for e in edges):
                ok = False
                break
            _, c = _step(cur, stats[r], params)
            cost += c
            cur = estimate_join(cur, stats[r], fk_selectivity=retain[r])
            joined.add(r)
        if ok and (best is None or cost < best):
            best = cost
    return best


GRAPHS = {
    # chain: 0 -> 1 -> ... (probe 0 joins dims 1..k in any feasible order)
    "star3": ([_stats(4000, 50_000), _stats(40, 500), _stats(400, 5_000)],
              [1.0, 0.2, 1.0],
              [JoinEdge(0, 1, "k1", "pk1"), JoinEdge(0, 2, "k2", "pk2")]),
    "star4": ([_stats(8000, 100_000), _stats(30, 400), _stats(900, 9_000),
               _stats(90, 1_000)],
              [1.0, 0.05, 1.0, 0.5],
              [JoinEdge(0, 1, "a", "pa"), JoinEdge(0, 2, "b", "pb"),
               JoinEdge(0, 3, "c", "pc")]),
    "chain4": ([_stats(6000, 60_000), _stats(600, 6_000), _stats(60, 600),
                _stats(6, 60)],
               [1.0, 1.0, 0.3, 1.0],
               [JoinEdge(0, 1, "x", "px"), JoinEdge(1, 2, "y", "py"),
                JoinEdge(2, 3, "z", "pz")]),
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_dp_matches_exhaustive(gname):
    stats, retain, edges = GRAPHS[gname]
    order = enumerate_join_order(stats, retain, edges, P)
    assert order is not None
    brute = _exhaustive_best(stats, retain, edges, P)
    assert order.cost == pytest.approx(brute)
    # the order is complete and starts from a feasible probe root
    assert sorted(order.order()) == list(range(len(stats)))


def test_dp_respects_orientation():
    """A leaf that is only ever a probe can never be added as build side."""
    stats = [_stats(100, 1000), _stats(10, 100)]
    edges = [JoinEdge(0, 1, "k", "pk")]
    order = enumerate_join_order(stats, [1.0, 1.0], edges, P, start=1)
    assert order is None  # cannot start from the build-only leaf


def test_dp_bushy_no_worse_than_left_deep():
    for gname in sorted(GRAPHS):
        stats, retain, edges = GRAPHS[gname]
        ld = enumerate_join_order(stats, retain, edges, P)
        bushy = enumerate_join_order(stats, retain, edges, P, bushy=True)
        assert bushy.cost <= ld.cost + 1e-9


# ---------------------------------------------------------------------------
# Rewrites preserve results on the whole suite
# ---------------------------------------------------------------------------

def _result_rows(res):
    return rows_as_set(res.table.to_numpy())


@pytest.mark.parametrize("qname", sorted(every_query()))
def test_optimized_plans_preserve_results(catalog, qname):
    """Pushdown + pruning + reordering never change query results (row
    count + per-row checksum vs the unoptimized execution)."""
    plan = every_query()[qname]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    opt = Executor(catalog,
                   ReorderingStrategy(RelJoinStrategy())).execute(plan)
    assert base.rows == opt.rows
    assert rows_close(_result_rows(opt), _result_rows(base)), qname


def test_pushdown_prune_only_preserve_results(catalog):
    """The pure logical rewrites (no reordering) also preserve results."""
    for qname in ("q1_star3", "q7_filtered_fact", "q15_late_filter"):
        plan = every_query()[qname]
        base = Executor(catalog, RelJoinStrategy()).execute(plan)
        res = optimize(plan, catalog, reorder=False)
        opt = Executor(catalog, RelJoinStrategy()).execute(res.plan)
        assert rows_close(_result_rows(opt), _result_rows(base)), qname


# ---------------------------------------------------------------------------
# The mis-ordered queries: strict modeled-workload wins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", sorted(misordered_queries()))
def test_misordered_queries_strictly_improved(catalog, qname):
    res = optimize(misordered_queries()[qname], catalog)
    assert res.reordered, qname
    assert res.chosen_cost < res.plan_order_cost, qname


def test_modeled_tree_cost_matches_region(catalog):
    """Plan-order modeled cost is reproducible from the extracted graph."""
    schema = catalog_schema(catalog)
    plan = misordered_queries()["q14_big_dim_first"]
    graph = extract_join_graph(plan.child, schema)
    assert graph is not None and graph.n == 4
    base = {name: t.measure() for name, t in catalog.tables.items()}
    stats = [estimate_leaf_stats(l, base, schema) for l in graph.leaves]
    retain = [leaf_retain_fraction(l) for l in graph.leaves]
    plan_cost = modeled_tree_cost(graph, stats, retain, P)
    dp = enumerate_join_order(stats, retain, augment_edges(graph), P)
    assert dp.cost < plan_cost


# ---------------------------------------------------------------------------
# No suite-level regression with reordering enabled
# ---------------------------------------------------------------------------

def test_reordering_does_not_regress_suite_network(catalog):
    """Total executed network bytes over the 12 baseline queries must not
    increase when reordering is enabled (per-query shifts between network
    and local workload are allowed — the model optimizes their w-sum)."""
    plain = re = 0.0
    for qname, plan in all_queries().items():
        plain += Executor(catalog, RelJoinStrategy()
                          ).execute(plan).network_bytes
        re += Executor(catalog, ReorderingStrategy(RelJoinStrategy())
                       ).execute(plan).network_bytes
    assert re <= plain * 1.001


def test_misordered_queries_network_improves(catalog):
    """On the deliberately mis-ordered queries the win must be large."""
    for qname, plan in misordered_queries().items():
        plain = Executor(catalog, RelJoinStrategy()).execute(plan)
        re = Executor(catalog,
                      ReorderingStrategy(RelJoinStrategy())).execute(plan)
        assert re.network_bytes < plain.network_bytes, qname


# ---------------------------------------------------------------------------
# Edge cases: single join, tie-breaking determinism, empty intermediates
# ---------------------------------------------------------------------------

def test_single_join_query_not_reordered(catalog):
    """A 2-relation region has nothing to reorder: optimize() must report no
    region decision and the reordering executor must match the plain one."""
    plan = Aggregate(Join(Scan("store_sales"), Scan("item"),
                          "ss_item_sk", "i_item_sk"),
                     "i_brand", (("ss_sales_price", "sum"),))
    res = optimize(plan, catalog)
    assert res.regions == [] and not res.reordered
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    opt = Executor(catalog, ReorderingStrategy(RelJoinStrategy())
                   ).execute(plan)
    assert base.rows == opt.rows
    # Exactly one join either way (no region splitting/ordering artifacts);
    # the *method* may differ — pruning narrows rows, moving k vs k0.
    assert len(base.decisions) == len(opt.decisions) == 1
    assert rows_close(_result_rows(opt), _result_rows(base))


def test_dp_tie_break_deterministic():
    """When every candidate order costs the same (identical dimensions),
    the DP must keep the first-found state — repeated enumerations return
    the identical order, never a cost-equal sibling."""
    stats = [_stats(4000, 50_000)] + [_stats(40, 500)] * 3
    retain = [1.0, 1.0, 1.0, 1.0]
    edges = [JoinEdge(0, i, f"k{i}", f"pk{i}") for i in (1, 2, 3)]
    first = enumerate_join_order(stats, retain, edges, P)
    assert first is not None
    for _ in range(3):
        again = enumerate_join_order(stats, list(retain), list(edges), P)
        assert again.order() == first.order()
        assert again.cost == first.cost
    # strict-improvement updates keep the lexicographically first extension
    assert first.order() == [0, 1, 2, 3]


def test_replanning_with_empty_intermediate(catalog):
    """Adaptive re-planning must survive a mid-pipeline empty intermediate:
    a predicate selecting nothing empties the region after its first join;
    every remaining step then re-enumerates with zero-row statistics."""
    j = Join(Scan("store_sales"),
             Filter(Scan("date_dim"), "d_year", "eq", 1900,
                    selectivity=0.01),  # no 1900 dates exist -> 0 rows
             "ss_sold_date_sk", "d_date_sk")
    j = Join(j, Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("store"), "ss_store_sk", "s_store_sk")
    plan = Aggregate(j, "c_region", (("ss_net_profit", "sum"),))
    for strat in (RelJoinStrategy(), ReorderingStrategy(RelJoinStrategy())):
        res = Executor(catalog, strat).execute(plan)
        assert res.rows == 0, strat.name
        assert len(res.decisions) == 3, strat.name
