"""Plan-lint tests: one deliberate corruption per rule (each must trip
exactly its named rule), the elision-aware cost-model fix the analyzer's
E2 rule pinned, and clean gated passes over the golden query suite."""

import dataclasses

import pytest

from repro.core.cost_model import CostParams, JoinMethod
from repro.core.selection import (JoinProperties, JoinType, Selection,
                                  select_join_method)
from repro.core.stats import TableStats
from repro.joins.exchange import ExchangeReport
from repro.joins.methods import JoinReport
from repro.sql import (Executor, FilterCache, FilteredStrategy,
                       PlanVerificationError, RelJoinStrategy,
                       ReorderingStrategy, SkewAwareStrategy, all_queries,
                       analyze_plan, every_query, filtered_queries, optimize,
                       skewed_queries, verify_execution)
from repro.sql.logical import (Aggregate, Filter, Join, JoinEdge, Project,
                               RuntimeFilter, Scan)
from repro.sql.executor import ReoptDecision
from repro.sql.plan_analysis import (RULES, audit_exchanges,
                                     audit_join_decision, audit_selection,
                                     catalog_dtypes, check_cache_reuse,
                                     check_cache_store,
                                     check_filter_placement,
                                     check_filter_quote, check_replan_step,
                                     check_reopt_decision,
                                     check_schema_preserved,
                                     infer_properties)
from repro.sql.planner import JoinStep, catalog_schema

PARAMS = CostParams(p=4, w=1.0)


def _rules(violations):
    return {v.rule for v in violations}


def _stats(size, card, skew=1.0):
    return TableStats(float(size), float(card)).with_skew(skew)


def _rf(keep_est=0.2, benefit=1e6, cost=1e3, kind="bloom"):
    return RuntimeFilter(0, 1, "fk", "pk", m_bits=1 << 13, k=4,
                         sigma_est=0.2, keep_est=keep_est, benefit=benefit,
                         cost=cost, kind=kind)


def _shuffle_report(elided_left=False, elided_right=False):
    ex = lambda e: ExchangeReport("shuffle", 0.0 if e else 1000.0, 0.0,
                                  elided=e)
    return JoinReport(JoinMethod.SHUFFLE_HASH,
                      [ex(elided_left), ex(elided_right)], 0.0, 0)


# ---------------------------------------------------------------------------
# Mutation tests: one corruption per rule, each trips exactly that rule.
# ---------------------------------------------------------------------------


def test_p1_unknown_column(catalog):
    schema = catalog_schema(catalog)
    plan = Filter(Scan("item"), "no_such_column", "eq", 1)
    assert _rules(analyze_plan(plan, schema)) == {"P1_UNKNOWN_COLUMN"}
    plan = Join(Scan("store_sales"), Scan("item"), "ss_item_sk",
                "no_such_key")
    assert _rules(analyze_plan(plan, schema)) == {"P1_UNKNOWN_COLUMN"}
    assert _rules(analyze_plan(Scan("no_such_table"),
                               schema)) == {"P1_UNKNOWN_COLUMN"}


def test_p2_schema_changed(catalog):
    schema = catalog_schema(catalog)
    before = Join(Scan("store_sales"), Scan("item"), "ss_item_sk",
                  "i_item_sk")
    # A rewrite that silently drops output columns must trip P2.
    after = Project(before, ("ss_item_sk", "i_brand"))
    assert _rules(check_schema_preserved(before, after,
                                         schema)) == {"P2_OUTPUT_SCHEMA_CHANGED"}
    assert check_schema_preserved(before, before, schema) == []


def test_p3_key_dtype_mismatch(catalog):
    schema = catalog_schema(catalog)
    dtypes = catalog_dtypes(catalog)
    # float sales price against an int item surrogate key.
    plan = Join(Scan("store_sales"), Scan("item"), "ss_sales_price",
                "i_item_sk")
    assert _rules(analyze_plan(plan, schema,
                               dtypes)) == {"P3_KEY_DTYPE_MISMATCH"}
    # Without dtype information the rule cannot fire (schema-only callers).
    assert analyze_plan(plan, schema) == []


def test_p4_bad_agg_op(catalog):
    schema = catalog_schema(catalog)
    plan = Aggregate(Scan("item"), "i_brand", (("i_price", "median"),))
    assert _rules(analyze_plan(plan, schema)) == {"P4_BAD_AGG_OP"}


def test_e1_missing_exchange():
    sel = Selection(JoinMethod.SHUFFLE_HASH, "test", 1.0,
                    {JoinMethod.SHUFFLE_HASH: 1.0})
    # Probe shuffle elided without a proven hash-on-key distribution.
    vs = audit_exchanges(sel, JoinProperties(), _shuffle_report(True, False))
    assert _rules(vs) == {"E1_MISSING_EXCHANGE"}
    # A broadcast exchange is never elidable, proven flags or not.
    bsel = Selection(JoinMethod.BROADCAST_HASH, "test", 1.0,
                     {JoinMethod.BROADCAST_HASH: 1.0})
    brep = JoinReport(JoinMethod.BROADCAST_HASH,
                      [ExchangeReport("broadcast", 0.0, 0.0, elided=True)],
                      0.0, 0)
    vs = audit_exchanges(bsel, JoinProperties(right_partitioned=True), brep)
    assert _rules(vs) == {"E1_MISSING_EXCHANGE"}


def test_e2_redundant_exchange():
    sel = Selection(JoinMethod.SHUFFLE_HASH, "test", 1.0,
                    {JoinMethod.SHUFFLE_HASH: 1.0})
    # Build side proven partitioned on its key, yet re-shuffled: the
    # redundant exchange the cost model used to re-pay.
    vs = audit_exchanges(sel, JoinProperties(right_partitioned=True),
                         _shuffle_report(False, False))
    assert _rules(vs) == {"E2_REDUNDANT_EXCHANGE"}
    assert audit_exchanges(sel, JoinProperties(right_partitioned=True),
                           _shuffle_report(False, True)) == []


def test_f1_filter_unsafe_join_type():
    rf = _rf()
    assert check_filter_placement(rf, JoinType.INNER) == []
    assert check_filter_placement(rf, JoinType.LEFT_SEMI) == []
    # LEFT_OUTER is only safe via the padding path.
    assert _rules(check_filter_placement(
        rf, JoinType.LEFT_OUTER)) == {"F1_FILTER_UNSAFE_JOIN_TYPE"}
    assert check_filter_placement(rf, JoinType.LEFT_OUTER, padded=True) == []
    # LEFT_ANTI drops exactly the kept rows — never safe, padded or not.
    assert _rules(check_filter_placement(
        rf, JoinType.LEFT_ANTI,
        padded=True)) == {"F1_FILTER_UNSAFE_JOIN_TYPE"}


def test_f2_filter_not_cheaper():
    assert check_filter_quote(_rf()) == []
    assert _rules(check_filter_quote(
        _rf(keep_est=1.0))) == {"F2_FILTER_NOT_CHEAPER"}
    assert _rules(check_filter_quote(
        _rf(benefit=10.0, cost=10.0))) == {"F2_FILTER_NOT_CHEAPER"}


def test_f3_cache_chain_mismatch():
    base = ("item", (("i_category", "lt", 3.0, 0.0),))
    wider = ("item", ())
    assert check_cache_reuse(base, base) == []
    # Stored subset of the edge chain: payload is a key superset — safe.
    assert check_cache_reuse(wider, base) == []
    # Stored chain has a predicate the edge lacks: payload may miss keys.
    assert _rules(check_cache_reuse(base,
                                    wider)) == {"F3_CACHE_CHAIN_MISMATCH"}
    assert _rules(check_cache_reuse(
        base, ("store", ()))) == {"F3_CACHE_CHAIN_MISMATCH"}
    assert _rules(check_cache_reuse(None, base)) == {"F3_CACHE_CHAIN_MISMATCH"}
    # Store side: a masked build's payload must not enter the cache.
    assert check_cache_store(base, build_masked=False) == []
    assert _rules(check_cache_store(
        base, build_masked=True)) == {"F3_CACHE_CHAIN_MISMATCH"}


def test_s1_salt_unreplicable_build():
    sel = Selection(JoinMethod.SALTED_SHUFFLE_HASH, "test", 1.0, {},
                    swapped_sides=True, salt_r=4)
    vs = audit_selection(sel, _stats(1000, 100), _stats(2000, 200),
                         JoinProperties(), PARAMS)
    assert _rules(vs) == {"S1_SALT_UNREPLICABLE_BUILD"}


def test_c1_negative_cost_term():
    sel = Selection(JoinMethod.SHUFFLE_HASH, "test", 1.0, {})
    vs = audit_selection(sel, _stats(-5, 100), _stats(2000, 200),
                         JoinProperties(), PARAMS)
    assert _rules(vs) == {"C1_NEGATIVE_COST_TERM"}
    bad = Selection(JoinMethod.SHUFFLE_HASH, "test", -1.0,
                    {JoinMethod.SHUFFLE_HASH: -1.0})
    vs = audit_selection(bad, _stats(1000, 100), _stats(2000, 200),
                         JoinProperties(), PARAMS)
    assert _rules(vs) == {"C1_NEGATIVE_COST_TERM"}


def test_c2_nonminimal_method():
    left, right = _stats(8000, 800), _stats(7000, 700)
    sel = select_join_method(left, right, JoinProperties(), PARAMS)
    assert sel.method is JoinMethod.SHUFFLE_HASH  # k ~ 1.14 < k0 = 7
    assert audit_selection(sel, left, right, JoinProperties(), PARAMS) == []
    # Swap in the pricier method at its own quote: exactly C2.
    worse = dataclasses.replace(
        sel, method=JoinMethod.BROADCAST_HASH,
        cost=sel.costs[JoinMethod.BROADCAST_HASH])
    vs = audit_selection(worse, left, right, JoinProperties(), PARAMS)
    assert _rules(vs) == {"C2_NONMINIMAL_METHOD"}
    # Right method misquoted at the wrong cost: also C2.
    misquoted = dataclasses.replace(sel, cost=sel.cost * 2)
    vs = audit_selection(misquoted, left, right, JoinProperties(), PARAMS)
    assert _rules(vs) == {"C2_NONMINIMAL_METHOD"}


def test_r1_replan_broken_edge():
    edges = [JoinEdge(0, 1, "fk", "pk"), JoinEdge(1, 2, "fk2", "pk2")]
    ok = JoinStep(1, "fk", "pk", None, 0.0)
    assert check_replan_step(ok, {0}, edges) == []
    # Build leaf with no edge into the joined set.
    assert _rules(check_replan_step(JoinStep(2, "fk2", "pk2", None, 0.0),
                                    {0}, edges)) == {"R1_REPLAN_BROKEN_EDGE"}
    # Right leaf, wrong keys.
    assert _rules(check_replan_step(JoinStep(1, "fk", "pk2", None, 0.0),
                                    {0}, edges)) == {"R1_REPLAN_BROKEN_EDGE"}


def test_r2_reopt_discipline():
    est, meas = _stats(1000, 100), _stats(9000, 900)   # q-error exactly 9
    fired = ReoptDecision(boundary=0, estimated=est, measured=meas,
                          threshold=3.0, q_error=9.0, triggered=True,
                          old_next=1, new_next=2)
    assert check_reopt_decision(fired) == []
    calm = ReoptDecision(boundary=1, estimated=est, measured=_stats(
        1100, 110), threshold=3.0, q_error=1.1, triggered=False,
        old_next=2, new_next=2)
    assert check_reopt_decision(calm) == []
    # Forged q-error: the recorded value must be recomputable.
    forged = dataclasses.replace(fired, q_error=1.0, triggered=False,
                                 new_next=1)
    assert _rules(check_reopt_decision(forged)) == {"R2_REOPT_DISCIPLINE"}
    # Trigger flag contradicting the recorded numbers.
    ignored = dataclasses.replace(fired, triggered=False, new_next=1)
    assert _rules(check_reopt_decision(ignored)) == {"R2_REOPT_DISCIPLINE"}
    # Silent re-plan: the continuation changed without a trigger.
    silent = dataclasses.replace(calm, new_next=0)
    assert _rules(check_reopt_decision(silent)) == {"R2_REOPT_DISCIPLINE"}


def test_every_rule_has_a_mutation_test():
    """The registry and this file grow together."""
    import pathlib
    src = pathlib.Path(__file__).read_text()
    for rule_id in RULES:
        assert f'"{rule_id}"' in src, f"no mutation test mentions {rule_id}"


# ---------------------------------------------------------------------------
# The elision-aware cost fix (the analyzer's E2 finding, pinned).
# ---------------------------------------------------------------------------


def test_prepartitioned_probe_discounts_shuffle_quote():
    """The redundant-exchange finding: a probe side already partitioned on
    its join key ships nothing in a shuffle join, so the quote must drop
    its network term — here that flips the selection from broadcast to
    shuffle. Before the fix the model re-paid the elided exchange and
    broadcast won."""
    left, right = _stats(8000, 800), _stats(1000, 100)
    base = select_join_method(left, right, JoinProperties(), PARAMS)
    assert base.method is JoinMethod.BROADCAST_HASH  # k = 8 > k0 = 7
    pre = select_join_method(
        left, right, JoinProperties(left_partitioned=True), PARAMS)
    assert pre.method is JoinMethod.SHUFFLE_HASH
    # coef_a drops to 1.0; coef_b stays (w*p - w + 2p)/p = 2.75 at p=4, w=1.
    assert pre.costs[JoinMethod.SHUFFLE_HASH] == pytest.approx(
        8000 + 2.75 * 1000)
    # Salted quotes never take the discount (salting re-keys the data).
    assert pre.costs[JoinMethod.SALTED_SHUFFLE_HASH] == pytest.approx(
        base.costs[JoinMethod.SALTED_SHUFFLE_HASH])


def test_prepartitioned_build_discount():
    left, right = _stats(8000, 800), _stats(1000, 100)
    base = select_join_method(left, right, JoinProperties(), PARAMS)
    pre = select_join_method(
        left, right, JoinProperties(right_partitioned=True), PARAMS)
    # B-coefficient falls from 2.75 to 2.0 (the build still replicates
    # p-fold locally but ships nothing).
    assert pre.costs[JoinMethod.SHUFFLE_HASH] == pytest.approx(
        base.costs[JoinMethod.SHUFFLE_HASH] - 0.75 * 1000)


def test_agg_agg_join_elides_and_discounts(catalog):
    """q4 joins two aggregates both keyed on the join key: the engine
    elides both shuffles, the decision's recorded properties prove it,
    and the exchange audit finds zero redundant exchanges."""
    res = Executor(catalog, RelJoinStrategy(), verify=True).execute(
        all_queries()["q4_agg_agg"])
    (d,) = res.decisions
    assert d.props.left_partitioned and d.props.right_partitioned
    assert all(e.elided for e in d.report.exchanges)
    assert d.network_bytes == 0.0
    assert audit_join_decision(d, CostParams(p=catalog.p, w=1.0)) == []


# ---------------------------------------------------------------------------
# Clean gated passes: the golden suite under verify=True.
# ---------------------------------------------------------------------------

_ALL = {**every_query(), **skewed_queries(), **filtered_queries()}


@pytest.mark.parametrize("qname", sorted(_ALL))
def test_golden_queries_clean_under_verify(catalog, qname):
    plan = _ALL[qname]
    optimize(plan, catalog, verify=True)
    res = Executor(catalog, RelJoinStrategy(), verify=True).execute(plan)
    assert verify_execution(res, CostParams(p=catalog.p, w=1.0)) == []


_COMPOSED = ("q2_chain7", "q7_filtered_fact", "q13_fact_fact_first",
             "q19_filtered_customer", "q21_catalog_filtered_dates")


@pytest.mark.parametrize("qname", _COMPOSED)
def test_composed_strategies_clean_under_verify(catalog, qname):
    """Adaptive re-plans, runtime-filter placements, cache traffic and
    skew-aware selections all pass the gates."""
    plan = _ALL[qname]
    cache = FilterCache()
    strat = FilteredStrategy(ReorderingStrategy(RelJoinStrategy()),
                             cache=cache)
    Executor(catalog, strat, verify=True).execute(plan)
    # Warm second run: cache hits go through the F3 reuse gate.
    Executor(catalog, strat, verify=True).execute(plan)
    Executor(catalog, SkewAwareStrategy(), verify=True).execute(plan)


def test_verify_flag_via_strategy(catalog):
    strat = RelJoinStrategy()
    strat.verify = True
    wrapped = FilteredStrategy(strat)
    assert wrapped.verify
    assert Executor(catalog, wrapped).verify


def test_verify_raises_on_bad_plan(catalog):
    plan = Join(Scan("store_sales"), Scan("item"), "ss_item_sk",
                "no_such_key")
    with pytest.raises(PlanVerificationError) as ei:
        Executor(catalog, RelJoinStrategy(), verify=True).execute(plan)
    assert {v.rule for v in ei.value.violations} == {"P1_UNKNOWN_COLUMN"}
    # Gates disarmed (the default): the executor fails later and
    # differently, or not at all — the analyzer is opt-in.
    assert not Executor(catalog, RelJoinStrategy()).verify


def test_infer_properties_tracks_rename_and_matched(catalog):
    schema = catalog_schema(catalog)
    plan = Join(Scan("store_sales"), Scan("item"), "ss_item_sk", "i_item_sk",
                join_type=JoinType.LEFT_OUTER)
    props, violations = infer_properties(plan, schema)
    assert violations == []
    cols = props["root"].columns
    assert "i_item_sk_matched" in cols
    assert props["root"].dtypes["i_item_sk_matched"] == "bool"
    agg = Aggregate(Scan("item"), "i_brand", (("i_price", "mean"),
                                              ("i_price", "count")))
    props, _ = infer_properties(agg, schema, catalog_dtypes(catalog))
    assert props["root"].dtypes["mean_i_price"] == "float32"
    assert props["root"].dtypes["count_i_price"] == "int32"
    assert props["root"].distribution.partitioned_on("i_brand")
