"""Per-architecture smoke tests (deliverable f) + decode/forward
consistency (validates the chunked SSD / RWKV / flash-attention math
against the sequential recurrences)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.relshard import plan_model
from repro.models import lm
from repro.models.config import SHAPE_BY_NAME, Family

MESH1 = (("data", 1), ("model", 1))


def _batch(cfg, key, B=2, S=64):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.n_cond_tokens:
        batch["cond_emb"] = 0.01 * jax.random.normal(
            key, (B, cfg.n_cond_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_step(arch):
    """Reduced config: one forward/train step on CPU; shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    plan = plan_model(cfg, MESH1, SHAPE_BY_NAME["train_4k"], fsdp=False)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)

    def loss_fn(p):
        loss, metrics = lm.train_loss(p, cfg, plan, None, batch)
        return loss
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_hidden_shapes(arch):
    cfg = get_smoke_config(arch)
    plan = plan_model(cfg, MESH1, SHAPE_BY_NAME["train_4k"], fsdp=False)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key, B=2, S=32)
    hidden, aux = lm.forward(params, cfg, plan, None, batch["tokens"],
                             batch.get("cond_emb"))
    S_total = 32 + cfg.n_cond_tokens
    assert hidden.shape == (2, S_total, cfg.d_model)
    assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())
    if cfg.is_moe:
        assert aux.moe_load.shape == (cfg.n_layers, cfg.n_experts)
        # router must have routed every token top_k times
        tokens_routed = float(aux.moe_load.sum())
        assert tokens_routed == pytest.approx(
            cfg.n_layers * 2 * S_total * cfg.top_k, rel=1e-6)


@pytest.mark.parametrize("arch", ["granite_8b", "rwkv6_3b", "zamba2_7b",
                                  "musicgen_large"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-sequence logits: validates
    KV-cache indexing and the chunked-vs-sequential SSM/RWKV equivalence."""
    cfg = get_smoke_config(arch)
    if cfg.family in (Family.VLM, Family.AUDIO):
        cfg = __import__("dataclasses").replace(cfg, n_cond_tokens=0)
    plan = plan_model(cfg, MESH1, SHAPE_BY_NAME["decode_32k"], fsdp=False)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # full-sequence hidden -> logits at every position
    hidden, _ = lm.forward(params, cfg, plan, None, tokens)
    from repro.layers import embedding as emb
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    full_logits = emb.lm_head_logits(head, hidden, mesh=None,
                                     batch_axes=plan.batch_axes,
                                     model_axis=plan.model_axis,
                                     strategy="replicate")

    cache = lm.init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(params, cfg, plan, None,
                                       tokens[:, t:t + 1], cache)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)  # (B, S, V)

    # bf16 compute accumulates ~0.1-0.2 absolute noise over several blocks;
    # logic bugs produce O(1) divergence at wrong positions.
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=0.2, atol=0.25)


def test_moe_load_is_runtime_statistic():
    """The MoE router load is the adaptive runtime statistic: it must sum
    to tokens*top_k and react to the data distribution."""
    cfg = get_smoke_config("qwen3_moe_235b_a22b")
    plan = plan_model(cfg, MESH1, SHAPE_BY_NAME["train_4k"], fsdp=False)
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    _, aux = lm.forward(params, cfg, plan, None, tokens)
    load = np.asarray(aux.moe_load)
    assert (load.sum(axis=1) == 2 * 32 * cfg.top_k).all()


def test_param_counts_match_analytic():
    """Analytic 6ND accounting vs actual init sizes (dense archs)."""
    for arch in ["granite_8b", "tinyllama_1_1b"]:
        cfg = get_smoke_config(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        # analytic count excludes norms (tiny); within 2%
        assert abs(actual - cfg.param_count()) / actual < 0.02, arch


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (deliverable f)."""
    from repro.configs import get_config
    c = get_config("glm4_9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab) == (40, 4096, 32, 2, 13696, 151552)
    c = get_config("qwen3_moe_235b_a22b")
    assert (c.n_layers, c.n_experts, c.top_k, c.vocab) == (94, 128, 8,
                                                           151936)
    c = get_config("dbrx_132b")
    assert (c.n_experts, c.top_k, c.d_model) == (16, 4, 6144)
    c = get_config("zamba2_7b")
    assert (c.n_layers, c.ssm_state, c.d_model) == (81, 64, 3584)
    c = get_config("rwkv6_3b")
    assert (c.n_layers, c.d_model, c.vocab) == (32, 2560, 65536)
    c = get_config("musicgen_large")
    assert (c.n_layers, c.d_model, c.vocab) == (48, 2048, 2048)
    c = get_config("paligemma_3b")
    assert (c.n_layers, c.n_heads, c.kv_heads, c.vocab) == (18, 8, 1,
                                                            257216)
    c = get_config("starcoder2_3b")
    assert (c.n_layers, c.d_model, c.kv_heads) == (30, 3072, 2)
    c = get_config("granite_8b")
    assert (c.n_layers, c.d_model, c.kv_heads, c.d_ff) == (36, 4096, 8,
                                                           14336)
    c = get_config("tinyllama_1_1b")
    assert (c.n_layers, c.d_model, c.kv_heads) == (22, 2048, 4)
