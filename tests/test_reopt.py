"""Checkpoint re-optimization, differentially pinned.

Three cells × p ∈ {1, 8}, each against the numpy oracle and against the
reopt-off arm:

  * **no divergence** — uniform catalog, histogram-backed estimates are
    near-exact, so no checkpoint may trigger and the executed decisions
    must be byte-identical to the reopt-off run (re-planning may only be
    bought with evidence);
  * **forced divergence** — ``skew_overrides`` tilts one fact FK until a
    checkpoint's measured cardinality blows past the q-error threshold:
    re-opt must trigger, and the *rows* must still be identical (only the
    physical continuation may change);
  * **empty intermediate** — a filter that keeps nothing: the q-error of
    an empty boundary is finite by the one-row floor, checkpoints stay
    disciplined, and both arms return the empty result.

Every reopt run carries ``verify=True``, so plan-analysis rule
``R2_REOPT_DISCIPLINE`` audits each recorded ``ReoptDecision`` inline.
"""

import numpy as np
import pytest

from repro.joins.ref import ref_equi_join, rows_as_set
from repro.sql import Executor, RelJoinStrategy, ReorderingStrategy
from repro.sql.datagen import generate
from repro.sql.logical import Filter, Join, Scan


def _plan(item_cut=150.0):
    """3-leaf chain (a reorderable region with ≥2 checkpoints):
    (store_sales ⋈ σ(item)) ⋈ date_dim."""
    return Join(
        Join(Scan("store_sales"),
             Filter(Scan("item"), "i_item_sk", "lt", item_cut),
             "ss_item_sk", "i_item_sk"),
        Scan("date_dim"), "ss_sold_date_sk", "d_date_sk")


def _oracle_rows(catalog, item_cut=150.0):
    ss = catalog.table("store_sales").to_numpy()
    item = catalog.table("item").to_numpy()
    dd = catalog.table("date_dim").to_numpy()
    item_f = {n: c[item["i_item_sk"] < item_cut] for n, c in item.items()}
    out = ref_equi_join(ss, item_f, "ss_item_sk", "i_item_sk")
    out = ref_equi_join(out, dd, "ss_sold_date_sk", "d_date_sk")
    return rows_as_set(out)


def _run(catalog, reopt, item_cut=150.0, adaptive=False):
    ex = Executor(catalog,
                  strategy=ReorderingStrategy(RelJoinStrategy(),
                                              reopt=reopt),
                  adaptive=adaptive, verify=True)
    return ex.execute(_plan(item_cut))


@pytest.mark.parametrize("p", [1, 8])
def test_no_divergence_is_byte_identical(p):
    """Uniform catalog: estimates are histogram-exact, no checkpoint
    triggers, and the reopt arm's decisions equal the reopt-off arm's."""
    catalog = generate(scale=0.1, p=p, seed=42)
    off = _run(catalog, reopt=False)
    on = _run(catalog, reopt=True)
    assert on.reopts, "reopt run must audit every checkpoint"
    assert on.reopt_count == 0, (
        f"spurious trigger: {[d for d in on.reopts if d.triggered]}")
    # Non-triggered checkpoints leave the continuation untouched: the
    # physical execution is byte-identical to the reopt-off arm.
    assert on.methods() == off.methods()
    assert [(d.selection.method, d.selection.swapped_sides)
            for d in on.decisions] == \
        [(d.selection.method, d.selection.swapped_sides)
         for d in off.decisions]
    assert on.network_bytes == off.network_bytes
    assert rows_as_set(on.table.to_numpy()) == \
        rows_as_set(off.table.to_numpy()) == _oracle_rows(catalog)


@pytest.mark.parametrize("p", [1, 8])
def test_forced_divergence_triggers_and_preserves_rows(p):
    """A Zipf-tilted ss_item_sk makes the static estimate of the first
    join's output wrong by far more than the threshold: the checkpoint
    must trigger, fold the measured stats, and still produce exactly the
    oracle's rows."""
    catalog = generate(scale=0.1, p=p, seed=7,
                       skew_overrides={"ss_item_sk": 1.3})
    off = _run(catalog, reopt=False)
    on = _run(catalog, reopt=True)
    assert on.reopt_count >= 1, (
        f"no trigger despite divergence: {on.reopts}")
    trig = [d for d in on.reopts if d.triggered]
    for d in trig:
        assert d.q_error > d.threshold
    expected = _oracle_rows(catalog)
    assert rows_as_set(on.table.to_numpy()) == expected
    assert rows_as_set(off.table.to_numpy()) == expected
    assert on.rows == off.rows


@pytest.mark.parametrize("p", [1, 8])
def test_empty_intermediate_stays_disciplined(p):
    """A filter keeping nothing empties the first boundary; q-errors stay
    finite (one-row floor), R2 still passes, and both arms agree on the
    empty result."""
    catalog = generate(scale=0.1, p=p, seed=42)
    off = _run(catalog, reopt=False, item_cut=0.0)
    on = _run(catalog, reopt=True, item_cut=0.0)
    assert on.rows == off.rows == 0
    assert rows_as_set(on.table.to_numpy()) == _oracle_rows(
        catalog, item_cut=0.0) == []
    for d in on.reopts:
        assert np.isfinite(d.q_error)
        assert d.triggered == (d.q_error > d.threshold)


def test_adaptive_reopt_agrees_with_static(catalog):
    """reopt composes with adaptive execution: measured stats are already
    folded at every boundary, so checkpoints see q-error 1.0 against the
    *predicted* next step and rows match the static arms."""
    res = _run(catalog, reopt=True, adaptive=True)
    assert rows_as_set(res.table.to_numpy()) == _oracle_rows(catalog)
    for d in res.reopts:
        assert d.triggered == (d.q_error > d.threshold)


def test_reopt_decisions_record_the_continuation(catalog):
    """Every audited checkpoint names the planned next build leaf before
    and after; non-triggered checkpoints must not change it."""
    res = _run(catalog, reopt=True)
    assert res.reopts
    for d in res.reopts:
        if not d.triggered:
            assert d.new_next == d.old_next
