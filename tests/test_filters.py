"""Runtime bloom-filter pushdown: executor + planner integration tests.

Covers the FilteredStrategy end to end: result preservation on every query
family, the strict cost gate (no filters on unfiltered builds => selections
byte-identical to the wrapped strategy), leaf-level placement below earlier
exchanges, measured-stat re-planning, the empty-build degenerate case, and
composition with reordering and skew awareness.
"""

import pytest

from repro.core.cost_model import (CostParams, bloom_fpr, bloom_params,
                                   filtered_probe_fraction,
                                   runtime_filter_cost)
from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (Executor, FilteredStrategy, RelJoinStrategy,
                       ReorderingStrategy, SkewAwareStrategy, all_queries,
                       filtered_queries, plan_runtime_filters)
from repro.sql.logical import Aggregate, Filter, Join, JoinEdge, Scan
from repro.core.selection import JoinType
from repro.core.stats import TableStats


def _rows(res):
    return rows_as_set(res.table.to_numpy())


# ---------------------------------------------------------------------------
# Planner: placement decisions
# ---------------------------------------------------------------------------


def _stats(size, card):
    return TableStats(float(size), float(card))


def test_planner_plans_filter_only_when_strictly_cheaper():
    params = CostParams(p=8, w=1.0)
    edge = [JoinEdge(0, 1, "fk", "pk")]
    probe, build = _stats(1 << 20, 32_768), _stats(1 << 14, 1_024)
    # Selective build (sigma 0.1): the shuffle saving dwarfs the broadcast.
    planned = plan_runtime_filters(edge, [probe, build], [1.0, 0.1], params)
    assert len(planned) == 1
    rf = planned[0]
    assert rf.benefit > rf.cost
    assert rf.keep_est == pytest.approx(
        filtered_probe_fraction(0.1, bloom_fpr(1_024, rf.m_bits, rf.k)))
    # Unfiltered build (sigma 1): nothing to save, nothing planned.
    assert plan_runtime_filters(edge, [probe, build], [1.0, 1.0], params) == []


def test_planner_respects_broadcast_cost_floor():
    """A tiny probe side cannot amortize the filter broadcast: the cost
    inequality must reject the filter even at high selectivity."""
    params = CostParams(p=8, w=1.0)
    edge = [JoinEdge(0, 1, "fk", "pk")]
    probe, build = _stats(2_000, 100), _stats(160_000, 10_000)
    assert plan_runtime_filters(edge, [probe, build], [1.0, 0.1],
                                params) == []


def test_planner_dedupes_equivalent_edges():
    params = CostParams(p=8, w=1.0)
    edges = [JoinEdge(0, 1, "fk", "pk"), JoinEdge(0, 1, "fk", "pk", True)]
    probe, build = _stats(1 << 20, 32_768), _stats(1 << 14, 1_024)
    planned = plan_runtime_filters(edges, [probe, build], [1.0, 0.1], params)
    assert len(planned) == 1


def test_filter_cost_model_units():
    params = CostParams(p=8, w=2.0)
    assert runtime_filter_cost(8192, params) == pytest.approx(2.0 * 7 * 1024)
    m, k = bloom_params(1000)
    assert m >= 1000 * 10 and m & (m - 1) == 0
    assert 1 <= k <= 8


# ---------------------------------------------------------------------------
# Executor: end-to-end behaviour
# ---------------------------------------------------------------------------


# The session-scoped ``catalog`` fixture (scale 0.1, p=4) is reused for
# end-to-end runs: its shapes are already warm in the XLA compile cache.


@pytest.mark.parametrize("qname", sorted(filtered_queries()))
def test_filtered_results_identical(catalog, qname):
    """Filters must never change results — only bytes shipped."""
    plan = filtered_queries()[qname]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert rows_close(_rows(filt), _rows(base)), qname
    assert filt.filters, f"{qname} planned no filter"
    assert filt.probe_shuffle_bytes < base.probe_shuffle_bytes


def test_no_filters_on_unfiltered_builds(catalog):
    """Strict-cheaper gate: with no selective dimension predicate, sigma is
    1 everywhere, nothing is planned, and selections are byte-identical to
    the wrapped strategy's."""
    plan = all_queries()["q9_inventory_star"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert filt.filters == []
    assert filt.methods() == base.methods()
    assert filt.network_bytes == pytest.approx(base.network_bytes)


#: One representative per query shape: filtered star, aggregate build,
#: big-dim shuffle, semi, anti. (The full method x case grid runs in
#: test_differential; golden snapshots pin q1-q18 selections.)
_PRESERVE_QUERIES = ("q1_star3", "q3_cross_channel", "q7_filtered_fact",
                     "q8_semi", "q12_anti")


@pytest.mark.parametrize("qname", _PRESERVE_QUERIES)
def test_filtered_strategy_preserves_baseline_queries(catalog, qname):
    """Whatever the planner decides, baseline results are preserved."""
    plan = all_queries()[qname]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert rows_close(_rows(filt), _rows(base)), qname


# ---------------------------------------------------------------------------
# Join-type safety: one regression test per join type (rule F1's contract)
# ---------------------------------------------------------------------------


def _typed_join(join_type):
    """Fact joined to a selective dimension — selective enough that the
    planner wants a probe-side filter whenever the type allows one."""
    build = Filter(Scan("item"), "i_category", "lt", 3, selectivity=0.3)
    return Join(Scan("store_sales"), build, "ss_item_sk", "i_item_sk",
                join_type=join_type)


@pytest.mark.parametrize("join_type", [JoinType.INNER, JoinType.LEFT_SEMI,
                                       JoinType.LEFT_OUTER])
def test_filterable_join_types_preserve_results(catalog, join_type):
    """INNER/LEFT_SEMI drop-only semantics and the LEFT_OUTER padding path
    all yield byte-identical results with the filter actually applied."""
    plan = _typed_join(join_type)
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy(), verify=True).execute(plan)
    assert filt.filters, f"{join_type.value}: no filter planned"
    assert rows_close(_rows(filt), _rows(base)), join_type.value


def test_left_outer_padding_restores_unmatched_rows(catalog):
    """The filter drops unmatched probe rows before the join; the padding
    path must re-inject every one of them null-padded with _matched=False,
    so row count and the matched/unmatched split equal the unfiltered
    run's."""
    plan = _typed_join(JoinType.LEFT_OUTER)
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy(), verify=True).execute(plan)
    (f,) = filt.filters
    assert f.rows_after < f.rows_before
    assert filt.rows == base.rows  # every probe row survives

    def matched_count(res):
        rows = res.table.to_numpy()
        return int(rows["i_item_sk_matched"].sum())

    assert matched_count(filt) == matched_count(base)
    # The padded rows are exactly the unmatched remainder.
    assert filt.rows - matched_count(filt) > 0


def test_left_anti_never_filtered(catalog):
    """LEFT_ANTI keeps exactly the rows a build-key filter would drop:
    nothing may ever be planned, and results stay identical."""
    plan = _typed_join(JoinType.LEFT_ANTI)
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy(), verify=True).execute(plan)
    assert filt.filters == []
    assert rows_close(_rows(filt), _rows(base))


def test_filter_pushed_below_earlier_exchange(catalog):
    """q20: the item predicate joins *after* the customer shuffle in plan
    order, yet its filter lands on the fact leaf — the customer join's
    probe exchange must shrink by ~the item selectivity."""
    plan = filtered_queries()["q20_filter_below_earlier_exchange"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    assert len(filt.filters) == 1
    f = filt.filters[0]
    assert f.plan.probe_key == "ss_item_sk"
    # The *first* executed join is fact x customer; its probe exchange ran
    # on the filtered fact.
    first_base = base.decisions[0].probe_shuffle_bytes
    first_filt = filt.decisions[0].probe_shuffle_bytes
    assert first_filt < 0.3 * first_base
    assert rows_close(_rows(filt), _rows(base))


def test_replan_uses_measured_post_filter_stats(catalog):
    """The join selection after a filter must see the measured post-filter
    probe cardinality, not the pre-filter one."""
    plan = filtered_queries()["q19_filtered_customer"]
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    f = filt.filters[0]
    d = filt.decisions[0]
    assert f.rows_after < f.rows_before
    assert d.left_stats.cardinality == f.rows_after


def test_empty_build_side_yields_empty_result(catalog):
    """A predicate rejecting the whole dimension: the filter drops every
    probe row and the query returns the empty result without crashing."""
    f = Filter(Scan("customer"), "c_income", "lt", -1.0, selectivity=0.01)
    plan = Aggregate(Join(Scan("store_sales"), f, "ss_customer_sk",
                          "c_customer_sk"),
                     "c_region", (("ss_net_profit", "sum"),))
    res = Executor(catalog, FilteredStrategy()).execute(plan)
    assert res.rows == 0
    assert res.filters and res.filters[0].rows_after == 0


def test_filter_network_accounting(catalog):
    """The filter broadcast is charged to network_bytes (honest accounting:
    the m-bit array crosses the wire p-1 times)."""
    plan = filtered_queries()["q19_filtered_customer"]
    filt = Executor(catalog, FilteredStrategy()).execute(plan)
    join_net = sum(d.network_bytes for d in filt.decisions)
    assert filt.network_bytes == pytest.approx(
        join_net + filt.filter_network_bytes)
    assert filt.filter_network_bytes > 0


def test_wrappers_forward_filter_flags():
    """Both composition orders expose runtime_filters to the Executor:
    Reorder(Filtered(X)) must not silently lose filter pushdown."""
    inner = FilteredStrategy(bits_per_key=12)
    wrapped = ReorderingStrategy(inner)
    assert wrapped.runtime_filters and wrapped.reorder
    assert wrapped.bits_per_key == 12
    other = FilteredStrategy(ReorderingStrategy())
    assert other.runtime_filters and other.reorder


def test_composes_with_reordering(catalog):
    """Filtered(Reorder(RelJoin)): both rewrites active, results intact."""
    plan = filtered_queries()["q20_filter_below_earlier_exchange"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    comp = Executor(catalog,
                    FilteredStrategy(ReorderingStrategy())).execute(plan)
    assert comp.filters
    assert rows_close(_rows(comp), _rows(base))


def test_composes_with_skew_awareness(catalog):
    """Filtered(SkewAware): the post-filter table is what the straggler
    measurement sees (a filter changes the skew the exchange experiences)."""
    plan = filtered_queries()["q19_filtered_customer"]
    base = Executor(catalog, RelJoinStrategy()).execute(plan)
    comp = Executor(catalog,
                    FilteredStrategy(SkewAwareStrategy())).execute(plan)
    assert comp.filters
    assert rows_close(_rows(comp), _rows(base))
