"""Concurrent query service: plan cache, cross-query CSE, shared
FilterCache, and admission control.

The batched fixture runs the whole service suite (q19-q23 + the
deliberately-overlapping q33/q34) through one ``QueryService`` with
``verify=True`` — plan-analysis gates armed on every executed plan,
producers included — and keeps the solo reference runs beside it. Tests
then pin the correctness contract (rows identical to solo), the sharing
claims (each deduped subtree executes exactly once; suite bytes strictly
below serial), and the admission/caching discipline.
"""

import pytest

from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (AdmissionController, Aggregate, Join, PlanCache,
                       QueryService, Scan, Submission, generate, optimize,
                       parse_sql, service_queries,
                       shared_subtree_candidates, signature)
from repro.sql.queries import SQL_TEXTS


def _rows(res):
    return rows_as_set(res.table.to_numpy())


def _sub(qid, cost):
    """Minimal Submission for admission-only tests (no compiled plan)."""
    return Submission(qid=qid, name=f"q{qid}", plan=None, optimized=None,
                      quoted_cost=cost, plan_cached=False)


@pytest.fixture(scope="module")
def service_batch(catalog):
    """(service, submissions, batch report, solo references) for the full
    suite — module-scoped because execution dominates wall time."""
    service = QueryService(catalog, verify=True)
    queries = service_queries()
    subs = {q: service.submit(plan, name=q) for q, plan in queries.items()}
    reports = service.run()
    assert len(reports) == 1
    solos = {q: service.execute_solo(plan) for q, plan in queries.items()}
    return service, subs, reports[0], solos


# ---------------------------------------------------------------------------
# Correctness: batched == solo
# ---------------------------------------------------------------------------


def test_batched_rows_identical_to_solo(service_batch):
    _, _, report, solos = service_batch
    for qname, solo in solos.items():
        assert rows_close(_rows(report.results[qname]), _rows(solo)), qname


def test_shared_subtrees_executed_exactly_once(service_batch):
    """q33 duplicates q19's join and q34 duplicates q22's: each shared
    subtree gets exactly one producer execution, and its consumers run
    zero joins of their own for it (the injected table replaces them)."""
    _, _, report, solos = service_batch
    by_consumers = {frozenset(s.consumers): s for s in report.shared}
    pair19 = frozenset(("q19_filtered_customer", "q33_shared_customer_join"))
    pair22 = frozenset(("q22_zone_map_window", "q34_shared_window_join"))
    assert pair19 in by_consumers and pair22 in by_consumers
    for s in report.shared:
        assert s.occurrences >= 2
    # One producer execution per shared signature.
    sigs = [s.sig for s in report.shared]
    assert len(sigs) == len(set(sigs))
    # Consumers of a fully-shared join subtree execute no joins at all:
    # their whole pre-aggregate subtree arrives by injection.
    for qname in pair19 | pair22:
        assert len(report.results[qname].decisions) == 0, qname
        assert report.results[qname].network_bytes == 0.0, qname
    # Globally: batched joins strictly fewer than serial.
    batch_joins = (sum(len(s.result.decisions) for s in report.shared)
                   + sum(len(r.decisions) for r in report.results.values()))
    serial_joins = sum(len(r.decisions) for r in solos.values())
    assert batch_joins < serial_joins


def test_suite_bytes_strictly_below_serial(service_batch):
    _, _, report, solos = service_batch
    serial = sum(r.network_bytes for r in solos.values())
    assert report.total_network_bytes < serial


def test_stats_publish(service_batch):
    service, subs, _, _ = service_batch
    stats = service.stats()
    assert stats["queries_submitted"] >= len(subs)
    assert stats["plan_cache_misses"] >= len(subs)
    assert stats["plan_cache_size"] == len(service.plan_cache)


# ---------------------------------------------------------------------------
# Subtree-candidate enumeration (region atomicity)
# ---------------------------------------------------------------------------


def test_candidates_are_exchange_rooted_and_region_atomic():
    """Only Join/Aggregate roots are candidates, and an inner join nested
    directly under another hint-free inner join is NOT one: solo execution
    dissolves it into the parent's region (reordered/filtered across its
    boundary), so deduping it would not be execution-equivalent."""
    inner = Join(Scan("store_sales"), Scan("customer"),
                 "ss_customer_sk", "c_customer_sk")
    outer = Join(inner, Scan("store"), "ss_store_sk", "s_store_sk")
    plan = Aggregate(outer, "c_region", (("ss_net_profit", "sum"),))
    nodes = [n for _, n in shared_subtree_candidates(plan)]
    assert plan in nodes          # Aggregate root
    assert outer in nodes         # region root (parent is the Aggregate)
    assert inner not in nodes     # dissolves into the parent region
    # An aggregated subquery under a join IS atomic (exchange boundary).
    agg_leaf = Aggregate(Scan("catalog_sales"), "cs_item_sk",
                         (("cs_sales_price", "sum"),))
    j = Join(Scan("store_sales"), agg_leaf, "ss_item_sk", "cs_item_sk")
    assert agg_leaf in [n for _, n in shared_subtree_candidates(j)]


def test_aggregate_specs_distinguish_signatures():
    """q33 is q19's join under a different aggregate column: the plan
    signatures must differ (the plan cache / CSE would otherwise alias
    them and return wrong aggregates), while the join subtrees match."""
    q19 = parse_sql(SQL_TEXTS["q19_filtered_customer"])
    q33 = parse_sql(SQL_TEXTS["q33_shared_customer_join"])
    assert signature(q19) != signature(q33)
    assert signature(q19.child) == signature(q33.child)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_warm_hit_skips_optimize(catalog):
    service = QueryService(catalog)
    plan = service_queries()["q19_filtered_customer"]
    cold = service.submit(plan, name="cold")
    warm = service.submit(plan, name="warm")
    assert not cold.plan_cached and warm.plan_cached
    assert warm.optimized is cold.optimized   # the stored object, verbatim
    assert service.plan_cache.hits == 1


def test_plan_cache_binds_to_catalog_fingerprint(catalog):
    """Two catalogs sharing a version number must not share plans: the
    fingerprint (version + uid) is the binding, mirroring FilterCache."""
    plan = service_queries()["q19_filtered_customer"]
    cache = PlanCache()
    optimize(plan, catalog, prune=False, plan_cache=cache)
    assert len(cache) == 1 and cache.misses == 1
    other = generate(scale=0.1, p=4, seed=43)
    other.version = catalog.version   # forced version collision
    optimize(plan, other, prune=False, plan_cache=cache)
    assert cache.invalidations == 1
    assert cache.hits == 0            # the collision was NOT a hit
    assert len(cache) == 1            # re-populated against `other`


def test_plan_cache_key_separates_optimizer_knobs(catalog):
    """The same logical plan under different rewrite knobs compiles to
    different plans — the key must keep them apart."""
    plan = service_queries()["q19_filtered_customer"]
    cache = PlanCache()
    optimize(plan, catalog, prune=False, plan_cache=cache)
    optimize(plan, catalog, prune=True, plan_cache=cache)
    assert cache.hits == 0 and cache.misses == 2 and len(cache) == 2


# ---------------------------------------------------------------------------
# Shared FilterCache across the batch (interleaved multi-query execution)
# ---------------------------------------------------------------------------


def test_interleaved_queries_share_one_filter_cache(catalog):
    """Two queries with overlapping predicate chains through the service
    (CSE off, so both actually execute their joins): rows identical to
    solo, and the second query's eligible filters all report cached=True
    with zero rebuild bytes — PR 5's warm-run result, now intra-batch."""
    service = QueryService(catalog, cse=False)
    q19 = service_queries()["q19_filtered_customer"]
    q33 = service_queries()["q33_shared_customer_join"]
    service.submit(q19, name="first")
    service.submit(q33, name="second")
    report = service.run()[0]
    first, second = report.results["first"], report.results["second"]
    # Both executed fully (no CSE) and built/used filters.
    assert first.filters and second.filters
    assert first.cached_filters == 0
    assert second.cached_filters == len(second.filters)
    assert second.filter_reduce_bytes == 0.0
    assert rows_close(_rows(first), _rows(service.execute_solo(q19)))
    assert rows_close(_rows(second), _rows(service.execute_solo(q33)))


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_fifo_preserves_order():
    ac = AdmissionController()
    for i, cost in enumerate([5.0, 1.0, 3.0]):
        ac.submit(_sub(i, cost))
    assert [s.qid for s in ac.next_batch()] == [0, 1, 2]
    assert len(ac) == 0


def test_admission_cost_policy_sorts_cheapest_first():
    ac = AdmissionController(policy="cost")
    for i, cost in enumerate([5.0, 1.0, 3.0, 1.0]):
        ac.submit(_sub(i, cost))
    # Stable: the two cost-1.0 queries keep submission order.
    assert [s.qid for s in ac.next_batch()] == [1, 3, 2, 0]


def test_admission_budget_splits_batches():
    ac = AdmissionController(budget=4.0)
    for i, cost in enumerate([2.0, 2.0, 2.0, 10.0, 1.0]):
        ac.submit(_sub(i, cost))
    assert [s.qid for s in ac.next_batch()] == [0, 1]   # 2+2 <= 4
    assert [s.qid for s in ac.next_batch()] == [2]      # next 2 would + 10
    # An over-budget query is admitted alone — no live-lock.
    assert [s.qid for s in ac.next_batch()] == [3]
    assert [s.qid for s in ac.next_batch()] == [4]
    assert ac.next_batch() == []


def test_admission_rejects_unknown_policy():
    with pytest.raises(ValueError):
        AdmissionController(policy="priority")


def test_service_budget_run_produces_multiple_batches(catalog):
    """End to end: a budget below the suite's total quote forces multiple
    batches, every query still executes, rows still match solo."""
    probe = QueryService(catalog)
    queries = dict(list(service_queries().items())[:3])
    quotes = [probe.submit(p, name=q).quoted_cost
              for q, p in queries.items()]
    budget = max(quotes)  # big enough for any single query, not for all
    service = QueryService(catalog, cost_budget=budget)
    for q, p in queries.items():
        service.submit(p, name=q)
    reports = service.run()
    assert len(reports) >= 2
    executed = {q for r in reports for q in r.results}
    assert executed == set(queries)
    for r in reports:
        for qname, res in r.results.items():
            assert rows_close(_rows(res),
                              _rows(service.execute_solo(queries[qname])))


def test_submission_quotes_are_positive(service_batch):
    _, subs, _, _ = service_batch
    for sub in subs.values():
        assert sub.quoted_cost > 0
