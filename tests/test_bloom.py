"""Property-based tests of the bloom-filter kernel pair (bloom_build /
bloom_probe) via the ``hypothesis_compat`` shim (the real hypothesis
package when installed):

  * **No false negatives, ever** — every key fed to the build must pass the
    probe, across dtypes, duplicate-heavy inputs and m/n ratios. This is
    the property runtime-filter correctness rests on.
  * **False-positive rate tracks the model** — the empirical FPR on keys
    disjoint from the build set stays within 2x of the (1 - e^{-kn/m})^k
    prediction (upper bound always; lower bound only when enough expected
    events make it statistically meaningful).
  * **Bit-array invariance** — the filter is a pure function of the key
    *set*: permutations and duplications of the build input produce the
    byte-identical array.
  * Kernel == numpy reference on every case.
"""

import numpy as np
import pytest
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.core.cost_model import bloom_fpr, bloom_params
from repro.kernels.bloom import bloom_build, bloom_build_ref, bloom_probe

#: Integer dtypes a key column may arrive in (kernels view them as int32).
KEY_DTYPES = (np.int32, np.uint32, np.int16, np.int8)


def _keys(rng, n, lo, hi, dtype=np.int32):
    return rng.integers(lo, hi, n).astype(dtype)


@pytest.mark.parametrize("dtype", KEY_DTYPES, ids=[d.__name__
                                                   for d in KEY_DTYPES])
def test_no_false_negatives_across_dtypes(dtype):
    rng = np.random.default_rng(0)
    hi = min(120, np.iinfo(dtype).max)
    keys = _keys(rng, 500, 0, hi, dtype)
    m, k = bloom_params(len(np.unique(keys)))
    bits = bloom_build(keys, m_bits=m, k=k)
    assert bool(np.asarray(bloom_probe(keys, bits, k=k)).all()), dtype


@settings(max_examples=5, deadline=None)
@given(n=st.integers(1, 512), bits_per_key=st.integers(4, 16),
       dup=st.integers(1, 50), seed=st.integers(0, 10_000))
def test_no_false_negatives_fuzz(n, bits_per_key, dup, seed):
    """Duplicate-heavy inputs (each key repeated ``dup`` times), m/n ratios
    from lean (4 bits/key) to roomy (16): membership never lies."""
    rng = np.random.default_rng(seed)
    base = rng.integers(-(1 << 30), 1 << 30, n).astype(np.int32)
    keys = np.repeat(base, dup)
    m, k = bloom_params(len(np.unique(base)), bits_per_key)
    bits = bloom_build(keys, m_bits=m, k=k)
    assert bool(np.asarray(bloom_probe(base, bits, k=k)).all())


@settings(max_examples=4, deadline=None)
@given(n=st.integers(64, 2048), bits_per_key=st.integers(4, 12),
       seed=st.integers(0, 10_000))
def test_fpr_within_2x_of_model(n, bits_per_key, seed):
    """Empirical FPR on 20k keys disjoint from the build domain, vs the
    (1 - e^{-kn/m})^k prediction."""
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(0, 1 << 20, n).astype(np.int32))
    m, k = bloom_params(len(keys), bits_per_key)
    bits = bloom_build(keys, m_bits=m, k=k)
    probes = 20_000
    miss = rng.integers(1 << 20, 1 << 24, probes).astype(np.int32)
    emp = float(np.asarray(bloom_probe(miss, bits, k=k)).mean())
    pred = bloom_fpr(len(keys), m, k)
    # Upper bound always (with a tiny absolute floor for near-zero preds);
    # lower bound only when >= 20 events are expected, else 0 hits is fine.
    assert emp <= 2.0 * pred + 20.0 / probes, (emp, pred, m, k)
    if pred * probes >= 20:
        assert emp >= pred / 2.0 - 10.0 / probes, (emp, pred, m, k)


@settings(max_examples=5, deadline=None)
@given(n=st.integers(2, 600), seed=st.integers(0, 10_000))
def test_bit_array_invariant_to_key_order(n, seed):
    """The filter is a pure function of the key set: permuting and
    duplicating the input leaves the packed words byte-identical."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 10 * n, n).astype(np.int32)
    m, k = bloom_params(n)
    ref = np.asarray(bloom_build(keys, m_bits=m, k=k))
    perm = np.asarray(bloom_build(keys[rng.permutation(n)], m_bits=m, k=k))
    dup = np.asarray(bloom_build(np.concatenate([keys, keys[::-1]]),
                                 m_bits=m, k=k))
    assert np.array_equal(ref, perm)
    assert np.array_equal(ref, dup)


@settings(max_examples=5, deadline=None)
@given(n=st.integers(0, 300), seed=st.integers(0, 10_000))
def test_kernel_matches_numpy_reference(n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(-1000, 1000, n).astype(np.int32)
    valid = rng.random(n) < 0.8
    m, k = bloom_params(max(int(valid.sum()), 1))
    got = np.asarray(bloom_build(keys, valid, m_bits=m, k=k))
    want = bloom_build_ref(keys, valid, m_bits=m, k=k)
    assert np.array_equal(got, want)


def test_invalid_rows_do_not_contribute():
    """A masked-out key must not set bits: probing it gives (almost surely)
    False, and the array equals the build over the valid subset alone."""
    keys = np.arange(100, dtype=np.int32)
    valid = keys < 50
    m, k = bloom_params(50)
    bits = np.asarray(bloom_build(keys, valid, m_bits=m, k=k))
    only = np.asarray(bloom_build(keys[:50], m_bits=m, k=k))
    assert np.array_equal(bits, only)


def test_empty_build_rejects_everything():
    """The empty-build filter is all zeros and rejects every probe — the
    degenerate case the executor leans on for empty build sides."""
    bits = bloom_build(np.empty(0, np.int32), m_bits=256, k=3)
    assert int(np.asarray(bits).sum()) == 0
    probe = np.arange(1000, dtype=np.int32)
    assert not np.asarray(bloom_probe(probe, bits, k=3)).any()


def test_stacked_shape_roundtrip():
    """(p, cap) stacked key columns keep their shape through the probe."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 64, (4, 128)).astype(np.int32)
    m, k = bloom_params(64)
    bits = bloom_build(keys, m_bits=m, k=k)
    mask = bloom_probe(keys, bits, k=k)
    assert mask.shape == keys.shape
    assert bool(np.asarray(mask).all())
