"""SQL layer tests: executor correctness across strategies, adaptive stats,
re-optimization behaviour, aggregation, and the query suite."""

import numpy as np
import pytest

from repro.core.cost_model import JoinMethod, k0_threshold, CostParams
from repro.joins.aggregate import group_aggregate
from repro.sql import Executor, RelJoinStrategy, all_queries
from repro.sql.logical import Filter, Join, Scan
from repro.joins.ref import rows_as_set, rows_close


# catalog / strategies fixtures are session-scoped in conftest.py.


def _result_rows(res):
    return rows_as_set(res.table.to_numpy())


@pytest.mark.parametrize("qname", sorted(all_queries()))
def test_all_strategies_agree_on_results(catalog, strategies, qname):
    """Physical method selection must never change query results."""
    plan = all_queries()[qname]
    results = [Executor(catalog, s).execute(plan) for s in strategies]
    base = _result_rows(results[0])
    assert len(base) > 0, "degenerate query"
    for s, r in zip(strategies[1:], results[1:]):
        assert rows_close(_result_rows(r), base), s.name


def test_group_aggregate_matches_numpy(catalog):
    t = catalog.table("store_sales")
    out, _ = group_aggregate(t, "ss_store_sk", (("ss_quantity", "sum"),
                                                ("ss_quantity", "count")))
    got = out.to_numpy()
    flat = t.to_numpy()
    for i, key in enumerate(got["ss_store_sk"]):
        mask = flat["ss_store_sk"] == key
        assert got["sum_ss_quantity"][i] == flat["ss_quantity"][mask].sum()
        assert got["count_ss_quantity"][i] == mask.sum()
    # every live key appears exactly once
    assert len(np.unique(got["ss_store_sk"])) == len(got["ss_store_sk"])
    assert set(got["ss_store_sk"]) == set(np.unique(flat["ss_store_sk"]))


def test_reljoin_obeys_k0(catalog):
    """Every broadcast selection must satisfy k > k0; shuffles k <= k0."""
    strat = RelJoinStrategy(w=1.0)
    k0 = k0_threshold(CostParams(p=4, w=1.0))
    for qname, plan in all_queries().items():
        res = Executor(catalog, strat).execute(plan)
        for d in res.decisions:
            if d.selection.used_fallback or not d.selection.costs:
                continue
            big = max(d.left_stats.size_bytes, d.right_stats.size_bytes)
            small = min(d.left_stats.size_bytes, d.right_stats.size_bytes)
            k = big / max(small, 1)
            if d.selection.method is JoinMethod.BROADCAST_HASH:
                assert k > k0, (qname, k, k0)
            elif d.selection.method in (JoinMethod.SHUFFLE_HASH,
                                        JoinMethod.SHUFFLE_SORT):
                assert k <= k0, (qname, k, k0)


def test_adaptive_stats_are_runtime(catalog):
    """Join inputs that were materialized by an exchange must be selected
    with RUNTIME stats, in-stage filters with propagated estimates."""
    from repro.core.stats import StatsSource
    plan = all_queries()["q3_cross_channel"]
    res = Executor(catalog, RelJoinStrategy()).execute(plan)
    d = res.decisions[0]  # store_sales scan x aggregated catalog_sales
    assert d.left_stats.source is StatsSource.RUNTIME
    assert d.right_stats.source is StatsSource.RUNTIME
    # the aggregate's measured cardinality is the true group count
    assert d.right_stats.cardinality == pytest.approx(
        res.decisions[0].right_stats.cardinality)


def test_adaptive_beats_static_estimates(catalog):
    """With a badly biased catalog (est_error), static optimization makes
    different (worse) choices; adaptive mode is immune (paper §1, §2.3)."""
    plan = all_queries()["q3_cross_channel"]
    adaptive = Executor(catalog, RelJoinStrategy(), adaptive=True,
                        est_error=100.0).execute(plan)
    static = Executor(catalog, RelJoinStrategy(), adaptive=False,
                      est_error=100.0).execute(plan)
    assert rows_close(_result_rows(adaptive), _result_rows(static))
    # static sees inflated sizes -> k ~ unchanged but absolute sizes x100;
    # the aggregated build side estimate is what diverges: the static
    # optimizer cannot know the post-aggregation cardinality.
    d_ad, d_st = adaptive.decisions[0], static.decisions[0]
    assert d_ad.right_stats.size_bytes < d_st.right_stats.size_bytes


def test_filter_pushes_stats_not_rows(catalog):
    """Filters keep capacity static (mask only) but shrink measured stats."""
    ex = Executor(catalog, RelJoinStrategy())
    plan = Join(Filter(Scan("store_sales"), "ss_quantity", "lt", 10,
                       selectivity=0.09),
                Scan("customer"), "ss_customer_sk", "c_customer_sk")
    res = ex.execute(plan)
    d = res.decisions[0]
    full = catalog.table("store_sales").measure()
    assert d.left_stats.size_bytes < 0.2 * full.size_bytes


def test_workload_accounting_positive(catalog):
    for qname, plan in all_queries().items():
        res = Executor(catalog, RelJoinStrategy()).execute(plan)
        assert res.network_bytes >= 0
        assert res.local_bytes > 0
        assert res.workload(w=1.0) == pytest.approx(
            res.network_bytes + res.local_bytes)


def test_hint_respected(catalog):
    plan = Join(Scan("store_sales"), Scan("store"), "ss_store_sk",
                "s_store_sk", hint=JoinMethod.SHUFFLE_SORT)
    res = Executor(catalog, RelJoinStrategy()).execute(plan)
    assert res.methods() == [JoinMethod.SHUFFLE_SORT]


def test_skewed_catalog_still_correct(skewed_catalogs):
    """§3.7: data skew does not break selection or correctness."""
    cat_u, cat_s = skewed_catalogs
    plan = all_queries()["q1_star3"]
    ru = Executor(cat_u, RelJoinStrategy(),
                  capacity_factor=4.0).execute(plan)
    rs = Executor(cat_s, RelJoinStrategy(),
                  capacity_factor=4.0).execute(plan)
    assert ru.rows > 0 and rs.rows > 0
    # same *methods* chosen: cluster workload is skew-invariant
    assert ru.methods() == rs.methods()
