"""Distributed bloom build: per-device partial filters OR-reduced across
the mesh must be bit-identical to the global-view ``bloom_build`` — across
device counts. The 1-device mesh runs in every tier; the 8-device cases
run in the multi-device CI tier (XLA_FLAGS=--xla_force_host_platform_
device_count=8) and are skipped where fewer devices exist.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.cost_model import bloom_params
from repro.joins import from_numpy, partition_round_robin
from repro.joins.distributed import dist_bloom_build, make_join_mesh, place
from repro.kernels.bloom import bloom_build, bloom_build_ref


def _stacked(p, n=1000, seed=3, hole_frac=0.2):
    """Placed p-partition key table with a masked-out fraction of rows
    (post-filter survivors), plus sized bloom parameters."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(-(1 << 28), 1 << 28, n).astype(np.int32)
    t = from_numpy({"k": keys,
                    "payload": rng.integers(0, 99, n).astype(np.int32)})
    valid = np.asarray(t.valid) & (rng.random(n) >= hole_frac)
    t = t.with_valid(jnp.asarray(valid))
    mesh = make_join_mesh(p)
    stacked = place(partition_round_robin(t, p), mesh)
    m, k = bloom_params(len(np.unique(keys[valid])))
    return stacked, mesh, m, k


def _global_words(stacked, m, k):
    """Global-view build over the same (padded, masked) key material."""
    return np.asarray(bloom_build(np.asarray(stacked.column("k")),
                                  np.asarray(stacked.valid),
                                  m_bits=m, k=k))


def test_dist_build_bit_identical_to_global_single_device():
    stacked, mesh, m, k = _stacked(p=1)
    words = np.asarray(dist_bloom_build(stacked, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(stacked, m, k)).all()
    assert (words == bloom_build_ref(np.asarray(stacked.column("k")),
                                     np.asarray(stacked.valid),
                                     m_bits=m, k=k)).all()


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_build_bit_identical_to_global_8_devices():
    """The OR-reduce is partition-invariant: the 8-way distributed build
    equals the global build bit for bit — and therefore also equals the
    1-device distributed build (device-count invariance {1, 8})."""
    stacked, mesh, m, k = _stacked(p=8)
    words = np.asarray(dist_bloom_build(stacked, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(stacked, m, k)).all()


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_build_empty_partitions_are_neutral():
    """Partitions holding no live rows contribute the zero partial — the
    merged filter is unchanged by how rows land on devices."""
    stacked, mesh, m, k = _stacked(p=8, n=64, hole_frac=0.0)
    # Kill partitions 3..7 entirely.
    valid = np.asarray(stacked.valid).copy()
    valid[3:] = False
    dead = stacked.with_valid(jnp.asarray(valid))
    words = np.asarray(dist_bloom_build(dead, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(dead, m, k)).all()
