"""Distributed runtime-filter builds: every kind's per-device partial
payloads merged across the mesh must be bit-/value-identical to the
corresponding global-view build (``bloom_build`` / ``key_range`` /
``key_set``) — across device counts. The 1-device meshes run in every
tier; the 8-device cases run in the multi-device CI tier
(XLA_FLAGS=--xla_force_host_platform_device_count=8) and are skipped
where fewer devices exist.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.cost_model import bloom_params
from repro.core.psts import key_set
from repro.joins import from_numpy, partition_round_robin
from repro.joins.distributed import (dist_bloom_build, dist_key_set_build,
                                     dist_zone_map_build, make_join_mesh,
                                     place)
from repro.kernels.bloom import bloom_build, bloom_build_ref
from repro.kernels.zone_map import key_range_ref


def _stacked(p, n=1000, seed=3, hole_frac=0.2):
    """Placed p-partition key table with a masked-out fraction of rows
    (post-filter survivors), plus sized bloom parameters."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(-(1 << 28), 1 << 28, n).astype(np.int32)
    t = from_numpy({"k": keys,
                    "payload": rng.integers(0, 99, n).astype(np.int32)})
    valid = np.asarray(t.valid) & (rng.random(n) >= hole_frac)
    t = t.with_valid(jnp.asarray(valid))
    mesh = make_join_mesh(p)
    stacked = place(partition_round_robin(t, p), mesh)
    m, k = bloom_params(len(np.unique(keys[valid])))
    return stacked, mesh, m, k


def _global_words(stacked, m, k):
    """Global-view build over the same (padded, masked) key material."""
    return np.asarray(bloom_build(np.asarray(stacked.column("k")),
                                  np.asarray(stacked.valid),
                                  m_bits=m, k=k))


def test_dist_build_bit_identical_to_global_single_device():
    stacked, mesh, m, k = _stacked(p=1)
    words = np.asarray(dist_bloom_build(stacked, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(stacked, m, k)).all()
    assert (words == bloom_build_ref(np.asarray(stacked.column("k")),
                                     np.asarray(stacked.valid),
                                     m_bits=m, k=k)).all()


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_build_bit_identical_to_global_8_devices():
    """The OR-reduce is partition-invariant: the 8-way distributed build
    equals the global build bit for bit — and therefore also equals the
    1-device distributed build (device-count invariance {1, 8})."""
    stacked, mesh, m, k = _stacked(p=8)
    words = np.asarray(dist_bloom_build(stacked, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(stacked, m, k)).all()


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_build_empty_partitions_are_neutral():
    """Partitions holding no live rows contribute the zero partial — the
    merged filter is unchanged by how rows land on devices."""
    stacked, mesh, m, k = _stacked(p=8, n=64, hole_frac=0.0)
    # Kill partitions 3..7 entirely.
    valid = np.asarray(stacked.valid).copy()
    valid[3:] = False
    dead = stacked.with_valid(jnp.asarray(valid))
    words = np.asarray(dist_bloom_build(dead, "k", mesh, m_bits=m, k=k))
    assert (words == _global_words(dead, m, k)).all()


# ---------------------------------------------------------------------------
# Zone-map / key-set distributed builds (the other two kinds' contracts)
# ---------------------------------------------------------------------------


def _zone_and_set_case(p, n=1000, seed=5, hole_frac=0.3, dup=True,
                       permute=False):
    """Placed p-partition key table with duplicated keys (distributed
    dedupe must collapse them) and a masked-out fraction of rows."""
    rng = np.random.default_rng(seed)
    base = rng.integers(-(1 << 20), 1 << 20, n // (3 if dup else 1))
    keys = np.resize(base, n).astype(np.int32)    # heavy duplication
    if permute:
        keys = rng.permutation(keys)
    t = from_numpy({"k": keys})
    valid = np.asarray(t.valid) & (rng.random(n) >= hole_frac)
    t = t.with_valid(jnp.asarray(valid))
    mesh = make_join_mesh(p)
    return place(partition_round_robin(t, p), mesh), mesh


def _assert_matches_global(stacked, mesh):
    col = np.asarray(stacked.column("k"))
    valid = np.asarray(stacked.valid)
    got = np.asarray(dist_zone_map_build(stacked, "k", mesh))
    assert (got == key_range_ref(col, valid)).all()
    ks, n = dist_key_set_build(stacked, "k", mesh)
    gk, gn = key_set(stacked.column("k"), stacked.valid)
    assert int(n) == int(gn)
    assert (np.asarray(ks) == np.asarray(gk)).all()


def test_dist_zone_map_and_key_set_match_global_single_device():
    stacked, mesh = _zone_and_set_case(p=1)
    _assert_matches_global(stacked, mesh)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_zone_map_and_key_set_match_global_8_devices():
    """min/max and sorted set-union are partition-invariant merges: the
    8-way distributed builds equal the global builds value for value —
    and therefore also the 1-device builds (device-count invariance)."""
    stacked, mesh = _zone_and_set_case(p=8)
    _assert_matches_global(stacked, mesh)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (multi-device CI tier)")
def test_dist_builds_dup_and_order_invariant():
    """Permuting the input rows changes which device holds which keys —
    the merged payloads must not change (pure functions of the key set)."""
    a, mesh = _zone_and_set_case(p=8, seed=9, hole_frac=0.0)
    b, _ = _zone_and_set_case(p=8, seed=9, hole_frac=0.0, permute=True)
    za = np.asarray(dist_zone_map_build(a, "k", mesh))
    zb = np.asarray(dist_zone_map_build(b, "k", mesh))
    assert (za == zb).all()
    ka, na = dist_key_set_build(a, "k", mesh)
    kb, nb = dist_key_set_build(b, "k", mesh)
    assert int(na) == int(nb)
    assert (np.asarray(ka)[:int(na)] == np.asarray(kb)[:int(nb)]).all()


def test_dist_builds_empty_build_side():
    """All-invalid build -> the reject-everything payloads: the empty
    interval (lo > hi) and the empty key list (n = 0), matching the
    global-view degenerate-build contract."""
    stacked, mesh = _zone_and_set_case(p=1, n=64)
    dead = stacked.with_valid(jnp.zeros_like(stacked.valid))
    lo_hi = np.asarray(dist_zone_map_build(dead, "k", mesh))
    assert lo_hi[0] > lo_hi[1]
    ks, n = dist_key_set_build(dead, "k", mesh)
    assert int(n) == 0
    gk, gn = key_set(dead.column("k"), dead.valid)
    assert int(gn) == 0
    assert (np.asarray(ks) == np.asarray(gk)).all()
