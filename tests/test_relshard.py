"""RelShard planner tests: the paper's Eq.13 criterion driving sharding
strategy selection, decision audit, and adaptive re-planning."""

import pytest

from repro.configs import get_config
from repro.core.cost_model import CostParams, k0_threshold
from repro.core.relshard import (W_TPU_DEFAULT, ShardingPlan, plan_model,
                                 replan)
from repro.models.config import SHAPE_BY_NAME

MESH = (("data", 16), ("model", 16))
MESH_MP = (("pod", 2), ("data", 16), ("model", 16))


def test_small_vocab_replicates():
    # musicgen vocab=2048: tokens >> table -> broadcast analogue (k > k0).
    plan = plan_model(get_config("musicgen_large"), MESH,
                      SHAPE_BY_NAME["train_4k"])
    assert plan.embed_strategy == "replicate"
    d = [x for x in plan.decisions if x.op == "embedding"][0]
    assert d.k > d.k0
    assert d.cost_broadcast < d.cost_shuffle


def test_large_vocab_shards():
    # paligemma vocab=257216 -> vocab_parallel (k < k0).
    plan = plan_model(get_config("paligemma_3b"), MESH,
                      SHAPE_BY_NAME["train_4k"])
    assert plan.embed_strategy == "vocab_parallel"
    d = [x for x in plan.decisions if x.op == "embedding"][0]
    assert d.k <= d.k0


def test_k0_matches_cost_model():
    plan = plan_model(get_config("glm4_9b"), MESH, SHAPE_BY_NAME["train_4k"])
    k0 = k0_threshold(CostParams(p=16, w=plan.w))
    for d in plan.decisions:
        assert d.k0 == pytest.approx(k0)


def test_w_derived_from_chip_constants():
    plan = plan_model(get_config("glm4_9b"), MESH, SHAPE_BY_NAME["train_4k"])
    assert plan.w == pytest.approx(W_TPU_DEFAULT)
    assert plan.w == pytest.approx(819.0 / 50.0)


def test_moe_dispatch_decision():
    # qwen3: expert weights per layer ~9.7GB >> routed tokens -> shuffle.
    plan = plan_model(get_config("qwen3_moe_235b_a22b"), MESH,
                      SHAPE_BY_NAME["train_4k"])
    assert plan.moe_strategy == "expert_parallel"
    d = [x for x in plan.decisions if x.op == "moe_dispatch"][0]
    assert d.k <= d.k0


def test_decode_memory_gate():
    # decode: resident-weight feasibility decides (Algorithm 1's memory
    # gate); glm4's 2.3GB table fits the budget -> replicate.
    plan = plan_model(get_config("glm4_9b"), MESH,
                      SHAPE_BY_NAME["decode_32k"])
    assert plan.embed_strategy == "replicate"
    assert "decode" in plan.decisions[0].reason


def test_multi_pod_batch_axes():
    plan = plan_model(get_config("granite_8b"), MESH_MP,
                      SHAPE_BY_NAME["train_4k"])
    assert plan.batch_axes == ("pod", "data")
    assert plan.fsdp_axes == ("data",)


def test_explain_is_auditable():
    plan = plan_model(get_config("dbrx_132b"), MESH,
                      SHAPE_BY_NAME["train_4k"])
    text = plan.explain()
    assert "moe_dispatch" in text and "k0=" in text


def test_replan_responds_to_occupancy():
    """Stage-boundary re-optimization: a serving engine measuring low
    occupancy re-plans with the measured token count (adaptive stats)."""
    cfg = get_config("paligemma_3b")
    shape = SHAPE_BY_NAME["decode_32k"]
    plan = plan_model(cfg, MESH, shape)
    new = replan(plan, cfg, MESH, shape, measured_tokens=1)
    assert isinstance(new, ShardingPlan)
    # decisions were re-derived with tokens=1
    d = [x for x in new.decisions if x.op == "embedding"][0]
    assert d.size_a == 1 * cfg.d_model * 2


def test_train_vs_decode_regime_differs():
    """The same arch can broadcast in one regime and shard in another —
    the paper's central point that the decision is workload-relative."""
    cfg = get_config("glm4_9b")
    train_plan = plan_model(cfg, MESH, SHAPE_BY_NAME["train_4k"])
    decode_plan = plan_model(cfg, MESH, SHAPE_BY_NAME["decode_32k"])
    assert train_plan.embed_strategy == "vocab_parallel"
    assert decode_plan.embed_strategy == "replicate"
