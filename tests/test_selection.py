"""Tests for Algorithm 1 selection + baseline strategies + PSTS."""

import pytest
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.core import (CostParams, JoinMethod,
                        JoinProperties, JoinType, TableStats, compute_psts,
                        k0_threshold, select_absolute_size, select_forced,
                        select_join_method, selections_differ, unknown_stats)

MB = 2 ** 20
P = CostParams(p=20, w=1.0)  # paper testbed: k0 = 39


def _stats(size_mb, card=None):
    return TableStats(size_mb * MB, card if card is not None else size_mb * 1e4)


def test_hint_short_circuits():
    props = JoinProperties(hint=JoinMethod.SHUFFLE_SORT)
    sel = select_join_method(_stats(1000), _stats(1), props, P)
    assert sel.method is JoinMethod.SHUFFLE_SORT
    assert sel.reason == "user hint"


def test_relative_size_criterion():
    # k = 100 > k0 = 39 -> broadcast; k = 10 < 39 -> shuffle hash.
    sel = select_join_method(_stats(100), _stats(1), JoinProperties(), P)
    assert sel.method is JoinMethod.BROADCAST_HASH
    sel = select_join_method(_stats(10), _stats(1), JoinProperties(), P)
    assert sel.method is JoinMethod.SHUFFLE_HASH


def test_sides_swapped_when_right_larger():
    sel = select_join_method(_stats(1), _stats(100), JoinProperties(), P)
    assert sel.method is JoinMethod.BROADCAST_HASH
    assert sel.swapped_sides


def test_not_hashable_falls_to_sort():
    props = JoinProperties(hashable=False)
    sel = select_join_method(_stats(100), _stats(1), props, P)
    assert sel.method is JoinMethod.SHUFFLE_SORT


def test_non_equi_inner_prefers_cartesian():
    props = JoinProperties(equi=False, join_type=JoinType.INNER)
    sel = select_join_method(_stats(100, card=1e6), _stats(1, card=1e4),
                             props, P)
    # C_cartesian <= C_broadcastNL for a >> p.
    assert sel.method is JoinMethod.CARTESIAN


def test_non_equi_outer_requires_broadcast_nl():
    props = JoinProperties(equi=False, join_type=JoinType.FULL_OUTER)
    sel = select_join_method(_stats(100, card=1e6), _stats(1, card=1e4),
                             props, P)
    assert sel.method is JoinMethod.BROADCAST_NL


def test_invalid_stats_fall_back_to_absolute_size():
    sel = select_join_method(unknown_stats(), _stats(1), JoinProperties(), P)
    assert sel.used_fallback
    # AQE fallback: 1MB side would broadcast, but the unknown side dominates
    # role assignment; min side is 1MB <= 10MB -> broadcast under AQE rule.
    assert sel.method in (JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_SORT)


def test_watermark_gates_validity():
    huge = TableStats(200 * 1024 ** 3, 1e9)  # 200 GB > 100 GB watermark
    sel = select_join_method(huge, _stats(1), JoinProperties(), P)
    assert sel.used_fallback


def test_aqe_absolute_size_behaviour():
    # 1.10MB < 10MB threshold -> AQE broadcasts even when k < k0 (paper §5.4).
    left, right = _stats(5.0), _stats(1.10)
    aqe = select_absolute_size(left, right, JoinProperties())
    rel = select_join_method(left, right, JoinProperties(), P)
    assert aqe.method is JoinMethod.BROADCAST_HASH
    assert rel.method is JoinMethod.SHUFFLE_HASH  # k = 4.5 < 39
    assert selections_differ(aqe.method, rel.method)


def test_aqe_large_tables_sort():
    sel = select_absolute_size(_stats(100), _stats(50), JoinProperties())
    assert sel.method is JoinMethod.SHUFFLE_SORT


def test_forced_strategies():
    sel = select_forced(JoinMethod.SHUFFLE_SORT, _stats(10), _stats(1),
                        JoinProperties())
    assert sel.method is JoinMethod.SHUFFLE_SORT
    sel = select_forced(JoinMethod.SHUFFLE_HASH, _stats(10), _stats(1),
                        JoinProperties(hashable=False))
    assert sel.method is JoinMethod.SHUFFLE_SORT  # degrade like Alg. 1


@settings(max_examples=200, deadline=None)
@given(sa=st.floats(1e3, 1e11), sb=st.floats(1e3, 1e11),
       p=st.integers(2, 1024), w=st.floats(1e-3, 1e3))
def test_selection_matches_k0_rule(sa, sb, p, w):
    """For plain equi-joins Algorithm 1 must reduce to the Eq. 13 rule."""
    params = CostParams(p=p, w=w)
    big, small = max(sa, sb), min(sa, sb)
    sel = select_join_method(TableStats(sa, 1e6), TableStats(sb, 1e5),
                             JoinProperties(), params)
    k = big / small
    k0 = k0_threshold(params)
    if abs(k - k0) / k0 > 1e-6:
        expect = (JoinMethod.BROADCAST_HASH if k > k0
                  else JoinMethod.SHUFFLE_HASH)
        assert sel.method is expect


def test_psts_paper_structure():
    # 66 of 629 differ; strategy saves 419.9s of 2019s baseline -> PSTS ~1.98.
    n = 629
    base = [JoinMethod.BROADCAST_HASH] * n
    strat = list(base)
    for i in range(66):
        strat[i] = JoinMethod.SHUFFLE_HASH
    baseline_time = 419.9 / 0.208  # 20.8% reduction
    rep = compute_psts(strat, base, baseline_time - 419.9, baseline_time)
    assert rep.n_join_diff == 66
    assert rep.pct_join_diff == pytest.approx(10.5, abs=0.1)
    assert rep.pct_time_diff == pytest.approx(20.8, abs=0.1)
    assert rep.psts == pytest.approx(1.98, abs=0.02)


def test_psts_zero_when_identical():
    ms = [JoinMethod.SHUFFLE_HASH] * 5
    rep = compute_psts(ms, ms, 10.0, 10.0)
    assert rep.psts == 0.0 and rep.n_join_diff == 0


def test_shuffle_variants_not_counted_as_diff():
    assert not selections_differ(JoinMethod.SHUFFLE_SORT,
                                 JoinMethod.SHUFFLE_HASH)
    assert selections_differ(JoinMethod.BROADCAST_HASH,
                             JoinMethod.SHUFFLE_HASH)
