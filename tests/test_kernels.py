"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the exact TPU kernel body on CPU)."""

import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# tiled_probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("na,nb", [(1, 1), (7, 5), (8, 128), (100, 100),
                                   (256, 512), (300, 700), (1000, 64),
                                   (2048, 2048)])
def test_probe_matches_ref_shapes(na, nb):
    rng = np.random.default_rng(na * 1000 + nb)
    a = rng.integers(0, max(nb // 2, 2), size=na).astype(np.int32)
    b = rng.permutation(max(nb, 1)).astype(np.int32)[:nb]
    got = ops.probe(jnp.asarray(a), jnp.asarray(b))
    want = ref.tiled_probe_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("ta,tb", [(8, 128), (64, 128), (256, 512)])
def test_probe_tile_sweep(ta, tb):
    rng = np.random.default_rng(ta + tb)
    a = rng.integers(-1, 50, size=333).astype(np.int32)
    b = rng.integers(0, 50, size=217).astype(np.int32)
    got = ops.probe(jnp.asarray(a), jnp.asarray(b))
    want = ref.tiled_probe_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_probe_first_match_semantics():
    a = jnp.asarray([5, 9, 5], jnp.int32)
    b = jnp.asarray([1, 5, 3, 5], jnp.int32)  # duplicate build keys
    got = np.asarray(ops.probe(a, b))
    np.testing.assert_array_equal(got, [1, -1, 1])


def test_probe_sentinels_never_match():
    a = jnp.asarray([-1, -1, 3], jnp.int32)
    b = jnp.asarray([-2, 3, -2], jnp.int32)
    got = np.asarray(ops.probe(a, b))
    np.testing.assert_array_equal(got, [-1, -1, 1])


def test_probe_rejects_bad_dtype():
    with pytest.raises(TypeError):
        ops.probe(jnp.zeros(4, jnp.float32), jnp.zeros(4, jnp.int32))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-3, 40), min_size=1, max_size=300),
       st.lists(st.integers(0, 40), min_size=1, max_size=300))
def test_probe_property(avals, bvals):
    a = jnp.asarray(avals, jnp.int32)
    b = jnp.asarray(bvals, jnp.int32)
    got = np.asarray(ops.probe(a, b))
    want = np.asarray(ref.tiled_probe_ref(a, b))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# partition_hist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,nd", [(1, 2), (100, 4), (1024, 8), (5000, 16),
                                  (10000, 128), (3, 1)])
def test_hist_matches_ref(n, nd):
    rng = np.random.default_rng(n + nd)
    d = rng.integers(-1, nd, size=n).astype(np.int32)  # includes invalid -1
    got = ops.hist(jnp.asarray(d), nd)
    want = ref.partition_hist_ref(jnp.asarray(d), nd)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hist_total_conservation():
    rng = np.random.default_rng(0)
    d = rng.integers(0, 7, size=999).astype(np.int32)
    got = np.asarray(ops.hist(jnp.asarray(d), 7))
    assert got.sum() == 999


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-1, 15), min_size=1, max_size=500),
       st.integers(1, 16))
def test_hist_property(dvals, nd):
    d = jnp.asarray([min(v, nd - 1) for v in dvals], jnp.int32)
    got = np.asarray(ops.hist(d, nd))
    want = np.asarray(ref.partition_hist_ref(d, nd))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# bitonic_sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 64, 256, 1024, 4096])
def test_bitonic_sorts_pow2_tiles(n):
    rng = np.random.default_rng(n)
    k = rng.integers(0, 1 << 20, size=n).astype(np.int32)
    v = np.arange(n, dtype=np.int32)
    gk, gv = ops.sort_pairs(jnp.asarray(k), jnp.asarray(v))
    gk, gv = np.asarray(gk), np.asarray(gv)
    assert (np.diff(gk) >= 0).all()
    # Permutation correctness: the carried payload must still address the
    # original key at every output slot.
    np.testing.assert_array_equal(k[gv], gk)


def test_bitonic_with_duplicates_and_negatives():
    k = np.asarray([3, -1, 3, 0, -5, 3, 7, -1], np.int32)
    v = np.arange(8, dtype=np.int32)
    gk, gv = ops.sort_pairs(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(gk), np.sort(k))
    np.testing.assert_array_equal(k[np.asarray(gv)], np.asarray(gk))


def test_sort_pairs_non_pow2_fallback():
    k = np.asarray([5, 1, 4, 1, 3], np.int32)
    v = np.arange(5, dtype=np.int32)
    gk, gv = ops.sort_pairs(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(gk), np.sort(k))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 9), st.integers(0, 2 ** 31 - 2))
def test_bitonic_property(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    k = rng.integers(-100, 100, size=n).astype(np.int32)
    v = np.arange(n, dtype=np.int32)
    gk, gv = ops.sort_pairs(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(gk), np.sort(k))
    np.testing.assert_array_equal(k[np.asarray(gv)], np.asarray(gk))
