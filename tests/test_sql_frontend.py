"""SQL text front end: tokenizer/parser/binder units, the q1-q23 SQL
round-trip pin (text and hand-built plans must stay signature-identical),
signature literal regression, and the printer property test — random valid
plans print to SQL, reparse to the same signature, and execute to the same
rows.
"""

import random

import numpy as np
import pytest

from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st
from repro.sql import (Executor, RelJoinStrategy, generate, parse, parse_sql,
                       to_sql, tokenize)
from repro.sql.binder import SqlBindError
from repro.sql.datagen import COLUMN_DOMAINS, TABLE_COLUMNS
from repro.sql.logical import (Aggregate, Filter, Join, Scan,
                               effective_selectivity, signature, walk)
from repro.sql.parser import (AggCall, ColRef, ColumnEquals, Comparison,
                              InList, InSubquery, SqlSyntaxError)
from repro.sql.queries import HAND_BUILT, SQL_TEXTS, text_queries


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------


def test_tokenize_kinds_and_positions():
    toks = tokenize("SELECT x FROM t WHERE a <= -1.5e2")
    kinds = [(t.kind, t.text) for t in toks]
    assert ("symbol", "<=") in kinds
    assert ("number", "-1.5e2") in kinds
    assert kinds[-1] == ("eof", "")
    assert toks[0].pos == 0 and toks[1].pos == 7


def test_tokenize_rejects_unknown_characters():
    with pytest.raises(SqlSyntaxError, match="unrecognized character"):
        tokenize("SELECT @ FROM t")


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------


def test_parse_select_items_and_group_by():
    stmt = parse("SELECT k, SUM(v), AVG(w) FROM t GROUP BY k")
    assert stmt.items == (ColRef("k"), AggCall("SUM", "v"),
                          AggCall("AVG", "w"))
    assert stmt.group_by == "k" and not stmt.star


def test_parse_where_predicates():
    stmt = parse("SELECT * FROM t WHERE a = 1 AND b BETWEEN 2 AND 3"
                 " AND c IN (4, 5) AND t.d = u.e")
    a, b, c, d = stmt.where
    assert a == Comparison(ColRef("a"), "eq", 1.0)
    assert b == Comparison(ColRef("b"), "between", 2.0, 3.0)
    assert c == InList(ColRef("c"), (4.0, 5.0))
    assert d == ColumnEquals(ColRef("d", "t"), ColRef("e", "u"))


def test_parse_in_subquery_and_not_in():
    stmt = parse("SELECT * FROM t WHERE a NOT IN (SELECT b FROM u)")
    (pred,) = stmt.where
    assert isinstance(pred, InSubquery) and pred.negated
    assert pred.query.items == (ColRef("b"),)


def test_parse_join_kinds_and_aliases():
    stmt = parse("SELECT * FROM t AS x LEFT OUTER JOIN u y ON a = b JOIN"
                 " (SELECT * FROM v) AS z ON c = d")
    (tree,) = stmt.froms
    assert tree.primary.alias == "x"
    assert [j.kind for j in tree.joins] == ["left", "inner"]
    assert tree.joins[1].ref.alias == "z"


@pytest.mark.parametrize("bad, msg", [
    ("SELECT * FROM t extra garbage ON", "trailing input"),
    ("SELECT * FROM t WHERE a NOT = 1", "NOT is only supported"),
    ("SELECT * FROM t WHERE a < b", "support only ="),
    ("SELECT * FROM t WHERE a NOT IN (1, 2)", "only supported with a"),
    ("SELECT FROM t", "expected a column name"),
    ("SELECT * FROM t WHERE a BETWEEN 1", "expected AND"),
])
def test_parse_errors(bad, msg):
    with pytest.raises(SqlSyntaxError, match=msg):
        parse(bad)


# ---------------------------------------------------------------------------
# Binder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad, msg", [
    ("SELECT * FROM nope", "unknown table"),
    ("SELECT nope FROM item", "unknown column"),
    ("SELECT * FROM item WHERE nope = 1", "unknown column"),
    ("SELECT SUM(i_price) FROM item", "requires GROUP BY"),
    ("SELECT * FROM item, store", "unjoined"),
    ("SELECT * FROM item WHERE i_item_sk = i_brand", "one relation"),
    ("SELECT i_brand FROM item GROUP BY i_category",
     "first select item must be the group key"),
    ("SELECT i_category, i_brand FROM item GROUP BY i_category",
     "must be aggregates"),
    ("SELECT i_category FROM item GROUP BY i_category",
     "at least one aggregate"),
    ("SELECT * FROM item WHERE i_item_sk IN (SELECT * FROM store_sales)",
     "first select item"),
    ("SELECT * FROM store_sales, store_sales WHERE ss_quantity = 1",
     "ambiguous column"),
])
def test_bind_errors(bad, msg):
    with pytest.raises(SqlBindError, match=msg):
        parse_sql(bad)


def test_bind_qualified_columns_and_on_swap():
    plan = parse_sql("SELECT * FROM store_sales"
                     " JOIN item ON item.i_item_sk = store_sales.ss_item_sk")
    assert isinstance(plan, Join)
    # written build-first; the binder re-orients probe -> build
    assert (plan.left_key, plan.right_key) == ("ss_item_sk", "i_item_sk")


def test_bind_bakes_derived_selectivity():
    plan = parse_sql("SELECT * FROM date_dim WHERE d_month = 6")
    assert isinstance(plan, Filter)
    assert plan.selectivity == pytest.approx(1 / 12)


# ---------------------------------------------------------------------------
# q1-q23 round-trip: the SQL texts and the hand-built constructors are the
# same plans — same signature, same effective selectivities.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qname", sorted(HAND_BUILT))
def test_sql_matches_hand_built(qname):
    hand = HAND_BUILT[qname]()
    parsed = parse_sql(SQL_TEXTS[qname])
    assert signature(parsed) == signature(hand)
    hand_sel = [effective_selectivity(f) for f in walk(hand)
                if isinstance(f, Filter)]
    parsed_sel = [effective_selectivity(f) for f in walk(parsed)
                  if isinstance(f, Filter)]
    assert parsed_sel == pytest.approx(hand_sel)


def test_text_queries_are_the_sql_only_suite():
    tq = text_queries()
    assert len(tq) >= 8
    assert set(tq) == set(SQL_TEXTS) - set(HAND_BUILT)
    assert all(name not in HAND_BUILT for name in tq)


# ---------------------------------------------------------------------------
# Signature literal regression: plans differing only in a constant must not
# collide (the pre-fix signature dropped filter literals entirely).
# ---------------------------------------------------------------------------


def test_signature_distinguishes_filter_literals():
    base = Scan("item")
    assert (signature(Filter(base, "i_category", "lt", 3))
            != signature(Filter(base, "i_category", "lt", 4)))
    assert (signature(Filter(base, "i_category", "between", 1, 3))
            != signature(Filter(base, "i_category", "between", 1, 4)))
    assert (signature(Filter(base, "i_category", "in", values=(1., 2.)))
            != signature(Filter(base, "i_category", "in", values=(1., 3.))))
    # and the op is still part of the tag
    assert (signature(Filter(base, "i_category", "lt", 3))
            != signature(Filter(base, "i_category", "le", 3)))


# ---------------------------------------------------------------------------
# Schema metadata guards: the static TABLE_COLUMNS / COLUMN_DOMAINS tables
# the binder and selectivity estimator trust must match what generate()
# actually builds.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_catalog():
    return generate(scale=0.02, p=2, seed=7)


def test_table_columns_match_generate(small_catalog):
    got = {name: tuple(t.columns) for name, t in
           small_catalog.tables.items()}
    assert got == dict(TABLE_COLUMNS)


def test_column_domains_bound_generated_data(small_catalog):
    for col, (lo, hi, integral) in COLUMN_DOMAINS.items():
        table = next(t for t, cols in TABLE_COLUMNS.items() if col in cols)
        arr = np.asarray(small_catalog.tables[table].column(col))
        valid = np.asarray(small_catalog.tables[table].valid)
        vals = arr[valid]
        assert vals.min() >= lo and vals.max() < hi, col
        if integral:
            assert np.all(vals == np.floor(vals)), col


# ---------------------------------------------------------------------------
# Printer property test: random valid plans -> SQL -> reparse gives the
# same signature and the same executed rows on a small catalog.
# ---------------------------------------------------------------------------

_FACT_DIMS = [("ss_item_sk", "item", "i_item_sk"),
              ("ss_store_sk", "store", "s_store_sk"),
              ("ss_customer_sk", "customer", "c_customer_sk"),
              ("ss_sold_date_sk", "date_dim", "d_date_sk"),
              ("ss_promo_sk", "promotion", "p_promo_sk")]
_FILTER_COLS = {"store_sales": ("ss_quantity", 1, 100),
                "item": ("i_category", 0, 10),
                "store": ("s_state", 0, 12),
                "customer": ("c_region", 0, 8),
                "date_dim": ("d_moy", 0, 30),
                "promotion": ("p_channel", 0, 4)}
_GROUP_KEYS = {"store_sales": "ss_quantity", "item": "i_brand",
               "store": "s_state", "customer": "c_region",
               "date_dim": "d_month", "promotion": "p_channel"}
_AGG_COLS = ("ss_sales_price", "ss_net_profit", "ss_quantity")
_OPS = ("eq", "ne", "lt", "le", "gt", "ge", "between", "in")

_prop_catalog = None


def _property_catalog():
    global _prop_catalog
    if _prop_catalog is None:
        _prop_catalog = generate(scale=0.02, p=2, seed=7)
    return _prop_catalog


def _random_leaf(table, rng):
    node = Scan(table)
    if rng.random() < 0.6:
        col, lo, hi = _FILTER_COLS[table]
        op = rng.choice(_OPS)
        if op == "between":
            a, b = sorted((rng.randint(lo, hi - 1), rng.randint(lo, hi - 1)))
            node = Filter(node, col, "between", a, b)
        elif op == "in":
            vals = tuple(sorted(rng.sample(range(lo, hi),
                                           rng.randint(1, 3))))
            node = Filter(node, col, "in", values=vals)
        else:
            node = Filter(node, col, op, rng.randint(lo, hi - 1))
    return node


def _random_plan(rng):
    dims = rng.sample(_FACT_DIMS, rng.randint(0, 2))
    node = _random_leaf("store_sales", rng)
    for fk, dim, pk in dims:
        node = Join(node, _random_leaf(dim, rng), fk, pk)
    if rng.random() < 0.7:
        key = _GROUP_KEYS[rng.choice(["store_sales"]
                                     + [d[1] for d in dims])]
        agg_op = rng.choice(("sum", "count", "min", "max", "mean"))
        node = Aggregate(node, key, ((rng.choice(_AGG_COLS), agg_op),))
    return node


def _rows(result):
    # to_numpy() already drops invalid slots; only row order could differ,
    # and identical plans execute deterministically.
    return {c: np.asarray(a) for c, a in result.table.to_numpy().items()}


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_print_reparse_preserves_signature_and_result(seed):
    rng = random.Random(seed)
    plan = _random_plan(rng)
    reparsed = parse_sql(to_sql(plan))
    assert signature(reparsed) == signature(plan)

    catalog = _property_catalog()
    r1 = Executor(catalog, RelJoinStrategy()).execute(plan)
    r2 = Executor(catalog, RelJoinStrategy()).execute(reparsed)
    rows1, rows2 = _rows(r1), _rows(r2)
    assert rows1.keys() == rows2.keys()
    for col in rows1:
        np.testing.assert_allclose(rows1[col], rows2[col], rtol=1e-6,
                                   err_msg=col)
