"""Differential-testing harness: every distributed join method — including
the skew-mitigating SALTED_SHUFFLE_HASH — against the pure-numpy oracle
(joins/ref.py) on a grid of adversarial inputs:

  * Zipf-skewed probe keys (mild and extreme),
  * all-duplicate probe keys (matching and non-matching),
  * empty probe / empty build / both empty,
  * fully disjoint key ranges (no matches),
  * single-partition (p=1) vs multi-partition (p=8) layouts,

asserting row-multiset equality in every cell. All tables share one static
capacity per side so XLA compiles one shape per (method, p) cell, not one
per case. Capacity overflow (the deliberately skewed cases exceed the
default slot budget) is absorbed by the same geometric-doubling retry the
executor uses — the harness thereby also exercises that contract at the
method level.

A second grid runs every method x case with the runtime bloom prefilter
(FilteredStrategy's data path) on the probe side, asserting equality with
the *unfiltered* oracle — including the empty-build-side case, where the
filter rejects everything and the result is empty rather than a crash.

A deterministic property layer (``hypothesis_compat`` shim — the real
hypothesis package, when installed) fuzzes sizes/skew/seed across all
methods with the same fixed shapes.
"""

import zlib

import numpy as np
import pytest
from helpers.hypothesis_compat import given, settings
from helpers.hypothesis_compat import strategies as st

from repro.core.cost_model import JoinMethod, bloom_params
from repro.joins import from_numpy, partition_round_robin, run_equi_join
from repro.joins.methods import (HypercubeLink, HypercubeSpec,
                                 hypercube_multiway_join)
from repro.joins.ref import ref_equi_join, ref_multiway_join, rows_as_set
from repro.kernels.bloom import bloom_build, bloom_probe
from repro.sql.datagen import _zipf_fks

ALL_METHODS = [JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_HASH,
               JoinMethod.SALTED_SHUFFLE_HASH, JoinMethod.SHUFFLE_SORT,
               JoinMethod.BROADCAST_NL, JoinMethod.CARTESIAN]
HASH_FAMILY = [JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_HASH,
               JoinMethod.SALTED_SHUFFLE_HASH, JoinMethod.SHUFFLE_SORT]

#: Shared static capacities: every case pads to these, so each (method, p)
#: cell compiles once and the grid stays cheap on CPU.
CAP_A, CAP_B = 256, 64
NB = 48  # build keys live in [0, NB)


def _case(name, rng):
    """Adversarial (probe_keys, build_keys) pairs."""
    build = rng.permutation(NB).astype(np.int32)
    if name == "uniform":
        return rng.integers(0, NB, 200).astype(np.int32), build
    if name == "zipf_mild":
        return _zipf_fks(rng, 200, NB, 1.2), build
    if name == "zipf_extreme":
        return _zipf_fks(rng, 200, NB, 2.0), build
    if name == "all_dup_match":
        return np.full(200, int(build[0]), np.int32), build
    if name == "all_dup_nomatch":
        return np.full(200, NB + 17, np.int32), build
    if name == "no_overlap":
        return rng.integers(NB, 2 * NB, 200).astype(np.int32), build
    if name == "empty_probe":
        return np.empty(0, np.int32), build
    if name == "empty_build":
        return rng.integers(0, NB, 200).astype(np.int32), np.empty(0, np.int32)
    if name == "both_empty":
        return np.empty(0, np.int32), np.empty(0, np.int32)
    raise ValueError(name)


CASES = ("uniform", "zipf_mild", "zipf_extreme", "all_dup_match",
         "all_dup_nomatch", "no_overlap", "empty_probe", "empty_build",
         "both_empty")


def _tables(a_keys, b_keys, p):
    """(a, b, A, B): unstacked oracles + p-partitioned engine tables with
    integer payloads (exact multiset equality, no float tolerance)."""
    a = from_numpy({"k": a_keys,
                    "v": np.arange(len(a_keys), dtype=np.int32)},
                   capacity=CAP_A)
    b = from_numpy({"k": b_keys,
                    "payload": (np.arange(len(b_keys), dtype=np.int32) * 7)},
                   capacity=CAP_B)
    return a, b, partition_round_robin(a, p), partition_round_robin(b, p)


def _run_with_retry(method, A, B, join_type="inner", salt_r=3):
    """Method-level mirror of Executor._run_join_with_retry: double the slot
    capacity factor until no exchange overflows (bounded attempts)."""
    factor = 2.0
    for _ in range(6):
        out, rep = run_equi_join(method, A, B, "k", "k", join_type=join_type,
                                 capacity_factor=factor, salt_r=salt_r)
        if all(e.overflow_rows == 0 for e in rep.exchanges):
            return out, rep
        factor *= 2
    raise AssertionError(f"{method} overflow persisted after retries")


@pytest.mark.parametrize("p", [1, 8])
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_differential_inner(method, case, p):
    """Inner-join grid: every method must equal the oracle's row multiset."""
    # crc32, not hash(): builtin str hashing is randomized per process and
    # would silently defeat the deterministic-grid promise.
    rng = np.random.default_rng(zlib.crc32(f"{case}/{p}".encode()))
    a_keys, b_keys = _case(case, rng)
    a, b, A, B = _tables(a_keys, b_keys, p)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    out, rep = _run_with_retry(method, A, B)
    assert rows_as_set(out.to_numpy()) == want, (method, case, p)
    assert rep.output_rows == len(want)


@pytest.mark.parametrize("jt", ["inner", "left_outer", "left_semi",
                                "left_anti"])
@pytest.mark.parametrize("method", HASH_FAMILY)
def test_differential_join_types_on_skew(method, jt):
    """All join types survive Zipf skew on every hash-family method."""
    rng = np.random.default_rng(99)
    a_keys, b_keys = _case("zipf_extreme", rng)
    a, b, A, B = _tables(a_keys, b_keys, 8)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k",
                                     join_type=jt))
    out, _ = _run_with_retry(method, A, B, join_type=jt)
    assert rows_as_set(out.to_numpy()) == want, (method, jt)


@pytest.mark.parametrize("salt_r", [2, 5, 8])
def test_salted_agrees_for_any_salt_count(salt_r):
    """The salt bucket count r is a pure performance knob — results must be
    invariant to it (including r > p)."""
    rng = np.random.default_rng(7)
    a_keys, b_keys = _case("zipf_mild", rng)
    a, b, A, B = _tables(a_keys, b_keys, 4)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    out, _ = _run_with_retry(JoinMethod.SALTED_SHUFFLE_HASH, A, B,
                             salt_r=salt_r)
    assert rows_as_set(out.to_numpy()) == want


def _bloom_prefilter(A, B, bits_per_key: int = 10):
    """Mirror of Executor._apply_runtime_filter at the method level: build a
    bloom over B's valid keys, mask A's valid rows ahead of the join — the
    FilteredStrategy data path without the cost gate."""
    nb = int(np.asarray(B.valid).sum())
    m_bits, k = bloom_params(nb, bits_per_key)
    bits = bloom_build(B.column("k"), B.valid, m_bits=m_bits, k=k)
    keep = bloom_probe(A.column("k"), bits, k=k)
    return A.with_valid(A.valid & keep)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_differential_inner_with_runtime_filter(method, case, p=8):
    """FilteredStrategy's data path on the full adversarial grid: a bloom
    prefilter on the probe side must leave every method's inner-join result
    equal to the *unfiltered* oracle (no false negatives means no lost
    matches; false positives are dropped by the join itself). The
    empty-build cases double as the filter-rejects-everything path: the
    result is empty, never a crash."""
    rng = np.random.default_rng(zlib.crc32(f"filtered/{case}/{p}".encode()))
    a_keys, b_keys = _case(case, rng)
    a, b, A, B = _tables(a_keys, b_keys, p)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    out, _ = _run_with_retry(method, _bloom_prefilter(A, B), B)
    assert rows_as_set(out.to_numpy()) == want, (method, case)


@pytest.mark.parametrize("jt", ["inner", "left_semi"])
@pytest.mark.parametrize("method", HASH_FAMILY)
def test_runtime_filter_join_types(method, jt):
    """The join types a probe-side filter is semantics-free for (the
    executor's _FILTERABLE_TYPES gate) stay oracle-equal under it."""
    rng = np.random.default_rng(23)
    a_keys, b_keys = _case("zipf_mild", rng)
    a, b, A, B = _tables(a_keys, b_keys, 8)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k",
                                     join_type=jt))
    out, _ = _run_with_retry(method, _bloom_prefilter(A, B), B, join_type=jt)
    assert rows_as_set(out.to_numpy()) == want, (method, jt)


def test_runtime_filter_empty_build_yields_empty_result():
    """Filter from an empty build rejects every probe row: the join runs on
    an all-invalid probe side and returns the empty result, no crash."""
    rng = np.random.default_rng(5)
    a_keys, _ = _case("uniform", rng)
    a, b, A, B = _tables(a_keys, np.empty(0, np.int32), 8)
    for method in ALL_METHODS:
        out, rep = _run_with_retry(method, _bloom_prefilter(A, B), B)
        assert out.count() == 0, method
        assert rep.output_rows == 0, method


@settings(max_examples=6, deadline=None)
@given(na=st.integers(0, 220), nb=st.integers(1, NB),
       skew_x10=st.integers(0, 22), seed=st.integers(0, 10_000))
def test_fuzz_methods_agree(na, nb, skew_x10, seed):
    """Property layer: random sizes x skew x seed, every method vs oracle.
    Shapes stay fixed (shared capacities), so examples don't recompile."""
    rng = np.random.default_rng(seed)
    build = rng.permutation(nb).astype(np.int32)
    s = skew_x10 / 10.0
    probe = (_zipf_fks(rng, na, nb, s) if s > 0
             else rng.integers(0, nb, na).astype(np.int32))
    a, b, A, B = _tables(probe, build, 4)
    want = rows_as_set(ref_equi_join(a.to_numpy(), b.to_numpy(), "k", "k"))
    for method in ALL_METHODS:
        out, _ = _run_with_retry(method, A, B)
        assert rows_as_set(out.to_numpy()) == want, method


# ---------------------------------------------------------------------------
# Hypercube multi-way join (cyclic join graphs) vs the oracle.
# ---------------------------------------------------------------------------

#: Triangle geometry: R(ra, rb, v) x S(sb -> s_c) x T(ta -> t_c), closed by
#: the check s_c == t_c over a small shared domain (so some rows survive).
NT3, NS3, NC3 = 20, 24, 4
CAP_CUBE = 192


def _cube_case(name, rng):
    """Adversarial (probe, build...) column dicts for the multi-way grid."""
    s = {"sb": np.arange(NS3, dtype=np.int32),
         "s_c": rng.integers(0, NC3, NS3).astype(np.int32)}
    t = {"ta": np.arange(NT3, dtype=np.int32),
         "t_c": rng.integers(0, NC3, NT3).astype(np.int32)}
    if name == "skewed_key":
        ra, rb = _zipf_fks(rng, 160, NT3, 1.8), _zipf_fks(rng, 160, NS3, 1.8)
    else:
        ra = rng.integers(0, NT3, 160).astype(np.int32)
        rb = rng.integers(0, NS3, 160).astype(np.int32)
    if name == "empty_relation":
        s = {"sb": np.empty(0, np.int32), "s_c": np.empty(0, np.int32)}
    r = {"ra": ra, "rb": rb, "v": np.arange(len(ra), dtype=np.int32)}
    if name == "clique":
        # Fourth relation on a third variable + a second closing check.
        r["rc"] = rng.integers(0, NC3, 160).astype(np.int32)
        u = {"uc": np.arange(NC3, dtype=np.int32),
             "u_c": rng.integers(0, NC3, NC3).astype(np.int32)}
        return r, s, t, u
    return r, s, t


def _cube_spec(name, dims):
    """The physical plan matching _cube_case: axis 0 = variable a (R, T),
    axis 1 = variable b (R, S); the clique adds axis 2 = variable c (R, U)
    and a second closing check chaining through U's payload."""
    links = (HypercubeLink(1, "rb", "sb"), HypercubeLink(2, "ra", "ta"))
    checks = (("s_c", "t_c"),)
    axis_keys = [((0, "ra"), (1, "rb")), ((1, "sb"),), ((0, "ta"),)]
    if name == "clique":
        axis_keys[0] = ((0, "ra"), (1, "rb"), (2, "rc"))
        axis_keys.append(((2, "uc"),))
        links += (HypercubeLink(3, "rc", "uc"),)
        checks += (("t_c", "u_c"),)
    return HypercubeSpec(dims=tuple(dims), axis_keys=tuple(axis_keys),
                         links=links, checks=checks)


def _run_cube_with_retry(tables, spec, use_kernel=False):
    factor = 2.0
    for _ in range(6):
        out, rep = hypercube_multiway_join(tables, spec,
                                           capacity_factor=factor,
                                           use_kernel=use_kernel)
        if all(e.overflow_rows == 0 for e in rep.exchanges):
            return out, rep
        factor *= 2
    raise AssertionError("hypercube overflow persisted after retries")


def _cube_tables(raw, p):
    return [partition_round_robin(from_numpy(c, capacity=CAP_CUBE), p)
            for c in raw]


def _cube_dims(name, p):
    if p == 1:
        return (1,) * (3 if name == "clique" else 2)
    return (2, 2, 2) if name == "clique" else (2, 4)


CUBE_CASES = ("triangle", "clique", "empty_relation", "skewed_key")


@pytest.mark.parametrize("p", [1, 8])
@pytest.mark.parametrize("case", CUBE_CASES)
def test_differential_hypercube(case, p):
    """Multi-way grid: the hypercube join must equal the sequential
    probe-then-filter oracle's row multiset on every cyclic shape,
    including an empty build relation (empty result, no crash)."""
    rng = np.random.default_rng(zlib.crc32(f"cube/{case}/{p}".encode()))
    raw = _cube_case(case, rng)
    spec = _cube_spec(case, _cube_dims(case, p))
    want = rows_as_set(ref_multiway_join(
        raw, [(lk.build, lk.probe_col, lk.build_col) for lk in spec.links],
        spec.checks))
    out, rep = _run_cube_with_retry(_cube_tables(raw, p), spec)
    assert rows_as_set(out.to_numpy()) == want, (case, p)
    assert rep.output_rows == len(want)
    if case == "empty_relation":
        assert not want


@pytest.mark.parametrize("use_kernel", [False, True])
def test_hypercube_cube_vs_flat_meshes_identical(use_kernel):
    """The cube shape is a pure performance knob: every factorization of p
    — cube, flat-by-a, flat-by-b — and the fused-kernel probe must yield
    the identical row multiset."""
    rng = np.random.default_rng(zlib.crc32(b"cube/mesh"))
    raw = _cube_case("triangle", rng)
    outs = []
    for dims in [(2, 4), (4, 2), (8, 1), (1, 8)]:
        out, _ = _run_cube_with_retry(_cube_tables(raw, 8),
                                      _cube_spec("triangle", dims),
                                      use_kernel=use_kernel)
        outs.append(rows_as_set(out.to_numpy()))
    assert outs[0] == outs[1] == outs[2] == outs[3]
    assert outs[0] == rows_as_set(ref_multiway_join(
        raw, [(1, "rb", "sb"), (2, "ra", "ta")], (("s_c", "t_c"),)))
