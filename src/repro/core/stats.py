"""Dataset statistics used by the RelJoin cost model (paper §2.3).

The cost model needs exactly two statistics per dataset: *size* in bytes and
*cardinality* in rows (paper §4.1 Step 1: "the required statistics are the
size and cardinality of the output dataset"). Statistics are either

  * ``ESTIMATED`` — statically analyzed along the logical plan, or
  * ``RUNTIME``   — measured at a data-exchange boundary (adaptive runtime
    statistics, §2.3/§4.1), which supersede estimates.

A *watermark* (default 100 GB, §4.4) caps the size a statistic may take while
still being considered valid; lazily-initialized "very large number" defaults
from sources without stats are thereby rejected and the optimizer falls back
to the platform's original absolute-size strategy for that join.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Dict, Iterable, Sequence, Tuple

#: Paper §4.4: default watermark = 100 GB.
DEFAULT_WATERMARK_BYTES: float = 100 * 1024 ** 3

#: Spark initializes unknown sizes to a huge default (Long.MaxValue-ish).
UNKNOWN_SIZE: float = float(2 ** 63 - 1)


class StatsSource(enum.Enum):
    ESTIMATED = "estimated"
    RUNTIME = "runtime"


@dataclasses.dataclass(frozen=True)
class TableStats:
    """(size, cardinality) of one dataset plus provenance.

    ``skew`` is the *join-key partition skew factor* s =
    max_partition_load / mean_partition_load of the dataset's join key
    hashed across p shuffle partitions (s >= 1; 1.0 = uniform). It is a
    runtime statistic measured at exchange boundaries (per-partition load
    histograms); statically derived estimates always carry the uniform
    default — only measurement can establish skew.
    """

    size_bytes: float
    cardinality: float
    source: StatsSource = StatsSource.ESTIMATED
    skew: float = 1.0

    @property
    def row_bytes(self) -> float:
        """|A|/a — average row size (paper Table 1)."""
        if self.cardinality <= 0:
            return 0.0
        return self.size_bytes / self.cardinality

    def is_valid(self, watermark_bytes: float = DEFAULT_WATERMARK_BYTES) -> bool:
        """Paper §4.4: only sizes below the watermark are valid statistics."""
        return (
            math.isfinite(self.size_bytes)
            and 0 <= self.size_bytes <= watermark_bytes
            and math.isfinite(self.cardinality)
            and self.cardinality >= 0
        )

    def as_runtime(self) -> "TableStats":
        return dataclasses.replace(self, source=StatsSource.RUNTIME)

    def with_skew(self, skew: float) -> "TableStats":
        """Attach a measured join-key skew factor (clamped to >= 1)."""
        return dataclasses.replace(self, skew=max(float(skew), 1.0))

    def scaled(self, selectivity: float) -> "TableStats":
        """Estimate stats after a filter with the given selectivity.

        Derived statistics are always ESTIMATED, even when the input was
        runtime-measured: only exchange boundaries produce RUNTIME stats.
        """
        sel = min(max(selectivity, 0.0), 1.0)
        return TableStats(self.size_bytes * sel, self.cardinality * sel,
                          StatsSource.ESTIMATED)


def unknown_stats() -> TableStats:
    """Stats for a lazily-loaded source without header statistics (§4.4)."""
    return TableStats(UNKNOWN_SIZE, UNKNOWN_SIZE, StatsSource.ESTIMATED)


# ---------------------------------------------------------------------------
# Static estimation rules for plan operators (standard CBO rules; §2.3).
# ---------------------------------------------------------------------------

def estimate_filter(inp: TableStats, selectivity: float) -> TableStats:
    return inp.scaled(selectivity)


def estimate_project(inp: TableStats, kept_byte_fraction: float) -> TableStats:
    frac = min(max(kept_byte_fraction, 0.0), 1.0)
    return TableStats(inp.size_bytes * frac, inp.cardinality,
                      StatsSource.ESTIMATED)


def estimate_join(left: TableStats, right: TableStats,
                  fk_to_pk: bool = True,
                  distinct_keys: float | None = None,
                  fk_selectivity: float = 1.0) -> TableStats:
    """Output stats of an equi-join.

    For FK->PK joins (the TPC-DS star-schema case) output cardinality is the
    probe-side cardinality scaled by ``fk_selectivity`` — the fraction of the
    build side's key domain that survived its filters (key-uniformity
    assumption; 1.0 for unfiltered dimensions). Otherwise the textbook
    a*b/max(distinct) rule. Output row size is the sum of both row sizes
    (all columns kept).
    """
    if fk_to_pk:
        card = left.cardinality * min(max(fk_selectivity, 0.0), 1.0)
    else:
        d = distinct_keys or max(left.cardinality, right.cardinality, 1.0)
        card = left.cardinality * right.cardinality / max(d, 1.0)
    row = left.row_bytes + right.row_bytes
    return TableStats(card * row, card, StatsSource.ESTIMATED)


def estimate_group_by(inp: TableStats, groups: float) -> TableStats:
    card = min(inp.cardinality, max(groups, 1.0))
    return TableStats(card * inp.row_bytes, card, StatsSource.ESTIMATED)


# ---------------------------------------------------------------------------
# Per-column statistics: NDV / MCV / equi-depth histograms.
#
# The mergeable intermediate is an exact compressed multiset (sorted
# (value, count) pairs) — per-partition summaries merge by adding counts,
# so distributed builds are order-, duplicate- and partitioning-invariant
# by construction, and merge(split(summary, p)) == summary at any p. The
# finalized ``ColumnStats`` keeps the heaviest values exactly (MCV) and
# equi-depth buckets over the remainder.
# ---------------------------------------------------------------------------

#: Most-common values kept exactly per column (counts, not estimates).
MCV_TOP_K: int = 8

#: Equi-depth buckets over the non-MCV remainder of a column.
HISTOGRAM_BUCKETS: int = 16


def q_error(estimated: float, measured: float) -> float:
    """The symmetric multiplicative estimation error max(e/m, m/e).

    Both sides are floored at one row so empty relations (and estimates
    rounding to zero) yield finite, comparable errors: q_error(0, 0) == 1.
    """
    e = max(float(estimated), 1.0)
    m = max(float(measured), 1.0)
    return max(e / m, m / e)


@dataclasses.dataclass(frozen=True)
class ColumnSummary:
    """Exact compressed multiset of one column: sorted (value, count) pairs.

    The order- and partitioning-invariant intermediate behind
    ``ColumnStats``: build each partition's summary independently, merge by
    adding counts. Values are stored as floats (the engine's columns are
    int32/float32 — both embed exactly).
    """

    values: Tuple[float, ...]
    counts: Tuple[float, ...]

    @property
    def total(self) -> float:
        return float(sum(self.counts))

    @property
    def ndv(self) -> float:
        return float(len(self.values))


def summary_from_pairs(values: Iterable[float],
                       counts: Iterable[float]) -> ColumnSummary:
    """Normalize (value, count) pairs into a ``ColumnSummary``: duplicate
    values merge by adding counts, zero/negative counts drop, pairs sort by
    value — so any pair order or duplication yields the identical summary."""
    acc: Dict[float, float] = {}
    for v, c in zip(values, counts):
        if c > 0:
            fv = float(v)
            acc[fv] = acc.get(fv, 0.0) + float(c)
    ordered = sorted(acc.items())
    return ColumnSummary(tuple(v for v, _ in ordered),
                         tuple(c for _, c in ordered))


def build_summary(values: Iterable[float]) -> ColumnSummary:
    """Summarize a raw value sequence (one partition's column)."""
    counts: Dict[float, float] = {}
    for v in values:
        fv = float(v)
        counts[fv] = counts.get(fv, 0.0) + 1.0
    ordered = sorted(counts.items())
    return ColumnSummary(tuple(v for v, _ in ordered),
                         tuple(c for _, c in ordered))


def merge_summaries(parts: Sequence[ColumnSummary]) -> ColumnSummary:
    """Exact multiset union of per-partition summaries (any order)."""
    return summary_from_pairs(
        [v for s in parts for v in s.values],
        [c for s in parts for c in s.counts])


def filter_summary(summary: ColumnSummary, op: str, value: float = 0.0,
                   value2: float = 0.0,
                   values: Sequence[float] = ()) -> ColumnSummary:
    """The exact multiset surviving one predicate, engine semantics:
    ``between`` inclusive on both ends, ``in`` an OR of equalities."""
    keep = _predicate(op, value, value2, values)
    pairs = [(v, c) for v, c in zip(summary.values, summary.counts)
             if keep(v)]
    return ColumnSummary(tuple(v for v, _ in pairs),
                         tuple(c for _, c in pairs))


def _predicate(op: str, value: float, value2: float,
               values: Sequence[float]) -> Callable[[float], bool]:
    members = {float(v) for v in values}
    table = {
        "eq": lambda v: v == value,
        "ne": lambda v: v != value,
        "lt": lambda v: v < value,
        "le": lambda v: v <= value,
        "gt": lambda v: v > value,
        "ge": lambda v: v >= value,
        "between": lambda v: value <= v <= value2,
        "in": lambda v: v in members,
    }
    if op not in table:
        raise ValueError(f"unknown filter op {op}")
    return table[op]


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Finalized per-column statistics: row count, NDV, the top-K most
    common values with exact counts, and equi-depth buckets
    ``(lo, hi, rows, ndv)`` — bounds inclusive — over the remainder.
    ``integral`` marks integer-valued columns (point predicates on
    non-integers estimate zero)."""

    count: float
    ndv: float
    mcv: Tuple[Tuple[float, float], ...]
    buckets: Tuple[Tuple[float, float, float, float], ...]
    integral: bool = True

    def fraction(self, op: str, value: float = 0.0, value2: float = 0.0,
                 values: Sequence[float] = ()) -> float:
        """Estimated kept fraction of one predicate; 0.0 on empty input."""
        if self.count <= 0:
            return 0.0
        n = self.count
        if op == "eq":
            return _clamp01(self._eq_rows(value) / n)
        if op == "ne":
            return _clamp01(1.0 - self._eq_rows(value) / n)
        if op == "lt":
            return _clamp01(self._lt_rows(value) / n)
        if op == "le":
            return _clamp01(self._le_rows(value) / n)
        if op == "gt":
            return _clamp01(1.0 - self._le_rows(value) / n)
        if op == "ge":
            return _clamp01(1.0 - self._lt_rows(value) / n)
        if op == "between":
            return _clamp01(
                (self._le_rows(value2) - self._lt_rows(value)) / n)
        if op == "in":
            return _clamp01(
                sum(self._eq_rows(v) for v in {float(v) for v in values})
                / n)
        raise ValueError(f"unknown filter op {op}")

    def _eq_rows(self, value: float) -> float:
        v = float(value)
        for mv, mc in self.mcv:
            if mv == v:
                return mc
        if self.integral and not v.is_integer():
            return 0.0
        for lo, hi, rows, ndv in self.buckets:
            if lo <= v <= hi:
                return rows / max(ndv, 1.0)
        return 0.0

    def _le_rows(self, value: float) -> float:
        """Rows with column value <= ``value`` (MCV exact + bucket
        interpolation: discrete-uniform within integral buckets, linear
        within float buckets)."""
        v = float(value)
        rows = sum(mc for mv, mc in self.mcv if mv <= v)
        for lo, hi, cnt, _ in self.buckets:
            if v >= hi:
                rows += cnt
            elif v >= lo:
                if self.integral:
                    width = hi - lo + 1.0
                    rows += cnt * (math.floor(v) - lo + 1.0) / width
                else:
                    rows += cnt * (v - lo) / max(hi - lo, 1e-30)
        return rows

    def _lt_rows(self, value: float) -> float:
        v = float(value)
        rows = sum(mc for mv, mc in self.mcv if mv < v)
        for lo, hi, cnt, _ in self.buckets:
            if v > hi:
                rows += cnt
            elif v > lo:
                if self.integral:
                    width = hi - lo + 1.0
                    rows += cnt * (math.ceil(v) - lo) / width
                else:
                    rows += cnt * (v - lo) / max(hi - lo, 1e-30)
        return rows


def _clamp01(x: float) -> float:
    return min(max(x, 0.0), 1.0)


def column_stats_from_summary(summary: ColumnSummary,
                              integral: bool = True,
                              mcv_k: int = MCV_TOP_K,
                              n_buckets: int = HISTOGRAM_BUCKETS
                              ) -> ColumnStats:
    """Finalize a summary: peel off the top ``mcv_k`` values by count
    (ties broken by value — deterministic under any build order), then cut
    the remainder into at most ``n_buckets`` equi-depth buckets."""
    n = summary.total
    if n <= 0:
        return ColumnStats(0.0, 0.0, (), (), integral)
    pairs = list(zip(summary.values, summary.counts))
    by_weight = sorted(pairs, key=lambda vc: (-vc[1], vc[0]))
    mcv = tuple(by_weight[:mcv_k])
    mcv_values = {v for v, _ in mcv}
    rest = [(v, c) for v, c in pairs if v not in mcv_values]
    buckets: list[Tuple[float, float, float, float]] = []
    if rest:
        rem = sum(c for _, c in rest)
        # Close a bucket once it holds one equi-depth share; depth balances
        # to within one value's count, deterministically (rest is sorted).
        target = rem / max(n_buckets, 1)
        lo = rest[0][0]
        rows = 0.0
        ndv = 0.0
        for i, (v, c) in enumerate(rest):
            rows += c
            ndv += 1.0
            if rows >= target or i == len(rest) - 1:
                buckets.append((lo, v, rows, ndv))
                rows = 0.0
                ndv = 0.0
                if i + 1 < len(rest):
                    lo = rest[i + 1][0]
    return ColumnStats(n, summary.ndv, mcv, tuple(buckets), integral)


def split_summary(summary: ColumnSummary, p: int) -> Tuple[ColumnSummary, ...]:
    """Round-robin the expanded multiset across ``p`` parts — the test
    helper for the merge(split(h)) ≡ h invariant (not a data path)."""
    parts: Tuple[Dict[float, float], ...] = tuple({} for _ in range(p))
    i = 0
    for v, c in zip(summary.values, summary.counts):
        whole = int(c)
        for _ in range(whole):
            part = parts[i % p]
            part[v] = part.get(v, 0.0) + 1.0
            i += 1
        frac = float(c) - whole
        if frac > 0:
            part = parts[i % p]
            part[v] = part.get(v, 0.0) + frac
            i += 1
    return tuple(
        summary_from_pairs(tuple(d.keys()), tuple(d.values()))
        for d in parts)
