"""Dataset statistics used by the RelJoin cost model (paper §2.3).

The cost model needs exactly two statistics per dataset: *size* in bytes and
*cardinality* in rows (paper §4.1 Step 1: "the required statistics are the
size and cardinality of the output dataset"). Statistics are either

  * ``ESTIMATED`` — statically analyzed along the logical plan, or
  * ``RUNTIME``   — measured at a data-exchange boundary (adaptive runtime
    statistics, §2.3/§4.1), which supersede estimates.

A *watermark* (default 100 GB, §4.4) caps the size a statistic may take while
still being considered valid; lazily-initialized "very large number" defaults
from sources without stats are thereby rejected and the optimizer falls back
to the platform's original absolute-size strategy for that join.
"""

from __future__ import annotations

import dataclasses
import enum
import math

#: Paper §4.4: default watermark = 100 GB.
DEFAULT_WATERMARK_BYTES: float = 100 * 1024 ** 3

#: Spark initializes unknown sizes to a huge default (Long.MaxValue-ish).
UNKNOWN_SIZE: float = float(2 ** 63 - 1)


class StatsSource(enum.Enum):
    ESTIMATED = "estimated"
    RUNTIME = "runtime"


@dataclasses.dataclass(frozen=True)
class TableStats:
    """(size, cardinality) of one dataset plus provenance.

    ``skew`` is the *join-key partition skew factor* s =
    max_partition_load / mean_partition_load of the dataset's join key
    hashed across p shuffle partitions (s >= 1; 1.0 = uniform). It is a
    runtime statistic measured at exchange boundaries (per-partition load
    histograms); statically derived estimates always carry the uniform
    default — only measurement can establish skew.
    """

    size_bytes: float
    cardinality: float
    source: StatsSource = StatsSource.ESTIMATED
    skew: float = 1.0

    @property
    def row_bytes(self) -> float:
        """|A|/a — average row size (paper Table 1)."""
        if self.cardinality <= 0:
            return 0.0
        return self.size_bytes / self.cardinality

    def is_valid(self, watermark_bytes: float = DEFAULT_WATERMARK_BYTES) -> bool:
        """Paper §4.4: only sizes below the watermark are valid statistics."""
        return (
            math.isfinite(self.size_bytes)
            and 0 <= self.size_bytes <= watermark_bytes
            and math.isfinite(self.cardinality)
            and self.cardinality >= 0
        )

    def as_runtime(self) -> "TableStats":
        return dataclasses.replace(self, source=StatsSource.RUNTIME)

    def with_skew(self, skew: float) -> "TableStats":
        """Attach a measured join-key skew factor (clamped to >= 1)."""
        return dataclasses.replace(self, skew=max(float(skew), 1.0))

    def scaled(self, selectivity: float) -> "TableStats":
        """Estimate stats after a filter with the given selectivity.

        Derived statistics are always ESTIMATED, even when the input was
        runtime-measured: only exchange boundaries produce RUNTIME stats.
        """
        sel = min(max(selectivity, 0.0), 1.0)
        return TableStats(self.size_bytes * sel, self.cardinality * sel,
                          StatsSource.ESTIMATED)


def unknown_stats() -> TableStats:
    """Stats for a lazily-loaded source without header statistics (§4.4)."""
    return TableStats(UNKNOWN_SIZE, UNKNOWN_SIZE, StatsSource.ESTIMATED)


# ---------------------------------------------------------------------------
# Static estimation rules for plan operators (standard CBO rules; §2.3).
# ---------------------------------------------------------------------------

def estimate_filter(inp: TableStats, selectivity: float) -> TableStats:
    return inp.scaled(selectivity)


def estimate_project(inp: TableStats, kept_byte_fraction: float) -> TableStats:
    frac = min(max(kept_byte_fraction, 0.0), 1.0)
    return TableStats(inp.size_bytes * frac, inp.cardinality,
                      StatsSource.ESTIMATED)


def estimate_join(left: TableStats, right: TableStats,
                  fk_to_pk: bool = True,
                  distinct_keys: float | None = None,
                  fk_selectivity: float = 1.0) -> TableStats:
    """Output stats of an equi-join.

    For FK->PK joins (the TPC-DS star-schema case) output cardinality is the
    probe-side cardinality scaled by ``fk_selectivity`` — the fraction of the
    build side's key domain that survived its filters (key-uniformity
    assumption; 1.0 for unfiltered dimensions). Otherwise the textbook
    a*b/max(distinct) rule. Output row size is the sum of both row sizes
    (all columns kept).
    """
    if fk_to_pk:
        card = left.cardinality * min(max(fk_selectivity, 0.0), 1.0)
    else:
        d = distinct_keys or max(left.cardinality, right.cardinality, 1.0)
        card = left.cardinality * right.cardinality / max(d, 1.0)
    row = left.row_bytes + right.row_bytes
    return TableStats(card * row, card, StatsSource.ESTIMATED)


def estimate_group_by(inp: TableStats, groups: float) -> TableStats:
    card = min(inp.cardinality, max(groups, 1.0))
    return TableStats(card * inp.row_bytes, card, StatsSource.ESTIMATED)
