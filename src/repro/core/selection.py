"""Distributed join method selection — paper Algorithm 1 (§4.3) plus the
validity fallback of §4.4.

Selection is per-logical-join and independent of other joins (paper §4.2), so
repeated calls over a plan's joins yield the model-globally-optimal physical
plan in O(l*h).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple

from .cost_model import (CostParams, JoinMethod, broadcast_hash_cost,
                         broadcast_nl_cost, cartesian_cost, cube_replication,
                         cube_shares, default_salt_factor,
                         hypercube_shuffle_cost, salted_shuffle_hash_cost,
                         shuffle_hash_cost, shuffle_sort_cost)
from .stats import DEFAULT_WATERMARK_BYTES, TableStats


class JoinType(enum.Enum):
    INNER = "inner"
    LEFT_OUTER = "left_outer"
    RIGHT_OUTER = "right_outer"
    FULL_OUTER = "full_outer"
    LEFT_SEMI = "left_semi"
    LEFT_ANTI = "left_anti"
    CROSS = "cross"


#: Join types for which the Cartesian product join is feasible ("inner-like").
INNER_LIKE = frozenset({JoinType.INNER, JoinType.CROSS, JoinType.LEFT_SEMI,
                        JoinType.LEFT_ANTI})


@dataclasses.dataclass(frozen=True)
class JoinProperties:
    """Feasibility flags of a logical join (Algorithm 1 inputs)."""

    join_type: JoinType = JoinType.INNER
    equi: bool = True                  # has equality predicates
    sortable_keys: bool = True         # sort join feasible
    hashable: bool = True              # memory allows building a hash map
    hint: Optional[JoinMethod] = None  # user-defined join hint (§4.3 line 1)
    #: Side already hash-partitioned on its join key (upstream shuffle join
    #: or group-by on the same key). The engine elides that side's exchange,
    #: so shuffle-family quotes drop its network term (paper §3.7).
    left_partitioned: bool = False
    right_partitioned: bool = False


@dataclasses.dataclass(frozen=True)
class Selection:
    """Outcome of one selection with audit info."""

    method: JoinMethod
    reason: str
    cost: float
    costs: dict
    used_fallback: bool = False
    swapped_sides: bool = False  # True when |B| > |A| and sides were flipped
    salt_r: int = 1              # salt buckets when SALTED_SHUFFLE_HASH


def _ordered(left: TableStats, right: TableStats,
             ) -> Tuple[TableStats, TableStats, bool]:
    """Paper §3.1.4: A is the larger side. Returns (A, B, swapped)."""
    if right.size_bytes > left.size_bytes:
        return right, left, True
    return left, right, False


def select_join_method(left: TableStats, right: TableStats,
                       props: JoinProperties, params: CostParams,
                       watermark_bytes: float = DEFAULT_WATERMARK_BYTES,
                       ) -> Selection:
    """Algorithm 1: cost-based distributed join method selection.

    ``left``/``right`` are the plan-order children; the model's A/B roles are
    assigned by size (A = larger). Returns the selected physical method.
    """
    a, b, swapped = _ordered(left, right)

    # Line 1-3: user hints short-circuit everything (but the audit record
    # must still report which side plays the A role).
    if props.hint is not None:
        return Selection(props.hint, "user hint", float("nan"), {},
                         swapped_sides=swapped)

    # §4.4: invalid statistics (e.g. huge lazy-init sizes) -> fall back to the
    # platform's original absolute-size strategy, handled by the caller.
    if not (a.is_valid(watermark_bytes) and b.is_valid(watermark_bytes)):
        sel = select_absolute_size(left, right, props)
        return dataclasses.replace(sel, used_fallback=True,
                                   reason="invalid stats: " + sel.reason)

    sa, sb = a.size_bytes, b.size_bytes
    ca, cb = max(a.cardinality, 1.0), max(b.cardinality, 1.0)
    # Straggler factors of the A (probe) and B (build) join keys. Statistics
    # without a measured skew carry the uniform default 1.0, reproducing the
    # paper's costs bit-for-bit.
    ka, kb = max(a.skew, 1.0), max(b.skew, 1.0)
    salt_r = default_salt_factor(ka, params)
    # Map the plan-order pre-partitioned flags onto the model's A/B roles.
    # Only the plain shuffle methods see them: a salted exchange re-keys the
    # data, so salting always re-pays the shuffle it would otherwise elide.
    pre_a = props.right_partitioned if swapped else props.left_partitioned
    pre_b = props.left_partitioned if swapped else props.right_partitioned

    costs = {
        JoinMethod.BROADCAST_HASH: broadcast_hash_cost(sa, sb, params),
        JoinMethod.SHUFFLE_HASH: shuffle_hash_cost(sa, sb, params, ka, kb,
                                                   pre_a, pre_b),
        JoinMethod.SALTED_SHUFFLE_HASH: salted_shuffle_hash_cost(
            sa, sb, params, ka, salt_r),
        JoinMethod.SHUFFLE_SORT: shuffle_sort_cost(sa, sb, ca, cb, params,
                                                   ka, kb, pre_a, pre_b),
        JoinMethod.BROADCAST_NL: broadcast_nl_cost(sa, sb, ca, params),
        JoinMethod.CARTESIAN: cartesian_cost(sa, sb, ca, params),
    }

    if props.equi:
        # Lines 4-9: hash joins when hashing is allowed.
        if props.hashable:
            if costs[JoinMethod.BROADCAST_HASH] < costs[JoinMethod.SHUFFLE_HASH]:
                m = JoinMethod.BROADCAST_HASH
                why = "equi, hashable, C_bh < C_sh (k > k0)"
            else:
                m = JoinMethod.SHUFFLE_HASH
                why = "equi, hashable, C_sh <= C_bh (k <= k0)"
            # Skew extension: the salted variant replaces the hash-family
            # pick only when *strictly* cheaper — at skew 1 its replication
            # surcharge makes that impossible, so uniform-key selections are
            # identical to the paper's Algorithm 1. It is also only eligible
            # when the A role sits on the plan's probe (left) side: the
            # engine salts the left side and replicates the right, so on
            # swapped sides the method the model priced is not executable
            # (the executor would have to degrade it anyway).
            if (not swapped
                    and costs[JoinMethod.SALTED_SHUFFLE_HASH] < costs[m]):
                m = JoinMethod.SALTED_SHUFFLE_HASH
                why = (f"equi, hashable, skewed (s={ka:.2f}): "
                       f"C_salted(r={salt_r}) beats plain hash joins")
                return Selection(m, why, costs[m], costs,
                                 swapped_sides=swapped, salt_r=salt_r)
            return Selection(m, why, costs[m], costs, swapped_sides=swapped)
        # Lines 10-11: sort join.
        if props.sortable_keys:
            m = JoinMethod.SHUFFLE_SORT
            return Selection(m, "equi, not hashable, sortable keys",
                             costs[m], costs, swapped_sides=swapped)

    # Lines 12-17: NL-family fallbacks (non-equi, unsortable, unhashable).
    if (costs[JoinMethod.CARTESIAN] <= costs[JoinMethod.BROADCAST_NL]
            and props.join_type in INNER_LIKE):
        m = JoinMethod.CARTESIAN
        why = "NL family, inner-like, C_cartesian <= C_broadcastNL"
    else:
        m = JoinMethod.BROADCAST_NL
        why = "NL family"
    return Selection(m, why, costs[m], costs, swapped_sides=swapped)


def select_hypercube(stats: Sequence[TableStats],
                     memberships: Sequence[Sequence[int]], n_axes: int,
                     binary_cost: float, params: CostParams,
                     watermark_bytes: float = DEFAULT_WATERMARK_BYTES,
                     ) -> Optional[Selection]:
    """Quote the hypercube multi-way shuffle for a cyclic join-graph core.

    ``stats[i]`` are the relations' statistics (index 0 = probe);
    ``memberships[i]`` the cube axes relation i owns (one axis per join
    variable, ``n_axes`` total); ``binary_cost`` the best binary plan's
    modeled cost for the same core (the DP's quote). In the spirit of
    Algorithm 1 the multi-way plan is selected *only when strictly
    cheaper* than the best binary tree — on anything else (including
    invalid statistics, where no trustworthy quote exists) the binary
    plan stands and ``None`` is returned.
    """
    if not all(s.is_valid(watermark_bytes) for s in stats):
        return None
    sizes = [s.size_bytes for s in stats]
    dims = cube_shares(params.p, n_axes, memberships, sizes, params)
    factors = [float(cube_replication(dims, m)) for m in memberships]
    cost = hypercube_shuffle_cost(sizes, factors, params)
    if not cost < binary_cost * (1 - 1e-9):
        return None
    why = (f"cyclic core of {len(stats)} relations: cube {dims} "
           f"replication volume {cost:.0f} < best binary plan "
           f"{binary_cost:.0f}")
    return Selection(JoinMethod.HYPERCUBE_SHUFFLE, why, cost,
                     {JoinMethod.HYPERCUBE_SHUFFLE: cost})


# ---------------------------------------------------------------------------
# Baseline strategies reproduced for evaluation (paper Table 3).
# ---------------------------------------------------------------------------

#: Spark AQE's default autoBroadcastJoinThreshold.
AQE_BROADCAST_THRESHOLD_BYTES: float = 10 * 1024 ** 2


def select_absolute_size(left: TableStats, right: TableStats,
                         props: JoinProperties,
                         threshold_bytes: float = AQE_BROADCAST_THRESHOLD_BYTES,
                         prefer_sort: bool = True) -> Selection:
    """The AQE strategy: broadcast iff min-side size <= absolute threshold;
    otherwise shuffle sort (Spark's default) or shuffle hash."""
    a, b, swapped = _ordered(left, right)
    if props.hint is not None:
        return Selection(props.hint, "user hint", float("nan"), {},
                         swapped_sides=swapped)
    if props.equi and props.hashable and b.size_bytes <= threshold_bytes:
        return Selection(JoinMethod.BROADCAST_HASH,
                         f"abs size {b.size_bytes:.0f} <= {threshold_bytes:.0f}",
                         float("nan"), {}, swapped_sides=swapped)
    if props.equi and props.sortable_keys and prefer_sort:
        return Selection(JoinMethod.SHUFFLE_SORT, "abs size: default sort",
                         float("nan"), {}, swapped_sides=swapped)
    if props.equi and props.hashable:
        return Selection(JoinMethod.SHUFFLE_HASH, "abs size: hash",
                         float("nan"), {}, swapped_sides=swapped)
    if props.join_type in INNER_LIKE:
        return Selection(JoinMethod.CARTESIAN, "abs size: NL family",
                         float("nan"), {}, swapped_sides=swapped)
    return Selection(JoinMethod.BROADCAST_NL, "abs size: NL family",
                     float("nan"), {}, swapped_sides=swapped)


def select_forced(method: JoinMethod, left: TableStats, right: TableStats,
                  props: JoinProperties) -> Selection:
    """ShuffleSort / ShuffleHash forced strategies (paper Table 3): hint the
    shuffle method when feasible, otherwise degrade like Algorithm 1 would."""
    a, b, swapped = _ordered(left, right)
    if method is JoinMethod.SHUFFLE_SORT and props.equi and props.sortable_keys:
        return Selection(method, "forced", float("nan"), {},
                         swapped_sides=swapped)
    if method is JoinMethod.SHUFFLE_HASH and props.equi and props.hashable:
        return Selection(method, "forced", float("nan"), {},
                         swapped_sides=swapped)
    if props.equi and props.sortable_keys:
        return Selection(JoinMethod.SHUFFLE_SORT, "forced-fallback",
                         float("nan"), {}, swapped_sides=swapped)
    if props.join_type in INNER_LIKE:
        return Selection(JoinMethod.CARTESIAN, "forced-fallback", float("nan"),
                         {}, swapped_sides=swapped)
    return Selection(JoinMethod.BROADCAST_NL, "forced-fallback", float("nan"),
                     {}, swapped_sides=swapped)
