"""RelJoin cost model — faithful implementation of paper §3 (Eqs. 1-13, Table 2).

Cluster-workload costs of distributed join methods. Workload units are bytes of
data touched (sizes |A|, |B|); the single hyperparameter ``w`` weights the
network workload of the exchange phase against the local compute workload
(paper §3.2.4). All formulas are linear in |A|, |B| except the sort terms.

Notation (paper Table 1):
    size_a, size_b   : |A|, |B|  (bytes; |A| >= |B| by convention, A = probe side)
    card_a, card_b   : a, b      (row counts)
    p                : distributed join parallelism (number of shuffle partitions)
    w                : relative weight of network cost vs computing cost
    l_fan            : average matches in B per row of A (uniform default b/a)
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterator, Sequence, Tuple

#: The module's public surface. docs/cost_model.md documents every name
#: listed here (pinned by tests/test_docs.py — extend both together).
__all__ = [
    "JoinMethod", "RANK", "CostParams",
    # phase workloads (Eqs. 1-12)
    "broadcast_workload", "build_workload_broadcast", "probe_workload",
    "shuffle_workload", "sort_workload", "merge_workload",
    "build_workload_shuffle", "nl_workload_broadcast",
    "nl_workload_cartesian",
    # overall method costs (Eqs. 4, 8, 10, §3.5) + skew extension
    "broadcast_hash_cost", "shuffle_hash_cost", "shuffle_sort_cost",
    "default_salt_factor", "salted_shuffle_hash_cost", "broadcast_nl_cost",
    "cartesian_cost", "method_cost", "all_costs",
    # hypercube multi-way shuffle (cyclic join graphs)
    "cube_shares", "cube_replication", "hypercube_shuffle_cost",
    # runtime-filter costs (bloom / zone-map / semi-join / cache)
    "BLOOM_DEFAULT_BITS_PER_KEY", "BLOOM_MIN_BITS", "BLOOM_MAX_HASHES",
    "ZONE_MAP_BITS", "SEMI_JOIN_BITS_PER_KEY",
    "bloom_params", "bloom_fpr", "runtime_filter_cost",
    "filter_reduce_cost", "cached_filter_cost", "filtered_probe_fraction",
    "zone_map_cost", "semi_join_cost", "bloom_total_cost",
    # the relative-size criterion (Eq. 13)
    "k0_threshold", "relative_size", "broadcast_preferred",
    # checkpoint re-optimization trigger (PR 10, not in the paper)
    "DEFAULT_REOPT_QERROR",
]


class JoinMethod(enum.Enum):
    """Physical distributed join methods modeled by the paper, plus the
    skew-aware salted shuffle extension (not in the paper's Table 2)."""

    BROADCAST_HASH = "broadcast_hash"
    SHUFFLE_HASH = "shuffle_hash"
    SHUFFLE_SORT = "shuffle_sort"
    BROADCAST_NL = "broadcast_nl"
    CARTESIAN = "cartesian"
    SALTED_SHUFFLE_HASH = "salted_shuffle_hash"
    #: Multi-way extension (not in the paper's Table 2): partition the p
    #: tasks as a hypercube with one axis per join variable, hash every
    #: relation on the axes of the variables it contains and replicate it
    #: along the axes it does not, then run one local multi-way probe. Only
    #: quoted for cyclic join-graph cores, never by the binary Algorithm 1.
    HYPERCUBE_SHUFFLE = "hypercube_shuffle"


#: Paper Table 2 — higher-rank methods are preferred when feasible.
RANK: Dict[JoinMethod, int] = {
    JoinMethod.BROADCAST_HASH: 3,
    JoinMethod.SHUFFLE_HASH: 3,
    JoinMethod.SALTED_SHUFFLE_HASH: 3,
    JoinMethod.HYPERCUBE_SHUFFLE: 3,
    JoinMethod.SHUFFLE_SORT: 2,
    JoinMethod.BROADCAST_NL: 1,
    JoinMethod.CARTESIAN: 1,
}


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Environment parameters of the cost model.

    ``w`` is the paper's only hyperparameter (§1, §3.2.4); ``p`` is the join
    parallelism. The paper's testbed uses w=1, p=20 (=> k0=39).
    """

    p: int = 20
    w: float = 1.0

    def __post_init__(self) -> None:
        if self.p < 1:
            raise ValueError(f"parallelism p must be >= 1, got {self.p}")
        if self.w < 0:
            raise ValueError(f"network weight w must be >= 0, got {self.w}")


# ---------------------------------------------------------------------------
# Phase workloads (Eqs. 1-12). Each returns cluster workload in |.| units.
# ---------------------------------------------------------------------------

def broadcast_workload(size_b: float, params: CostParams) -> float:
    """Eq. 1: C_broadcast = (p-1)|B| — network workload of broadcasting B."""
    return (params.p - 1) * size_b


def build_workload_broadcast(size_b: float, params: CostParams) -> float:
    """Eq. 2: C_build = p|B| — every task builds a hash map of all of B."""
    return params.p * size_b


def probe_workload(size_a: float, size_b: float, card_a: float, card_b: float,
                   l_fan: float | None = None) -> float:
    """Eq. 3 (general form in §3.2.3): C_probe = |A| + (a*l_fan/b)|B|.

    With the paper's uniform-matching assumption l_fan = b/a this reduces to
    |A| + |B| (Eq. 3). Passing an explicit fanout reproduces the general form.
    """
    if l_fan is None:
        return size_a + size_b
    if card_b <= 0:
        return size_a
    return size_a + (card_a * l_fan / card_b) * size_b


def shuffle_workload(size_a: float, size_b: float, params: CostParams,
                     skew_a: float = 1.0, skew_b: float = 1.0) -> float:
    """Eq. 5: C_shuffle = ((p-1)/p)(|A| + |B|) — network workload of shuffle.

    The paper charges total exchanged bytes, implicitly assuming uniform key
    distributions. Under key skew the hottest partition — not the mean —
    bounds the stage, so each side is charged at its straggler load:
    ``skew = max_partition_load / mean_partition_load`` (1.0 reproduces the
    paper exactly).
    """
    p = params.p
    return (p - 1) / p * (skew_a * size_a + skew_b * size_b)


def sort_workload(size_a: float, size_b: float, card_a: float, card_b: float,
                  params: CostParams,
                  skew_a: float = 1.0, skew_b: float = 1.0) -> float:
    """Eq. 6: C_sort = |A| log2(a/p) + |B| log2(b/p).

    Skew-adjusted: the straggler partition holds ``skew * card / p`` rows, so
    both the touched bytes and the sort depth scale with the skew factor.
    """
    p = params.p
    wa = skew_a * size_a * math.log2(max(skew_a * card_a / p, 1.0))
    wb = skew_b * size_b * math.log2(max(skew_b * card_b / p, 1.0))
    return wa + wb


def merge_workload(size_a: float, size_b: float) -> float:
    """Eq. 7: C_merge = |A| + |B|."""
    return size_a + size_b


def build_workload_shuffle(size_b: float) -> float:
    """Eq. 9: C'_build = |B| — each task hashes only its partition of B."""
    return size_b


def nl_workload_broadcast(size_a: float, size_b: float, card_a: float) -> float:
    """Eq. 11: C_NL = |A| + a|B|."""
    return size_a + card_a * size_b


def nl_workload_cartesian(size_a: float, size_b: float, card_a: float,
                          params: CostParams) -> float:
    """Eq. 12: C'_NL = |A| + (a/p)|B|."""
    return size_a + card_a / params.p * size_b


# ---------------------------------------------------------------------------
# Overall method costs (Eqs. 4, 8, 10 and §3.5). w weights network terms.
# ---------------------------------------------------------------------------

def broadcast_hash_cost(size_a: float, size_b: float, params: CostParams) -> float:
    """Eq. 4: C_broadcastHash = |A| + (wp - w + p + 1)|B|."""
    p, w = params.p, params.w
    return size_a + (w * p - w + p + 1) * size_b


def shuffle_hash_cost(size_a: float, size_b: float, params: CostParams,
                      skew_a: float = 1.0, skew_b: float = 1.0,
                      pre_a: bool = False, pre_b: bool = False) -> float:
    """Eq. 10: C_shuffleHash = ((wp-w+p)/p)|A| + ((wp-w+2p)/p)|B|.

    Under key skew every shuffle-phase term (exchange, build, probe) is
    bounded by the straggler partition, so each side's coefficient scales
    with its skew factor: |A| -> skew_a|A|, |B| -> skew_b|B|. Defaults
    reproduce the paper's uniform-distribution formula.

    ``pre_a`` / ``pre_b`` mark a side as already hash-partitioned on the
    join key (e.g. the output of an upstream shuffle join or group-by on
    the same key). The engine elides that side's exchange (ships 0 bytes),
    so the quote drops the w(p-1)/p network term for the side — its
    coefficient (wp-w+p)/p collapses to the probe read 1 (A side) and
    (wp-w+2p)/p to build + probe 2 (B side). Paper §3.7's C_shuffle = 0
    case, which the selection would otherwise re-pay.
    """
    p, w = params.p, params.w
    coef_a = 1.0 if pre_a else (w * p - w + p) / p
    coef_b = 2.0 if pre_b else (w * p - w + 2 * p) / p
    return coef_a * skew_a * size_a + coef_b * skew_b * size_b


def shuffle_sort_cost(size_a: float, size_b: float, card_a: float, card_b: float,
                      params: CostParams,
                      skew_a: float = 1.0, skew_b: float = 1.0,
                      pre_a: bool = False, pre_b: bool = False) -> float:
    """Eq. 8: ((wp-w+p)/p + log2(a/p))|A| + ((wp-w+p)/p + log2(b/p))|B|.

    Skew-adjusted like :func:`shuffle_hash_cost`; the sort-depth log terms
    additionally grow with the straggler partition's cardinality.
    ``pre_a`` / ``pre_b`` drop the elided exchange's w(p-1)/p network term
    for a side already partitioned on the join key (see
    :func:`shuffle_hash_cost`); the sort + merge terms remain.
    """
    p, w = params.p, params.w
    base = (w * p - w + p) / p
    ta = (1.0 if pre_a else base) + math.log2(max(skew_a * card_a / p, 1.0))
    tb = (1.0 if pre_b else base) + math.log2(max(skew_b * card_b / p, 1.0))
    return ta * skew_a * size_a + tb * skew_b * size_b


def default_salt_factor(skew: float, params: CostParams) -> int:
    """Salt-bucket count r for the salted shuffle: enough buckets to flatten
    a straggler of factor ``skew`` (r ~ ceil(s)), at least 2 so the method is
    a real salting, at most p (more salts than partitions cannot spread
    further)."""
    return int(min(params.p, max(2, math.ceil(skew - 1e-9))))


def salted_shuffle_hash_cost(size_a: float, size_b: float, params: CostParams,
                             skew_a: float = 1.0,
                             r: int | None = None) -> float:
    """Skew-mitigated shuffle hash: salt hot probe keys across ``r`` salt
    buckets and replicate the matching build rows r-fold.

    Modeled as shuffle hash with two adjustments:

      * the probe side's straggler is flattened to the residual
        ``max(1, skew_a / r)`` — each hot key's rows now spread over r
        partitions;
      * the build side pays a replication surcharge ``1 + (r-1)/p``: only
        the hot-bucket slice of B (at most ~a partition's fair share, 1/p of
        |B|) is replicated r-fold, and the replicas ride the same shuffle +
        build + probe phases.

    At skew 1 this is strictly worse than plain shuffle hash (the surcharge
    buys nothing), so Algorithm 1 only deviates from the paper's five-method
    choice when measured skew makes plain shuffle lose.
    """
    r = r if r is not None else default_salt_factor(skew_a, params)
    residual = max(1.0, skew_a / max(r, 1))
    replication = 1.0 + (r - 1) / params.p
    return shuffle_hash_cost(size_a, size_b, params,
                             skew_a=residual, skew_b=replication)


def broadcast_nl_cost(size_a: float, size_b: float, card_a: float,
                      params: CostParams) -> float:
    """§3.5: C_broadcastNL = |A| + (wp - w + a)|B|."""
    p, w = params.p, params.w
    return size_a + (w * p - w + card_a) * size_b


def cartesian_cost(size_a: float, size_b: float, card_a: float,
                   params: CostParams) -> float:
    """§3.5: C_cartesian = ((wp-w+p)/p)|A| + ((wp-w+a)/p)|B|."""
    p, w = params.p, params.w
    return (w * p - w + p) / p * size_a + (w * p - w + card_a) / p * size_b


def method_cost(method: JoinMethod, size_a: float, size_b: float,
                card_a: float, card_b: float, params: CostParams,
                skew_a: float = 1.0, skew_b: float = 1.0,
                pre_a: bool = False, pre_b: bool = False) -> float:
    """Dispatch to the per-method overall cost. Broadcast-family methods are
    skew-invariant (B is fully replicated regardless of key distribution and
    A never moves); shuffle-family methods are charged at the straggler.
    ``pre_a``/``pre_b`` mark pre-partitioned sides whose shuffle is elided —
    they only discount the plain shuffle methods (salting re-keys the data,
    so a salted exchange can never be elided)."""
    if method is JoinMethod.BROADCAST_HASH:
        return broadcast_hash_cost(size_a, size_b, params)
    if method is JoinMethod.SHUFFLE_HASH:
        return shuffle_hash_cost(size_a, size_b, params, skew_a, skew_b,
                                 pre_a, pre_b)
    if method is JoinMethod.SALTED_SHUFFLE_HASH:
        return salted_shuffle_hash_cost(size_a, size_b, params, skew_a)
    if method is JoinMethod.SHUFFLE_SORT:
        return shuffle_sort_cost(size_a, size_b, card_a, card_b, params,
                                 skew_a, skew_b, pre_a, pre_b)
    if method is JoinMethod.BROADCAST_NL:
        return broadcast_nl_cost(size_a, size_b, card_a, params)
    if method is JoinMethod.CARTESIAN:
        # Round-robin co-shuffle: destinations are key-independent, so the
        # exchange is skew-free by construction.
        return cartesian_cost(size_a, size_b, card_a, params)
    if method is JoinMethod.HYPERCUBE_SHUFFLE:
        # A multi-way method cannot price a binary join: it needs every
        # relation of a cyclic core at once (hypercube_shuffle_cost). As a
        # binary alternative it is never applicable, so Algorithm 1's
        # two-sided comparisons can never pick it.
        return math.inf
    raise ValueError(f"unknown method {method}")


def all_costs(size_a: float, size_b: float, card_a: float, card_b: float,
              params: CostParams,
              skew_a: float = 1.0, skew_b: float = 1.0,
              pre_a: bool = False, pre_b: bool = False
              ) -> Dict[JoinMethod, float]:
    """Costs of every modeled method for one logical join."""
    return {m: method_cost(m, size_a, size_b, card_a, card_b, params,
                           skew_a, skew_b, pre_a, pre_b)
            for m in JoinMethod}


# ---------------------------------------------------------------------------
# Hypercube multi-way shuffle (cyclic join graphs; Shares/HyperCube scheme).
#
# The p tasks are arranged as a hypercube with one axis per join variable
# (equivalence class of join keys), of share d_v per axis with prod(d_v) = p.
# Relation R_i is hash-partitioned on the coordinates of the variables it
# contains (p_i = prod of its axes' shares) and replicated along the axes it
# does not own, a factor f_i = p / p_i. One local multi-way probe per task
# then evaluates the whole cyclic core without materializing any binary
# intermediate — the replication volume sum_i |R_i| * (p / p_i) replaces the
# binary plan's intermediate shuffles.
# ---------------------------------------------------------------------------

def cube_replication(dims: Sequence[int],
                     membership: Sequence[int]) -> int:
    """Replication factor f = p / p_i of a relation owning the axes in
    ``membership`` of a cube with per-axis shares ``dims``."""
    p = 1
    for d in dims:
        p *= d
    owned = 1
    for ax in membership:
        owned *= dims[ax]
    return p // owned


def hypercube_shuffle_cost(sizes: Sequence[float],
                           factors: Sequence[float],
                           params: CostParams) -> float:
    """Overall cost of the hypercube multi-way shuffle join.

    ``sizes[i]`` is |R_i| with R_0 the probe relation; ``factors[i]`` the
    replication factor f_i = p / p_i. Each relation ships f_i copies of
    itself through the exchange (w-weighted network workload
    f_i * ((p-1)/p) |R_i| — the replication volume sum_i |R_i| (p / p_i),
    with the same stays-local discount as Eq. 5), the probe copies are read
    once and every build copy is hashed and probed (the same 1 / 2 local
    coefficients as Eq. 10). At f = 1 for two relations this reproduces
    ``shuffle_hash_cost`` exactly.
    """
    p, w = params.p, params.w
    net = sum(w * (p - 1) / p * f * s for s, f in zip(sizes, factors))
    local = factors[0] * sizes[0]
    local += sum(2.0 * f * s for s, f in zip(sizes[1:], factors[1:]))
    return net + local


def _factorizations(p: int, k: int) -> Iterator[Tuple[int, ...]]:
    """All ordered factorizations of p into k positive factors."""
    if k == 1:
        yield (p,)
        return
    d = 1
    while d <= p:
        if p % d == 0:
            for rest in _factorizations(p // d, k - 1):
                yield (d,) + rest
        d += 1


def cube_shares(p: int, n_axes: int,
                memberships: Sequence[Sequence[int]],
                sizes: Sequence[float],
                params: CostParams) -> Tuple[int, ...]:
    """Optimal per-axis shares (d_0, ..., d_{n_axes-1}) with prod = p.

    Exhaustively enumerates the ordered factorizations of p (p and n_axes
    are tiny) and returns the one minimizing
    :func:`hypercube_shuffle_cost` over the relations' sizes, where
    ``memberships[i]`` lists the axes relation i owns. Ties break toward
    the first enumerated (most-balanced-first is not guaranteed; the cost
    is what matters)."""
    best: Tuple[int, ...] | None = None
    best_cost = math.inf
    for dims in _factorizations(p, n_axes):
        factors = [float(cube_replication(dims, m)) for m in memberships]
        cost = hypercube_shuffle_cost(sizes, factors, params)
        if cost < best_cost:
            best, best_cost = dims, cost
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Runtime bloom-filter pushdown (sideways information passing).
#
# A bloom filter built over the build side's join keys and broadcast to the
# probe side's tasks shrinks the probe dataset *before* its exchange: the
# filtered exchange ships B'_probe = B_probe * keep bytes, where the kept
# fraction is max(sigma_est, fpr) — sigma_est the true key-match fraction
# (estimated from build-side distinct counts over the key domain) and fpr
# the filter's false-positive floor. The filter itself costs its broadcast,
# w * (p-1) * m/8 bytes of network workload (Eq. 1 applied to the m-bit
# array), so Algorithm 1 only plans a filter when the filtered join plus
# that surcharge is strictly cheaper than the unfiltered join.
# ---------------------------------------------------------------------------

#: Default filter budget: bits per distinct build-side key. 10 bits/key at
#: the optimal hash count k = ln2 * m/n gives ~0.8% false positives.
BLOOM_DEFAULT_BITS_PER_KEY = 10

BLOOM_MIN_BITS = 256
BLOOM_MAX_HASHES = 8


def bloom_params(n_keys: float,
                 bits_per_key: int = BLOOM_DEFAULT_BITS_PER_KEY
                 ) -> tuple[int, int]:
    """(m_bits, k) for an expected ``n_keys`` distinct build keys.

    ``m_bits`` is rounded up to a power of two (mask-reduction in the
    kernel, and pow2-quantized sizes reuse XLA compilations across build
    cardinalities, like ``compact_partitions``); ``k`` is the textbook
    optimum ln2 * m/n clamped to [1, BLOOM_MAX_HASHES].
    """
    n = max(int(n_keys), 1)
    m = max(BLOOM_MIN_BITS, 1 << (n * bits_per_key - 1).bit_length())
    k = int(round(math.log(2) * m / n))
    return m, max(1, min(BLOOM_MAX_HASHES, k))


def bloom_fpr(n_keys: float, m_bits: int, k: int) -> float:
    """Predicted false-positive rate (1 - e^{-kn/m})^k of a filter holding
    ``n_keys`` keys in ``m_bits`` bits with ``k`` hashes."""
    if n_keys <= 0:
        return 0.0
    return (1.0 - math.exp(-k * float(n_keys) / float(m_bits))) ** k


def runtime_filter_cost(m_bits: int, params: CostParams) -> float:
    """Workload of shipping the filter: broadcasting the m-bit array to the
    probe side's p tasks (Eq. 1 on m/8 bytes), network-weighted by w."""
    return params.w * (params.p - 1) * m_bits / 8.0


def filter_reduce_cost(m_bits: int, params: CostParams,
                       kind: str = "bloom") -> float:
    """Workload of the distributed filter *build*: the build side's p
    partitions hold disjoint key subsets, so each builds a partial filter
    and the partials are merged across the mesh. The merge's wire shape —
    and therefore its charge — depends on the kind:

      * ``"bloom"`` / ``"zone_map"``: the partial payload has the *same*
        serialized size as the merged one (an m-bit array under OR, a
        64-bit interval under min/max), so the merge is a binary reduce
        tree — ceil(log2 p) rounds of m/8 bytes.
      * ``"semi_join"``: the partial key lists are disjoint subsets whose
        union *is* the payload, so no mid-tree merge can compress them;
        the distributed build is an all_gather whose volume is (p-1)·m/8
        bytes (Eq. 1's convention applied to the gathered list), the same
        shape ``dist_key_set_build`` executes.

    Network-weighted by w; zero at p = 1 (a global build needs no merge).
    """
    if params.p <= 1:
        return 0.0
    if kind == "semi_join":
        return params.w * (params.p - 1) * m_bits / 8.0
    return params.w * math.ceil(math.log2(params.p)) * m_bits / 8.0


def cached_filter_cost(m_bits: int, params: CostParams) -> float:
    """Quote for a cross-query cache *hit*: the payload already exists
    (built and merged by an earlier query), so the build + reduce terms
    drop and only the per-query broadcast to the probe side's tasks
    remains. This is what makes the planner select cached filters more
    aggressively than cold ones — a borderline edge whose reduce tree
    priced it out on a cold cache clears the strictly-cheaper gate once
    the filter is free to re-ship."""
    return runtime_filter_cost(m_bits, params)


def filtered_probe_fraction(sigma_est: float, fpr: float) -> float:
    """Kept fraction of the probe side after a bloom filter: the match
    fraction floored by the filter's false-positive rate."""
    return min(max(max(sigma_est, fpr), 0.0), 1.0)


# ---------------------------------------------------------------------------
# Non-bloom runtime-filter kinds: the same plan-vs-price framing (ship the
# filter iff the filtered join plus the filter's wire cost is strictly
# cheaper) applied to a min/max zone map and an exact semi-join reducer.
# ---------------------------------------------------------------------------

#: Wire size of a zone map: one (min, max) int32 pair.
ZONE_MAP_BITS = 64

#: Wire size per distinct key of the exact semi-join reducer (int32 keys).
SEMI_JOIN_BITS_PER_KEY = 32


def zone_map_cost(params: CostParams) -> float:
    """Total workload of a zone-map filter: reduce the per-partition
    (min, max) pairs up the tree, then broadcast the 8-byte interval
    (Eq. 1). The cheapest reducer the model knows — but only *applicable*
    when the build side's surviving keys are band-shaped, else its keep
    fraction degenerates toward 1."""
    return (runtime_filter_cost(ZONE_MAP_BITS, params)
            + filter_reduce_cost(ZONE_MAP_BITS, params, kind="zone_map"))


def semi_join_cost(n_keys: float, params: CostParams) -> float:
    """Total workload of an exact semi-join reducer over ``n_keys``
    distinct build keys: all_gather the disjoint per-partition key lists
    ((p-1)·n·32/8 bytes — see :func:`filter_reduce_cost`), then broadcast
    the merged n*32-bit list. No false-positive floor — the kept fraction
    is exactly sigma — so it beats bloom when the key list is small
    enough that exactness outprices the denser encoding."""
    bits = max(n_keys, 0.0) * SEMI_JOIN_BITS_PER_KEY
    return (runtime_filter_cost(bits, params)
            + filter_reduce_cost(bits, params, kind="semi_join"))


def bloom_total_cost(m_bits: int, params: CostParams) -> float:
    """Total workload of a bloom filter: OR-reduce the per-partition
    partial bit arrays up the tree, then broadcast the merged m bits."""
    return (runtime_filter_cost(m_bits, params)
            + filter_reduce_cost(m_bits, params, kind="bloom"))


# ---------------------------------------------------------------------------
# The relative-size criterion (Eq. 13).
# ---------------------------------------------------------------------------

def k0_threshold(params: CostParams, skew: float = 1.0) -> float:
    """Eq. 13: k0 = (pw + p - w)/w — broadcast wins iff |A| > k0 |B|.

    For w -> 0 the threshold diverges (broadcast's extra build work p|B| can
    never be amortized by saving network), matching §5.5's observation that
    small w makes RelJoin behave like the forced-shuffle strategies.

    With probe-side key skew ``s`` (both sides charged at the straggler) the
    shuffle side of Eq. 13's comparison inflates and the threshold drops:

        k0(s) = (g*p + 1 - s*(g+1)) / (s*g - 1),   g = (wp - w + p)/p,

    which reduces to the paper's k0 at s=1 and can reach 0 for extreme skew
    (broadcast always wins — it is skew-invariant).
    """
    p, w = params.p, params.w
    if skew <= 1.0:
        if w == 0:
            return math.inf
        return (p * w + p - w) / w
    # g = 1 + w(p-1)/p >= 1, so skew*g > 1 on this (skew > 1) path and the
    # denominator is always positive.
    g = (w * p - w + p) / p
    return max((g * p + 1 - skew * (g + 1)) / (skew * g - 1), 0.0)


def relative_size(size_a: float, size_b: float) -> float:
    """k such that |A| = k|B| (inf when B is empty)."""
    if size_b <= 0:
        return math.inf
    return size_a / size_b


def broadcast_preferred(size_a: float, size_b: float, params: CostParams,
                        skew: float = 1.0) -> bool:
    """True iff C_broadcastHash < C_shuffleHash, i.e. k > k0 (paper §3.6.2).
    ``skew`` is the probe-side straggler factor (1.0 = paper's rule)."""
    return relative_size(size_a, size_b) > k0_threshold(params, skew)


#: Checkpoint re-optimization trigger: re-plan the remaining join order
#: when a measured intermediate's cardinality diverges from its estimate
#: by more than this q-error (max(est/meas, meas/est), one-row-floored).
#: 3x is loose enough that histogram-backed estimates on uniform data
#: never trip it and tight enough that compounding-predicate or skew
#: misestimates (the cases where re-planning flips a method) always do.
DEFAULT_REOPT_QERROR: float = 3.0
