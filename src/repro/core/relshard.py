"""RelShard: the paper's relative-cost selection applied to sharded-LM ops.

Every "join-like" tensor op — embedding lookup, LM head, MoE dispatch —
faces the paper's §3.6.2 decision: *broadcast* the small table across the
model axis, or *shuffle* activations between shards. We evaluate the very
same cost equations (Eqs. 4/10, threshold Eq. 13) with:

    |A| = bytes of the activations that the shuffle-analogue would move
    |B| = bytes of the weight table the broadcast-analogue would replicate
    p   = model-axis size (the join parallelism)
    w   = network-vs-compute weight, derived from chip constants
          (HBM bandwidth / ICI bandwidth for the v5e target) instead of the
          paper's GbE testbed value of 1 — recorded per decision.

Training amortizes nothing: the broadcast-analogue re-gathers the table
every step (FSDP), so the paper's equations apply verbatim. Serving keeps
weights resident, so the broadcast term amortizes to ~0 and the decision
degenerates to Algorithm 1's memory-feasibility gate ("hashing allowed"),
which we mirror with an HBM budget check.

The planner also fixes the generic mesh rules (batch/fsdp/tensor axes) that
the model builders consume.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..models.config import Family, ModelConfig, ShapeConfig
from .cost_model import (CostParams, broadcast_hash_cost, k0_threshold,
                         shuffle_hash_cost)

# v5e target constants (same as §Roofline): 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI.
HBM_GBPS = 819.0
ICI_GBPS = 50.0
W_TPU_DEFAULT = HBM_GBPS / ICI_GBPS        # ~16.4
HBM_BUDGET_BYTES = 16 * 1024 ** 3          # v5e chip HBM

ACT_BYTES = 2   # bf16 activations
PARAM_BYTES = 4  # fp32 params


@dataclasses.dataclass(frozen=True)
class OpDecision:
    """Audit record of one planned op (the RelShard analogue of a paper
    join-method selection)."""

    op: str
    strategy: str
    size_a: float     # activation bytes (shuffle side)
    size_b: float     # table bytes (broadcast side)
    k: float
    k0: float
    cost_broadcast: float
    cost_shuffle: float
    reason: str


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Everything the model builders need to place tensors on the mesh."""

    batch_axes: Tuple[str, ...]        # e.g. ("pod", "data")
    model_axis: str                    # "model"
    fsdp_axes: Tuple[str, ...]         # param sharding over data axes
    embed_strategy: str                # replicate | vocab_parallel
    head_strategy: str
    moe_strategy: str                  # replicate | expert_parallel
    w: float
    #: per-block weights: 'tensor_parallel' (shuffle activations between
    #: shards — Megatron TP) or 'replicated' (broadcast/gather weights —
    #: pure FSDP/ZeRO; batch spreads over the model axis too). The same
    #: Eq.13 decision as every other op: A = per-layer activation traffic
    #: TP would move, B = per-layer weights FSDP would gather.
    tp: str = "tensor_parallel"
    decisions: Tuple[OpDecision, ...] = ()

    def explain(self) -> str:
        lines = [f"RelShard plan (w={self.w:.2f}):"]
        for d in self.decisions:
            lines.append(
                f"  {d.op:12s} -> {d.strategy:16s} k={d.k:10.2f} "
                f"k0={d.k0:7.2f} C_bcast={d.cost_broadcast:.3e} "
                f"C_shuf={d.cost_shuffle:.3e} ({d.reason})")
        return "\n".join(lines)


def _decide(op: str, size_a: float, size_b: float, p: int, w: float,
            kind: str, broadcast_name: str, shuffle_name: str,
            resident_bytes_budget: float = HBM_BUDGET_BYTES / 4
            ) -> OpDecision:
    """One Eq.13 decision. For decode (resident weights) the broadcast term
    amortizes away and a memory gate decides (Algorithm 1's feasibility)."""
    params = CostParams(p=p, w=w)
    k = size_a / max(size_b, 1.0)
    k0 = k0_threshold(params)
    cb = broadcast_hash_cost(size_a, size_b, params)
    cs = shuffle_hash_cost(size_a, size_b, params)
    if kind == "decode":
        per_device = size_b  # full table resident on every device
        if per_device <= resident_bytes_budget:
            return OpDecision(op, broadcast_name, size_a, size_b, k, k0, cb,
                              cs, "decode: table fits resident HBM budget")
        return OpDecision(op, shuffle_name, size_a, size_b, k, k0, cb, cs,
                          "decode: table exceeds resident budget")
    if k > k0:
        return OpDecision(op, broadcast_name, size_a, size_b, k, k0, cb, cs,
                          f"k > k0 (Eq.13): C_bcast {cb:.3e} < {cs:.3e}")
    return OpDecision(op, shuffle_name, size_a, size_b, k, k0, cb, cs,
                      f"k <= k0 (Eq.13): C_shuf {cs:.3e} <= {cb:.3e}")


def plan_model(cfg: ModelConfig, mesh_axes: Tuple[Tuple[str, int], ...],
               shape: ShapeConfig, w: Optional[float] = None,
               fsdp: bool = True) -> ShardingPlan:
    """Derive the sharding plan for (architecture x input shape x mesh).

    ``mesh_axes``: ((name, size), ...) e.g. (("data", 16), ("model", 16)).
    """
    w = W_TPU_DEFAULT if w is None else w
    names = [n for n, _ in mesh_axes]
    sizes = dict(mesh_axes)
    model_axis = "model"
    batch_axes = tuple(n for n in names if n != model_axis)
    p = sizes[model_axis]
    d = cfg.d_model

    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    decisions: List[OpDecision] = []

    # Embedding lookup: A = token activations, B = vocab table.
    size_a = tokens * d * ACT_BYTES
    size_b = cfg.vocab * d * PARAM_BYTES
    emb = _decide("embedding", size_a, size_b, p, w, shape.kind,
                  "replicate", "vocab_parallel")
    decisions.append(emb)

    # LM head: A = activations + logit reductions, B = head table.
    head = _decide("lm_head", size_a, size_b, p, w, shape.kind,
                   "replicate", "vocab_parallel")
    decisions.append(head)

    # MoE dispatch: A = routed token activations (top_k copies), B = expert
    # weights of one layer.
    moe_strategy = "expert_parallel"
    if cfg.is_moe:
        size_a = tokens * cfg.top_k * d * ACT_BYTES
        size_b = cfg.n_experts * 3 * d * cfg.d_ff * PARAM_BYTES
        moe = _decide("moe_dispatch", size_a, size_b, p, w, shape.kind,
                      "replicate", "expert_parallel",
                      resident_bytes_budget=HBM_BUDGET_BYTES / 2)
        decisions.append(moe)
        moe_strategy = moe.strategy

    # Block weights: TP (shuffle activations) vs pure FSDP (broadcast
    # weights). |A| ~ the ~6 full-width activation passes TP's forward/
    # backward all-reduces move per layer; |B| = one layer's weights in
    # bf16. Gated to the attention-free family on train shapes (where the
    # decision is measurable and batch=256 divides the full mesh).
    tp = "tensor_parallel"
    if cfg.family is Family.SSM and shape.kind == "train":
        n_layers = max(cfg.n_layers, 1)
        blk_bytes = (cfg.param_count() - 2 * cfg.vocab * cfg.d_model) \
            / n_layers * 2.0
        act_bytes = 6.0 * tokens * d * ACT_BYTES
        tp_dec = _decide("block_tp", act_bytes, blk_bytes, p, w, shape.kind,
                         "replicated", "tensor_parallel")
        decisions.append(tp_dec)
        tp = tp_dec.strategy
    emb_strategy = emb.strategy
    head_strategy = head.strategy
    if tp == "replicated":
        # batch spans the model axis too; vocab-parallel's psum-over-model
        # lookup assumes model-replicated ids, so tables fall back to the
        # broadcast strategy (they are FSDP-gathered like block weights).
        batch_axes = batch_axes + (model_axis,)
        emb_strategy = "replicate"
        head_strategy = "replicate"

    return ShardingPlan(
        batch_axes=batch_axes,
        model_axis=model_axis,
        fsdp_axes=tuple(a for a in batch_axes if a == "data") if fsdp
        else (),
        embed_strategy=emb_strategy,
        head_strategy=head_strategy,
        moe_strategy=moe_strategy,
        w=w,
        tp=tp,
        decisions=tuple(decisions),
    )


def replan(plan: ShardingPlan, cfg: ModelConfig,
           mesh_axes: Tuple[Tuple[str, int], ...], shape: ShapeConfig,
           measured_tokens: int) -> ShardingPlan:
    """Stage-boundary re-optimization (paper §4.1): adapt the plan to the
    *measured* token throughput (e.g. serving batch occupancy). Returns a
    possibly different plan; the caller recompiles when it changed."""
    scaled = dataclasses.replace(shape,
                                 global_batch=max(measured_tokens, 1),
                                 seq_len=1 if shape.kind == "decode"
                                 else shape.seq_len)
    return plan_model(cfg, mesh_axes, scaled, w=plan.w,
                      fsdp=bool(plan.fsdp_axes))
