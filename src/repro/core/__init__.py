"""RelJoin core: the paper's cost model, selection algorithm, adaptive
statistics, plan (re-)optimization, the PSTS metric, and the RelShard
tensor-op planner that applies the same cost model to sharded-LM collectives.
"""

from .cost_model import (CostParams, JoinMethod, RANK, all_costs,
                         bloom_total_cost, broadcast_hash_cost,
                         broadcast_nl_cost, broadcast_preferred,
                         cached_filter_cost, cartesian_cost,
                         default_salt_factor, filter_reduce_cost,
                         k0_threshold, method_cost, relative_size,
                         salted_shuffle_hash_cost, semi_join_cost,
                         shuffle_hash_cost, shuffle_sort_cost,
                         zone_map_cost)
from .psts import (PSTSReport, compute_psts, distinct_count, key_set,
                   selections_differ, semi_join_mask)
from .selection import (AQE_BROADCAST_THRESHOLD_BYTES, INNER_LIKE,
                        JoinProperties, JoinType, Selection,
                        select_absolute_size, select_forced,
                        select_join_method)
from .stats import (DEFAULT_WATERMARK_BYTES, StatsSource, TableStats,
                    estimate_filter, estimate_group_by, estimate_join,
                    estimate_project, unknown_stats)

__all__ = [
    "CostParams", "JoinMethod", "RANK", "all_costs", "bloom_total_cost",
    "broadcast_hash_cost", "broadcast_nl_cost", "broadcast_preferred",
    "cached_filter_cost", "cartesian_cost", "default_salt_factor",
    "filter_reduce_cost", "k0_threshold", "method_cost", "relative_size",
    "salted_shuffle_hash_cost", "semi_join_cost", "shuffle_hash_cost",
    "shuffle_sort_cost", "zone_map_cost", "PSTSReport", "compute_psts",
    "distinct_count", "key_set", "selections_differ", "semi_join_mask",
    "AQE_BROADCAST_THRESHOLD_BYTES", "INNER_LIKE", "JoinProperties",
    "JoinType", "Selection", "select_absolute_size", "select_forced",
    "select_join_method", "DEFAULT_WATERMARK_BYTES", "StatsSource",
    "TableStats", "estimate_filter", "estimate_group_by", "estimate_join",
    "estimate_project", "unknown_stats",
]
