"""Performance Sensitivity To Selections (PSTS) — paper §5.4, Table 5.

PSTS = %TimeDiff / %JoinDiff with a baseline strategy (AQE in the paper):

    %JoinDiff = (# joins where the strategy and the baseline select different
                 methods) / (total # joins) * 100
    %TimeDiff = (baseline total time - strategy total time)
                / baseline total time * 100

PSTS > 0: the strategy's differing selections help; ~1 means 1% of selection
changes buys 1% completion-time reduction. Near 0 / negative: ineffective or
harmful (paper: ShuffleSort -0.03, ShuffleHash -0.04, RelJoin 1.98).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .cost_model import JoinMethod


def _is_shuffle(m: JoinMethod) -> bool:
    # Paper §5.4 treats shuffle sort and shuffle hash as the same method when
    # counting selection differences (their performance is near-identical).
    return m in (JoinMethod.SHUFFLE_SORT, JoinMethod.SHUFFLE_HASH,
                 JoinMethod.SALTED_SHUFFLE_HASH, JoinMethod.CARTESIAN)


def selections_differ(m1: JoinMethod, m2: JoinMethod) -> bool:
    """Broadcast-vs-shuffle is the difference that matters (paper §5.4)."""
    return _is_shuffle(m1) != _is_shuffle(m2)


@dataclasses.dataclass(frozen=True)
class PSTSReport:
    n_join_diff: int
    n_joins: int
    cost_diff: float
    time_diff: float
    pct_join_diff: float
    pct_time_diff: float
    psts: float

    def cost_diff_per_join(self) -> float:
        return self.cost_diff / self.n_join_diff if self.n_join_diff else 0.0

    def time_diff_per_join(self) -> float:
        return self.time_diff / self.n_join_diff if self.n_join_diff else 0.0


def compute_psts(strategy_methods: Sequence[JoinMethod],
                 baseline_methods: Sequence[JoinMethod],
                 strategy_time: float, baseline_time: float,
                 strategy_costs: Sequence[float] = (),
                 baseline_costs: Sequence[float] = ()) -> PSTSReport:
    """Compute the Table-5 statistics for one benchmark run."""
    if len(strategy_methods) != len(baseline_methods):
        raise ValueError("selection sequences must align join-for-join")
    n = len(strategy_methods)
    diffs = [i for i in range(n)
             if selections_differ(strategy_methods[i], baseline_methods[i])]
    cost_diff = 0.0
    if strategy_costs and baseline_costs:
        cost_diff = sum(baseline_costs[i] - strategy_costs[i] for i in diffs)
    time_diff = baseline_time - strategy_time
    pct_join = 100.0 * len(diffs) / n if n else 0.0
    pct_time = 100.0 * time_diff / baseline_time if baseline_time else 0.0
    psts = pct_time / pct_join if pct_join else 0.0
    return PSTSReport(len(diffs), n, cost_diff, time_diff, pct_join, pct_time,
                      psts)
