"""Performance Sensitivity To Selections (PSTS) — paper §5.4, Table 5 —
plus the distinct-key machinery the exact semi-join reducer builds on.

PSTS = %TimeDiff / %JoinDiff with a baseline strategy (AQE in the paper):

    %JoinDiff = (# joins where the strategy and the baseline select different
                 methods) / (total # joins) * 100
    %TimeDiff = (baseline total time - strategy total time)
                / baseline total time * 100

PSTS > 0: the strategy's differing selections help; ~1 means 1% of selection
changes buys 1% completion-time reduction. Near 0 / negative: ineffective or
harmful (paper: ShuffleSort -0.03, ShuffleHash -0.04, RelJoin 1.98).

The selection-difference accounting above and semi-join reduction answer
the same underlying question — *which distinct join keys actually
participate?* — so the distinct-key helpers live here: ``key_set`` folds a
(possibly duplicated, partially invalid) key column into a sorted
membership structure, ``distinct_count`` sizes it, and ``semi_join_mask``
is the exact probe — the zero-false-positive reducer the runtime-filter
planner weighs against bloom filters and zone maps.

**Distributed-equivalence contract.** ``key_set`` is a pure function of
the key *set* (order- and duplication-invariant, canonical sorted
serialization), which makes it the merge operator of its own distributed
build: ``joins.distributed.dist_key_set_build`` runs ``key_set`` per
device, all_gathers the partial lists, and merge-dedupes with a second
``key_set`` pass — value-identical (array and count) to the global
``key_set`` over the concatenated column at any device count, because
distinct-of-union equals union-of-distincts. ``semi_join_mask`` therefore
produces the same probe mask whether its key set was built globally or
distributed — the property the runtime-filter executor and the
cross-query ``FilterCache`` both rest on.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .cost_model import JoinMethod

#: Sentinel used to pad the sorted key set to its static capacity. Chosen
#: as INT32_MAX so padding sorts to the tail; a real key equal to the
#: sentinel would be indistinguishable from padding, so ``key_set`` tracks
#: the live count separately and ``semi_join_mask`` only consults the
#: live prefix.
KEY_SET_SENTINEL = 2 ** 31 - 1


def key_set(keys: jax.Array, valid: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array]:
    """Sorted distinct-key membership structure of the valid entries.

    Returns ``(sorted_keys, n_distinct)``: an int32 array of the input's
    flattened (static) shape — distinct live keys sorted ascending, then
    sentinel padding — and the scalar count of distinct live keys. Pure
    function of the key *set*: duplicates and input order do not change
    the result (the property serialization / bit-identity tests pin).
    """
    flat = keys.reshape(-1).astype(jnp.int32)
    v = (jnp.ones(flat.shape, jnp.bool_) if valid is None
         else valid.reshape(-1).astype(jnp.bool_))
    if flat.shape[0] == 0:
        return flat, jnp.int32(0)
    # Invalid rows sort to the tail as sentinels; duplicate live keys are
    # then sentinel-ed too (first occurrence wins) and re-sorted away.
    # Positions < n_valid hold exactly the sorted live keys, so masking the
    # duplicate test to that prefix keeps the arithmetic correct even for a
    # live key that happens to equal the sentinel value.
    s = jnp.sort(jnp.where(v, flat, KEY_SET_SENTINEL))
    n_valid = jnp.sum(v)
    live = jnp.arange(s.shape[0]) < n_valid
    dup = jnp.concatenate([jnp.zeros((1,), jnp.bool_),
                           s[1:] == s[:-1]]) & live
    distinct = jnp.sort(jnp.where(dup, KEY_SET_SENTINEL, s))
    return distinct, n_valid - jnp.sum(dup)


def distinct_count(keys: jax.Array, valid: jax.Array | None = None) -> int:
    """Concrete number of distinct valid keys (host sync)."""
    _, n = key_set(keys, valid)
    return int(n)


def semi_join_mask(probe_keys: jax.Array, sorted_keys: jax.Array,
                   n: jax.Array | int | None = None) -> jax.Array:
    """Exact membership mask of ``probe_keys`` against a ``key_set``.

    Binary search on the sorted array (log2 n compares per probe, all
    vectorized) — no hashing, no false positives, no false negatives.
    ``n`` bounds the live prefix; rows landing in the sentinel padding are
    rejected. Same shape as ``probe_keys``.
    """
    flat = probe_keys.reshape(-1).astype(jnp.int32)
    if sorted_keys.shape[0] == 0:
        return jnp.zeros(probe_keys.shape, jnp.bool_)
    idx = jnp.searchsorted(sorted_keys, flat)
    idx = jnp.clip(idx, 0, sorted_keys.shape[0] - 1)
    hit = jnp.take(sorted_keys, idx) == flat
    if n is not None:
        hit = hit & (idx < n)
    else:
        hit = hit & (jnp.take(sorted_keys, idx) != KEY_SET_SENTINEL)
    return hit.reshape(probe_keys.shape)


def _is_shuffle(m: JoinMethod) -> bool:
    # Paper §5.4 treats shuffle sort and shuffle hash as the same method when
    # counting selection differences (their performance is near-identical).
    return m in (JoinMethod.SHUFFLE_SORT, JoinMethod.SHUFFLE_HASH,
                 JoinMethod.SALTED_SHUFFLE_HASH, JoinMethod.CARTESIAN)


def selections_differ(m1: JoinMethod, m2: JoinMethod) -> bool:
    """Broadcast-vs-shuffle is the difference that matters (paper §5.4)."""
    return _is_shuffle(m1) != _is_shuffle(m2)


@dataclasses.dataclass(frozen=True)
class PSTSReport:
    n_join_diff: int
    n_joins: int
    cost_diff: float
    time_diff: float
    pct_join_diff: float
    pct_time_diff: float
    psts: float

    def cost_diff_per_join(self) -> float:
        return self.cost_diff / self.n_join_diff if self.n_join_diff else 0.0

    def time_diff_per_join(self) -> float:
        return self.time_diff / self.n_join_diff if self.n_join_diff else 0.0


def compute_psts(strategy_methods: Sequence[JoinMethod],
                 baseline_methods: Sequence[JoinMethod],
                 strategy_time: float, baseline_time: float,
                 strategy_costs: Sequence[float] = (),
                 baseline_costs: Sequence[float] = ()) -> PSTSReport:
    """Compute the Table-5 statistics for one benchmark run."""
    if len(strategy_methods) != len(baseline_methods):
        raise ValueError("selection sequences must align join-for-join")
    n = len(strategy_methods)
    diffs = [i for i in range(n)
             if selections_differ(strategy_methods[i], baseline_methods[i])]
    cost_diff = 0.0
    if strategy_costs and baseline_costs:
        cost_diff = sum(baseline_costs[i] - strategy_costs[i] for i in diffs)
    time_diff = baseline_time - strategy_time
    pct_join = 100.0 * len(diffs) / n if n else 0.0
    pct_time = 100.0 * time_diff / baseline_time if baseline_time else 0.0
    psts = pct_time / pct_join if pct_join else 0.0
    return PSTSReport(len(diffs), n, cost_diff, time_diff, pct_join, pct_time,
                      psts)
