"""Serving substrate: batched request scheduling over prefill/decode."""

from .engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
