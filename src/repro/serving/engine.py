"""Batched serving engine: continuous-batching decode over the model's
cache, with RelShard stage-boundary re-planning on measured occupancy.

The engine keeps one fixed-shape decode program (batch = ``max_batch``) and
fills slots from a request queue (continuous batching). Measured occupancy
is the adaptive runtime statistic: ``maybe_replan`` re-runs the planner
with it (paper §4.1 re-optimization) and reports when the physical plan
would change, letting the driver swap compiled executables at a stage
boundary.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.relshard import ShardingPlan, replan
from ..models import lm
from ..models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, plan: ShardingPlan, mesh, params,
                 max_batch: int = 8, max_seq: int = 512,
                 mesh_axes=None, shape: Optional[ShapeConfig] = None):
        self.cfg, self.plan, self.mesh, self.params = cfg, plan, mesh, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mesh_axes, self.shape = mesh_axes, shape
        self.cache = lm.init_cache(cfg, max_batch, max_seq)
        self.slots: List[Optional[Request]] = [None] * max_batch
        # FIFO admission queue. A deque: admission pops from the head on
        # every step, and list.pop(0) is O(n) per admit — quadratic drain
        # under deep backlogs (the serving regime this engine exists for).
        self.queue: Deque[Request] = collections.deque()
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, plan, mesh, t, c))
        self.replan_events: List[str] = []

    # -- queueing -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # teacher-force the prompt through decode steps for slot i
                # (per-slot prefill; batched prefill is the prefill_* path)
                for tok in req.prompt[:-1]:
                    self._step_single(i, tok)
                req._next = req.prompt[-1]

    def _step_single(self, i: int, tok: int) -> None:
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[i, 0] = tok
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)

    # -- decode ----------------------------------------------------------------

    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> Dict[int, int]:
        """One batched decode step for all live slots. Returns {rid: token}."""
        self._admit()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None:
                tokens[i, 0] = getattr(req, "_next", req.prompt[-1])
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        out = np.asarray(jnp.argmax(logits, axis=-1))
        emitted: Dict[int, int] = {}
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(out[i])
            req.out.append(tok)
            req._next = tok
            emitted[req.rid] = tok
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        return emitted

    # -- adaptive re-planning ----------------------------------------------------

    def maybe_replan(self) -> Optional[ShardingPlan]:
        """Paper §4.1 step 2-3 at a serving stage boundary: feed measured
        occupancy (runtime statistic) back into the cost model. Returns the
        new plan if any strategy changed (caller recompiles), else None."""
        if self.mesh_axes is None or self.shape is None:
            return None
        new = replan(self.plan, self.cfg, self.mesh_axes, self.shape,
                     measured_tokens=max(self.occupancy(), 1))
        changed = (new.embed_strategy != self.plan.embed_strategy
                   or new.head_strategy != self.plan.head_strategy
                   or new.moe_strategy != self.plan.moe_strategy)
        if changed:
            self.replan_events.append(
                f"occupancy={self.occupancy()}: "
                f"embed {self.plan.embed_strategy}->{new.embed_strategy}, "
                f"moe {self.plan.moe_strategy}->{new.moe_strategy}")
            return new
        return None
