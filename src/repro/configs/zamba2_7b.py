"""zamba2-7b [hybrid]: Mamba2 + shared attention blocks
[arXiv:2411.15242; unverified]. 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64. Shared attn block applied every 6 mamba blocks
(weights shared). long_500k uses a 4096-token sliding window for the shared
attention (DESIGN.md §Arch-applicability)."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family=Family.HYBRID,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, ssm_state=64, attn_every=6,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=5, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=128, vocab=128, ssm_state=8,
                            attn_every=2)
