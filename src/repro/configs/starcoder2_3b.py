"""starcoder2-3b [dense]: GQA, RoPE [arXiv:2402.19173; hf].
30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family=Family.DENSE,
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, mlp_activation="gelu",
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=96, n_heads=4,
                            n_kv_heads=2, d_ff=256, vocab=128)
