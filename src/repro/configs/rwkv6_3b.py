"""rwkv6-3b [ssm]: Finch — data-dependent decay [arXiv:2404.05892; hf].
32L d_model=2560 (attn-free) d_ff=8960 vocab=65536."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family=Family.SSM,
    n_layers=32, d_model=2560, n_heads=40, d_ff=8960, vocab=65536,
    rwkv_head_dim=64,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            d_ff=128, vocab=128, rwkv_head_dim=16)
