"""qwen3-moe-235b-a22b [moe]: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
head_dim=128 (Qwen3 convention). Adafactor optimizer (memory)."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family=Family.MOE,
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, n_experts=128, top_k=8,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=64, vocab=128,
                            n_experts=8, top_k=2)
