"""paligemma-3b [vlm]: SigLIP + gemma [arXiv:2407.07726; hf].
18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216. head_dim=256
(gemma convention). The SigLIP frontend is a STUB: input_specs provides
precomputed patch embeddings (B, 256, d)."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family=Family.VLM,
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, n_cond_tokens=256, mlp_activation="geglu",
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=1, head_dim=16, d_ff=256, vocab=256,
                            n_cond_tokens=8)
