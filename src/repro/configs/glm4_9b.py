"""glm4-9b [dense]: RoPE, GQA [hf:THUDM/glm-4-9b; hf].
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family=Family.DENSE,
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=256, vocab=160)
