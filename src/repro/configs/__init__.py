"""Assigned architecture configs (exact public-literature values; sources
in each module docstring) + reduced smoke variants + the engine benchmark
config."""

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCH_IDS = [
    "musicgen_large", "granite_8b", "tinyllama_1_1b", "starcoder2_3b",
    "glm4_9b", "dbrx_132b", "qwen3_moe_235b_a22b", "zamba2_7b",
    "paligemma_3b", "rwkv6_3b",
]

#: CLI-facing ids (hyphenated, as assigned) -> module names.
ARCH_ALIASES = {
    "musicgen-large": "musicgen_large",
    "granite-8b": "granite_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "starcoder2-3b": "starcoder2_3b",
    "glm4-9b": "glm4_9b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-7b": "zamba2_7b",
    "paligemma-3b": "paligemma_3b",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(arch: str) -> ModelConfig:
    mod = ARCH_ALIASES.get(arch, arch)
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = ARCH_ALIASES.get(arch, arch)
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
