"""musicgen-large [audio]: decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec/text frontend is a STUB: input_specs provides precomputed
conditioning frame embeddings (B, n_cond, d)."""

import dataclasses

from ..models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family=Family.AUDIO,
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, n_cond_tokens=64, mlp_activation="gelu",
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=256, vocab=128,
                            n_cond_tokens=4)
