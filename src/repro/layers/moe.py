"""Mixture-of-Experts with RelJoin-planned dispatch.

MoE dispatch IS a distributed join (DESIGN.md §2): tokens (probe side A)
are matched to experts (build side B). The two physical methods are the
paper's two exchanges:

  * ``expert_parallel`` (shuffle-hash analogue): experts sharded over the
    ``model`` mesh axis; token assignments are packed into per-destination
    slots (the engine's ``slot_scatter``) and moved with ``all_to_all`` —
    exactly the slotted shuffle of ``repro.joins``.
  * ``replicate`` (broadcast-hash analogue): every device holds all experts
    (weights replicated / all-gathered); tokens never move.

``repro.core.relshard`` picks the strategy with the paper's cost equations
(k vs k0). The router's measured per-expert token counts are the adaptive
runtime statistics for re-planning capacity.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..joins.slots import slot_scatter
from .common import COMPUTE_DTYPE, PARAM_DTYPE, _dense_init


class MoEAux(NamedTuple):
    load: jax.Array          # (E,) tokens routed per expert (runtime stat)
    aux_loss: jax.Array      # load-balancing loss (Switch-style)
    dropped: jax.Array       # () fraction of assignments dropped by capacity


def moe_init(key, d: int, ff: int, n_experts: int):
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": _dense_init(kr, (d, n_experts)),
        "w_gate": jax.random.normal(kg, (n_experts, d, ff), PARAM_DTYPE)
        * d ** -0.5,
        "w_up": jax.random.normal(ku, (n_experts, d, ff), PARAM_DTYPE)
        * d ** -0.5,
        "w_down": jax.random.normal(kd, (n_experts, ff, d), PARAM_DTYPE)
        * ff ** -0.5,
    }


def _route(params, x2d, n_experts: int, top_k: int):
    """x2d: (N, d) -> gates (N, K), expert ids (N, K), aux loss pieces."""
    logits = (x2d @ params["router"].astype(COMPUTE_DTYPE)).astype(
        jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # Switch-transformer load balance loss: E * sum_e f_e * p_e.
    onehot = jax.nn.one_hot(expert_ids[:, 0], n_experts)
    f = jnp.mean(onehot, axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(f * pbar)
    load = jnp.sum(jax.nn.one_hot(expert_ids, n_experts,
                                  dtype=jnp.int32), axis=(0, 1))
    return gate_vals, expert_ids.astype(jnp.int32), aux, load


def _expert_ffn(w_gate, w_up, w_down, xe):
    """xe: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(COMPUTE_DTYPE))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(COMPUTE_DTYPE))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      w_down.astype(COMPUTE_DTYPE))


def _inverse_slots(idx: jax.Array, n_src: int) -> jax.Array:
    """Given slots->source idx (nd, cap), return source->flat-slot (n_src,)
    with -1 for unplaced sources."""
    flat = idx.reshape(-1)
    pos = jnp.arange(flat.shape[0], dtype=jnp.int32)
    inv = jnp.full((n_src,), -1, jnp.int32)
    return inv.at[jnp.where(flat >= 0, flat, n_src)].set(pos, mode="drop")


def _gather0(x, idx):
    safe = jnp.maximum(idx, 0)
    out = jnp.take(x, safe, axis=0)
    mask = (idx >= 0)
    return jnp.where(mask.reshape(mask.shape + (1,) * (out.ndim - mask.ndim)),
                     out, 0), mask


# ---------------------------------------------------------------------------
# replicate strategy (broadcast-hash analogue): all experts local.
# ---------------------------------------------------------------------------

def _moe_replicated(params, x, n_experts, top_k, capacity_factor):
    B, S, d = x.shape
    x2 = x.reshape(B * S, d).astype(COMPUTE_DTYPE)
    gates, eids, aux, load = _route(params, x2, n_experts, top_k)
    N = B * S * top_k
    tok = jnp.repeat(jnp.arange(B * S, dtype=jnp.int32), top_k)
    dest = eids.reshape(-1)
    cap = max(8, int(N / n_experts * capacity_factor))
    scat = slot_scatter(dest, jnp.ones((N,), bool), n_experts, cap)
    xe, _ = _gather0(x2, jnp.take(tok, jnp.maximum(scat.idx, 0)))
    xe = jnp.where((scat.idx >= 0)[..., None], xe, 0)      # (E, cap, d)
    ye = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], xe)
    # combine back: scatter expert outputs to assignments, weight, sum over K
    inv = _inverse_slots(scat.idx, N)                      # (N,)
    y_asn, mask = _gather0(ye.reshape(-1, d), inv)         # (N, d)
    y_asn = y_asn * gates.reshape(-1)[:, None].astype(COMPUTE_DTYPE)
    y2 = jnp.zeros((B * S, d), COMPUTE_DTYPE).at[tok].add(y_asn)
    dropped = 1.0 - jnp.mean(mask.astype(jnp.float32))
    return y2.reshape(B, S, d), MoEAux(load, aux, dropped)


# ---------------------------------------------------------------------------
# expert_parallel strategy (shuffle-hash analogue): slotted all_to_all.
# ---------------------------------------------------------------------------

def _moe_expert_parallel_body(params_loc, x_loc, *, axis, n_experts, top_k,
                              capacity_factor):
    """shard_map body. params experts sharded on axis; x replicated over it.

    x_loc: (Bl, S, d); expert weights: (El, d, ff) with El = E/p.
    """
    p = jax.lax.axis_size(axis)
    El = n_experts // p
    B, S, d = x_loc.shape
    x2 = x_loc.reshape(B * S, d).astype(COMPUTE_DTYPE)
    gates, eids, aux, load = _route(params_loc, x2, n_experts, top_k)

    N = B * S * top_k
    tok = jnp.repeat(jnp.arange(B * S, dtype=jnp.int32), top_k)
    dest_shard = (eids // El).reshape(-1)                  # owning device
    local_eid = (eids % El).reshape(-1)                    # expert id there
    cap = max(8, int(N / p * capacity_factor))

    # exchange 1: tokens -> expert shards (the slotted shuffle).
    scat = slot_scatter(dest_shard, jnp.ones((N,), bool), p, cap)
    x_send, _ = _gather0(x2, jnp.take(tok, jnp.maximum(scat.idx, 0)))
    x_send = jnp.where((scat.idx >= 0)[..., None], x_send, 0)  # (p, cap, d)
    e_send = jnp.where(scat.idx >= 0,
                       jnp.take(local_eid, jnp.maximum(scat.idx, 0)), -1)
    x_recv = jax.lax.all_to_all(x_send, axis, 0, 0)        # (p, cap, d)
    e_recv = jax.lax.all_to_all(e_send, axis, 0, 0)        # (p, cap)

    # local join: group received tokens by local expert, run the FFN.
    Nr = p * cap
    e_flat = e_recv.reshape(Nr)
    cap2 = max(8, int(Nr / El * capacity_factor))
    scat2 = slot_scatter(e_flat, e_flat >= 0, El, cap2)
    xe, _ = _gather0(x_recv.reshape(Nr, d), scat2.idx)     # (El, cap2, d)
    ye = _expert_ffn(params_loc["w_gate"], params_loc["w_up"],
                     params_loc["w_down"], xe)

    # reverse local grouping, exchange back, combine.
    inv2 = _inverse_slots(scat2.idx, Nr)
    y_recv, m2 = _gather0(ye.reshape(-1, d), inv2)         # (Nr, d)
    y_back = jax.lax.all_to_all(y_recv.reshape(p, cap, d), axis, 0, 0)
    inv1 = _inverse_slots(scat.idx, N)
    y_asn, m1 = _gather0(y_back.reshape(p * cap, d), inv1)  # (N, d)
    y_asn = y_asn * gates.reshape(-1)[:, None].astype(COMPUTE_DTYPE)
    y2 = jnp.zeros((B * S, d), COMPUTE_DTYPE).at[tok].add(y_asn)

    dropped = 1.0 - jnp.mean((m1 & (inv1 >= 0)).astype(jnp.float32))
    # global runtime stats over the data axis shards stay local here; the
    # trainer psums metrics outside.
    return y2.reshape(B, S, d), load, aux, dropped


def moe_apply(params, x, *, mesh, batch_axes, model_axis, n_experts, top_k,
              strategy: str, capacity_factor: float = 1.5):
    """Dispatch through the planned strategy. Returns (y, MoEAux)."""
    if strategy == "replicate" or mesh is None:
        return _moe_replicated(params, x, n_experts, top_k, capacity_factor)

    if strategy != "expert_parallel":
        raise ValueError(f"unknown MoE strategy {strategy}")

    B, S, d = x.shape
    p = mesh.shape[model_axis]
    # Train/prefill: the sequence dim is co-sharded over the model axis so
    # every device dispatches a distinct token slice (no duplicated
    # routing). Decode (S=1) keeps tokens replicated over model — each
    # shard redundantly routes the tiny token batch — and the pmean below
    # both de-duplicates and proves replication to shard_map.
    seq_shard = S % p == 0 and S >= p
    x_spec = (P(batch_axes, model_axis, None) if seq_shard
              else P(batch_axes, None, None))
    all_axes = tuple(batch_axes) + (model_axis,)

    def body(rp, wg, wu, wd, xl):
        y, load, aux, dropped = _moe_expert_parallel_body(
            {"router": rp, "w_gate": wg, "w_up": wu, "w_down": wd}, xl,
            axis=model_axis, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor)
        # In the decode path tokens are replicated over the model axis, so
        # the diagnostics (and y) are already invariant there — VMA tracks
        # this; only reduce over axes where values actually vary.
        red = all_axes if seq_shard else tuple(batch_axes)
        aux = jax.lax.pmean(aux, red) if red else aux
        dropped = jax.lax.pmean(dropped, red) if red else dropped
        load = (jax.lax.psum(load.astype(jnp.float32), red) if red
                else load.astype(jnp.float32))
        if not seq_shard:
            # y went through the all_to_all roundtrip, which VMA marks as
            # model-varying even though the copies are identical; the pmean
            # de-duplicates and proves replication for out_specs.
            y = jax.lax.pmean(y, model_axis)
        return y, load, aux, dropped

    y, load, aux, dropped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(model_axis), P(model_axis), P(model_axis), x_spec),
        out_specs=(x_spec, P(), P(), P()),
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"],
      x)
    return y, MoEAux(load, aux, dropped)
