"""Mamba2-style SSD block (zamba2's mixer) with chunked-parallel training.

Recurrence (per head h, scalar decay): state (hd, n) evolves as

    S_t = a_t * S_{t-1} + dt_t * (x_t outer B_t),   y_t = S_t @ C_t + D * x_t
    a_t = exp(-softplus(dt_raw_t) * exp(A_log_h))

Training/prefill uses the exact chunked form: within a chunk the scalar
decays factor into (t, s) decay matrices (cheap — scalar per pair); across
chunks a single carried state. Decode keeps the state and applies one step.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, PARAM_DTYPE, _dense_init


class SSMState(NamedTuple):
    s: jax.Array   # (B, H, hd, n) carried state
    conv: jax.Array  # (B, H*hd, k-1) causal-conv tail (decode)


CONV_K = 4


def ssm_init(key, d_model: int, n_state: int, n_heads: int):
    d_inner = 2 * d_model
    hd = d_inner // n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": _dense_init(k1, (d_model,
                                 2 * d_inner + 2 * n_state + n_heads)),
        "w_out": _dense_init(k2, (d_inner, d_model)),
        "conv_w": jax.random.normal(k3, (CONV_K, d_inner), PARAM_DTYPE)
        * CONV_K ** -0.5,
        "A_log": jnp.zeros((n_heads,), PARAM_DTYPE),
        "D": jnp.ones((n_heads,), PARAM_DTYPE),
        "dt_bias": jnp.full((n_heads,), -2.0, PARAM_DTYPE),
    }


def _split_proj(params, x, d_inner, n_state, n_heads):
    proj = x.astype(COMPUTE_DTYPE) @ params["w_in"].astype(COMPUTE_DTYPE)
    z, xs, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n_state,
               2 * d_inner + 2 * n_state], axis=-1)
    return z, xs, b, c, dt


def _causal_conv(xs, conv_w):
    """Depthwise causal conv over time. xs: (B, S, d_inner)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * conv_w[i].astype(COMPUTE_DTYPE)
              for i in range(k))
    return jax.nn.silu(out)


def ssm_apply(params, x, *, n_state: int, n_heads: int, chunk: int = 128):
    """Full-sequence SSD. x: (B, S, d). Returns (y, final SSMState)."""
    B, S, d = x.shape
    d_inner = 2 * d
    hd = d_inner // n_heads
    z, xs, bmat, cmat, dt_raw = _split_proj(params, x, d_inner, n_state,
                                            n_heads)
    xs = _causal_conv(xs, params["conv_w"])
    dt = jax.nn.softplus((dt_raw.astype(jnp.float32)
                          + params["dt_bias"]))             # (B,S,H)
    a_log = -dt * jnp.exp(params["A_log"])                  # (B,S,H) <= 0

    xh = xs.reshape(B, S, n_heads, hd)
    u = xh * dt[..., None].astype(COMPUTE_DTYPE)            # dt-scaled input

    nc = max(S // chunk, 1)
    chunk = S // nc
    assert S % chunk == 0
    uc = u.reshape(B, nc, chunk, n_heads, hd)
    bc = bmat.reshape(B, nc, chunk, n_state)
    cc = cmat.reshape(B, nc, chunk, n_state)
    al = a_log.reshape(B, nc, chunk, n_heads)

    def chunk_step(s, inp):
        uc_, bc_, cc_, al_ = inp          # (B,C,H,hd),(B,C,n),(B,C,n),(B,C,H)
        cum = jnp.cumsum(al_, axis=1)                      # (B,C,H) inclusive
        total = cum[:, -1]                                 # (B,H)
        # inter-chunk: y_inter[t] = exp(cum_t) * (S_prev @ C_t)
        sc = jnp.einsum("bhdn,bcn->bchd", s, cc_.astype(jnp.float32))
        y_inter = jnp.exp(cum)[..., None] * sc
        # intra-chunk: pairwise scalar decays exp(cum_t - cum_s), s <= t.
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,C,C,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dec = jnp.where(mask[None, :, :, None], dec, 0.0)
        kv = jnp.einsum("bsn,btn->bst", bc_.astype(jnp.float32),
                        cc_.astype(jnp.float32))           # (B,S=s,T=t)
        w = dec * kv.transpose(0, 2, 1)[..., None]          # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshd->bthd", w,
                             uc_.astype(jnp.float32))
        # state update: S' = exp(total) S + sum_s exp(cum_last - cum_s) u_s B_s
        decay_to_end = jnp.exp(total[:, None, :] - cum)     # (B,C,H)
        su = jnp.einsum("bshd,bsn,bsh->bhdn", uc_.astype(jnp.float32),
                        bc_.astype(jnp.float32), decay_to_end)
        s_new = jnp.exp(total)[..., None, None] * s + su
        return s_new, (y_inter + y_intra).astype(COMPUTE_DTYPE)

    s0 = jnp.zeros((B, n_heads, hd, n_state), jnp.float32)
    uc_t = jnp.moveaxis(uc, 1, 0)
    bc_t = jnp.moveaxis(bc, 1, 0)
    cc_t = jnp.moveaxis(cc, 1, 0)
    al_t = jnp.moveaxis(al, 1, 0)
    s_fin, ys = jax.lax.scan(chunk_step, s0, (uc_t, bc_t, cc_t, al_t))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, n_heads, hd)
    y = y + params["D"].astype(COMPUTE_DTYPE)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    out = y @ params["w_out"].astype(COMPUTE_DTYPE)
    conv_tail = jnp.transpose(xs[:, -(CONV_K - 1):, :], (0, 2, 1))
    return out, SSMState(s_fin, conv_tail)


def ssm_decode(params, x, state: SSMState, *, n_state: int, n_heads: int):
    """One-token step. x: (B, 1, d). Returns (y, new state)."""
    B, _, d = x.shape
    d_inner = 2 * d
    hd = d_inner // n_heads
    z, xs, bmat, cmat, dt_raw = _split_proj(params, x, d_inner, n_state,
                                            n_heads)
    # causal conv with carried tail
    hist = jnp.concatenate([state.conv,
                            jnp.transpose(xs, (0, 2, 1))], axis=-1)
    w = params["conv_w"].astype(COMPUTE_DTYPE)              # (K, d_inner)
    conv_out = jnp.einsum("bdk,kd->bd", hist[:, :, -CONV_K:], w)
    xs1 = jax.nn.silu(conv_out)[:, None, :]
    new_tail = hist[:, :, -(CONV_K - 1):]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"])               # (B,H)
    a = jnp.exp(-dt * jnp.exp(params["A_log"]))             # (B,H)
    xh = xs1.reshape(B, n_heads, hd)
    u = xh.astype(jnp.float32) * dt[..., None]
    outer = jnp.einsum("bhd,bn->bhdn", u, bmat[:, 0].astype(jnp.float32))
    s_new = a[..., None, None] * state.s + outer
    y = jnp.einsum("bhdn,bn->bhd", s_new, cmat[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(COMPUTE_DTYPE) * jax.nn.silu(z)
    out = y @ params["w_out"].astype(COMPUTE_DTYPE)
    return out, SSMState(s_new, new_tail)
