"""Model layers: norms/rope/mlp (common), GQA attention, MoE with
RelJoin-planned dispatch, Mamba2 SSD, RWKV6, planned embeddings."""
