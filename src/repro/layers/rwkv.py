"""RWKV-6 (Finch) block: time-mix with data-dependent per-channel decay +
channel-mix. Attention-free; decode is O(1) in sequence length.

Recurrence per head (state S: (Dk, Dv)):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (diag(u) k_t^T v_t + S_{t-1})        (u: current-token bonus)

w_t in (0,1) per key channel is data-dependent (the paper's headline
feature). Training runs chunks sequentially with a vectorized intra-chunk
pass; decode carries S.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .common import COMPUTE_DTYPE, PARAM_DTYPE, _dense_init


def _replicate_over_model(t, shard_ctx):
    """REFUTED §Perf lever (kept for the record): pinning the WKV inner
    replicated-over-model traded 57 GB of halo permutes for 165 GB of f32
    gathers (11.2 s collective term vs 7.9 s). The productive fix was
    keeping the full-width einsum operands bf16 so the unavoidable
    Megatron all-reduces shrink (see chunk_step)."""
    if shard_ctx is None or shard_ctx[0] is None:
        return t
    mesh, batch_axes, _ = shard_ctx
    spec = P(batch_axes, *(None,) * (t.ndim - 1))
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))


class RWKVState(NamedTuple):
    s: jax.Array       # (B, H, Dk, Dv)
    x_prev: jax.Array  # (B, d) previous token embedding (token-shift)


DECAY_LORA = 64


def rwkv_init(key, d_model: int, head_dim: int):
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_r": _dense_init(ks[0], (d_model, d_model)),
        "w_k": _dense_init(ks[1], (d_model, d_model)),
        "w_v": _dense_init(ks[2], (d_model, d_model)),
        "w_g": _dense_init(ks[3], (d_model, d_model)),
        "w_o": _dense_init(ks[4], (d_model, d_model)),
        # data-dependent decay LoRA: w_t = exp(-exp(base + tanh(x A) B))
        "decay_a": _dense_init(ks[5], (d_model, DECAY_LORA)),
        "decay_b": _dense_init(ks[6], (DECAY_LORA, d_model)),
        "decay_base": jnp.full((d_model,), -4.0, PARAM_DTYPE),
        "bonus_u": jnp.zeros((n_heads, head_dim), PARAM_DTYPE),
        "mix": jnp.full((5, d_model), 0.5, PARAM_DTYPE),
    }


def _projections(params, x, x_shift):
    """Token-shift mixing then r/k/v/g/decay projections.

    Fused (§Perf cell 2): the five mixed projections
    ``(m_i*x + (1-m_i)*x_shift) @ W_i`` factor into exactly TWO matmuls
    against row-scaled concatenated weights — one per input stream — which
    cuts the TP backward activation-grad all-reduces per layer from 5 to 2
    (rwkv6 train_4k was the only collective-bound train cell).
    """
    d = x.shape[-1]
    mix = params["mix"].astype(COMPUTE_DTYPE)          # (5, d)
    ws = [params[n].astype(COMPUTE_DTYPE)
          for n in ("w_r", "w_k", "w_v", "w_g")]
    # fuse r/k/v/g only: the 4d output splits on d boundaries, which stay
    # aligned with a model-axis sharding of the fused dim (a 4d+LORA fusion
    # put split points inside shards and GSPMD inserted 78 GB/dev of halo
    # collective-permutes — measured, refuted, narrowed to this form).
    w_x = jnp.concatenate([mix[i][:, None] * w for i, w in enumerate(ws)],
                          axis=1)
    w_s = jnp.concatenate([(1 - mix[i])[:, None] * w
                           for i, w in enumerate(ws)], axis=1)
    proj = x @ w_x + x_shift @ w_s                     # (..., 4d)
    r, k, v, g = jnp.split(proj, [d, 2 * d, 3 * d], axis=-1)
    x5 = x * mix[4] + x_shift * (1 - mix[4])
    lora = jnp.tanh(x5 @ params["decay_a"].astype(COMPUTE_DTYPE)) \
        @ params["decay_b"].astype(COMPUTE_DTYPE)
    log_w = -jnp.exp(params["decay_base"].astype(jnp.float32)
                     + lora.astype(jnp.float32))   # (..., d) negative
    return r, k, v, g, log_w


def _heads(t, n_heads, hd):
    return t.reshape(t.shape[:-1] + (n_heads, hd))


def rwkv_time_mix(params, x, state: RWKVState, *, head_dim: int,
                  chunk: int = 64, shard_ctx=None):
    """Full-sequence time-mix. x: (B, S, d). Returns (y, new state)."""
    B, S, d = x.shape
    H, hd = d // head_dim, head_dim
    x_shift = jnp.concatenate([state.x_prev[:, None, :], x[:, :-1]], axis=1)
    r, k, v, g, log_w = _projections(params, x, x_shift)
    r, k, v = (_heads(t, H, hd) for t in (r, k, v))
    log_w = _heads(log_w, H, hd)                       # (B,S,H,K)
    u = params["bonus_u"].astype(jnp.float32)          # (H,K)

    nc = max(S // chunk, 1)
    c = S // nc
    assert S % c == 0

    def chunk_step(s, inp):
        # f32 is confined to the decay cumsum and the carried state; all
        # full-width (B,c,H,*) einsum operands are bf16 so the backward's
        # Megatron all-reduces move bf16, not f32 (§Perf cell 2 iter 4).
        rc, kc, vc, lwc = inp     # (B,c,H,K) x3, (B,c,H,K)
        cum = jnp.cumsum(lwc, axis=1)                  # inclusive, f32
        cum_excl = cum - lwc                           # exclusive
        # inter: y_t += r_t diag(exp(cum_excl_t)) S_prev
        r_dec = (rc.astype(jnp.float32)
                 * jnp.exp(cum_excl)).astype(COMPUTE_DTYPE)
        y_inter = jnp.einsum("bthk,bhkv->bthv", r_dec,
                             s.astype(COMPUTE_DTYPE))
        # intra (s < t): r_t [prod w] k_s^T v_s
        k_dec = (kc.astype(jnp.float32)
                 * jnp.exp(-cum)).astype(COMPUTE_DTYPE)
        att = jnp.einsum("bthk,bshk->bhts", r_dec, k_dec,
                         preferred_element_type=jnp.float32)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strictly s < t
        att = jnp.where(mask[None, None], att, 0.0).astype(COMPUTE_DTYPE)
        y_intra = jnp.einsum("bhts,bshv->bthv", att, vc)
        # current-token bonus: r_t diag(u) k_t^T v_t
        bonus = jnp.einsum("bthk,hk,bthk->bth", rc.astype(jnp.float32), u,
                           kc.astype(jnp.float32))
        y_cur = bonus[..., None].astype(COMPUTE_DTYPE) * vc
        # state to chunk end (f32 state, small):
        dec_end = jnp.exp(cum[:, -1:, :, :] - cum)     # (B,c,H,K)
        s_new = jnp.exp(cum[:, -1])[..., None] * s \
            + jnp.einsum("bshk,bshv->bhkv",
                         kc.astype(jnp.float32) * dec_end,
                         vc.astype(jnp.float32))
        return s_new, (y_inter + y_intra + y_cur).astype(COMPUTE_DTYPE)

    rs = jnp.moveaxis(r.reshape(B, nc, c, H, hd), 1, 0)
    ks_ = jnp.moveaxis(k.reshape(B, nc, c, H, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, c, H, hd), 1, 0)
    lw = jnp.moveaxis(log_w.reshape(B, nc, c, H, hd), 1, 0)
    s_fin, ys = jax.lax.scan(chunk_step, state.s, (rs, ks_, vs, lw))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)
    y = y * jax.nn.silu(g)
    out = y @ params["w_o"].astype(COMPUTE_DTYPE)
    return out, RWKVState(s_fin, x[:, -1, :])


def rwkv_decode(params, x, state: RWKVState, *, head_dim: int):
    """One-token step. x: (B, 1, d)."""
    B, _, d = x.shape
    H, hd = d // head_dim, head_dim
    r, k, v, g, log_w = _projections(params, x[:, 0],
                                     state.x_prev)
    r, k, v = (_heads(t, H, hd) for t in (r, k, v))
    log_w = _heads(log_w, H, hd)
    u = params["bonus_u"].astype(jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state.s + u[None, :, :, None] * kv)
    s_new = jnp.exp(log_w)[..., None] * state.s + kv
    y = (y.reshape(B, 1, d).astype(COMPUTE_DTYPE)
         * jax.nn.silu(g)[:, None, :])
    out = y @ params["w_o"].astype(COMPUTE_DTYPE)
    return out, RWKVState(s_new, x[:, 0])


# channel-mix (the RWKV "MLP")

def channel_mix_init(key, d: int, ff: int):
    k1, k2 = jax.random.split(key)
    return {"w_kc": _dense_init(k1, (d, ff)),
            "w_vc": _dense_init(k2, (ff, d)),
            "mix_c": jnp.full((d,), 0.5, PARAM_DTYPE)}


def channel_mix(params, x, x_prev):
    """x: (B,S,d); x_prev: previous-token shifted x."""
    m = params["mix_c"].astype(COMPUTE_DTYPE)
    xm = x * m + x_prev * (1 - m)
    h = jnp.square(jax.nn.relu(xm @ params["w_kc"].astype(COMPUTE_DTYPE)))
    return h @ params["w_vc"].astype(COMPUTE_DTYPE)
