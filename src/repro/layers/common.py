"""Shared building blocks: RMSNorm, rotary embeddings, MLP variants.

Parameters are plain dict pytrees (framework-free); every layer exposes
``init(key, cfg) -> params`` and a pure ``apply``. Compute dtype is bf16
with fp32 params and fp32 softmax/norm accumulation (mixed precision).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, PARAM_DTYPE) * scale)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), PARAM_DTYPE)}


def rmsnorm(params, x, eps: float = 1e-5):
    """f32 is confined to the per-row variance reduction (a (B,S) scalar);
    the normalize/scale multiply stays bf16 so no f32 (B,S,d) tensor
    materializes (the f32 residual chains dominated the memory roofline
    term before this: ~5 full-width f32 tensors per layer)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(COMPUTE_DTYPE)
    return x.astype(COMPUTE_DTYPE) * inv * params["scale"].astype(
        COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Rotary position embeddings (half-dim rotation, NTK-free base theta)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)             # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]                      # (...,S,1,half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GEGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, activation: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"w_gate": _dense_init(k1, (d, ff)),
                "w_up": _dense_init(k2, (d, ff)),
                "w_down": _dense_init(k3, (ff, d))}
    return {"w_up": _dense_init(k1, (d, ff)),
            "w_down": _dense_init(k2, (ff, d))}


def mlp_apply(params, x, activation: str = "swiglu"):
    xc = x.astype(COMPUTE_DTYPE)
    if activation in ("swiglu", "geglu"):
        gate = xc @ params["w_gate"].astype(COMPUTE_DTYPE)
        up = xc @ params["w_up"].astype(COMPUTE_DTYPE)
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(
            gate)
        return (act * up) @ params["w_down"].astype(COMPUTE_DTYPE)
    up = xc @ params["w_up"].astype(COMPUTE_DTYPE)
    return jax.nn.gelu(up) @ params["w_down"].astype(COMPUTE_DTYPE)
