"""Embedding lookup and LM head with RelJoin-planned distribution.

The lookup is an equi-join: token ids (probe side A) against the vocab
table (build side B). The planner (repro.core.relshard) chooses:

  * ``replicate`` (broadcast-hash analogue): table replicated over the
    model axis; lookup is a local take. Costs one table broadcast
    ((p-1)|B|, amortized to the FSDP all-gather in training).
  * ``vocab_parallel`` (shuffle-hash analogue): table sharded over vocab;
    each shard resolves its own ids and the partials are all-reduced —
    moving |A|-sized activations instead of the |B|-sized table.

The vocab-parallel cross-entropy never materializes replicated logits: max
and sum-exp are reduced across shards (the |A| vs |B| trade again).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .common import COMPUTE_DTYPE, PARAM_DTYPE


def _constrain_table(table, mesh, spec: P):
    """Cast to compute dtype, then pin the compute-time sharding so the
    FSDP gather moves bf16 (and grads reduce-scatter in bf16)."""
    t = table.astype(COMPUTE_DTYPE)
    if mesh is None:
        return t
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))


def embedding_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), PARAM_DTYPE) * 0.02}


def head_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), PARAM_DTYPE)
            * d ** -0.5}


def embed_apply(params, ids, *, mesh, batch_axes, model_axis, strategy):
    """ids: (B, S) int32 -> (B, S, d)."""
    if strategy == "replicate" or mesh is None:
        table = _constrain_table(params["table"], mesh, P(None, None))
        return jnp.take(table, ids, axis=0)

    if strategy != "vocab_parallel":
        raise ValueError(f"unknown embedding strategy {strategy}")

    def body(table_loc, ids_loc):
        i = jax.lax.axis_index(model_axis)
        vshard = table_loc.shape[0]
        off = i * vshard
        local = ids_loc - off
        ok = (local >= 0) & (local < vshard)
        safe = jnp.clip(local, 0, vshard - 1)
        out = jnp.take(table_loc, safe, axis=0)
        out = jnp.where(ok[..., None], out, 0)
        return jax.lax.psum(out, model_axis)

    table = _constrain_table(params["table"], mesh, P(model_axis, None))
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(model_axis), P(batch_axes)),
        out_specs=P(batch_axes),
    )(table, ids)


CE_CHUNK = 512


def _seq_chunked(fn, h, labels):
    """Stream a per-token computation over sequence chunks: the (B,C,V)
    logits block is the only vocab-sized temp (recomputed in backward).
    Pads S up to a chunk multiple (train uses S-1=4095 positions; without
    padding the chunking silently never fired)."""
    B, S, d = h.shape
    if S <= CE_CHUNK:
        return fn((h, labels))
    pad = (-S) % CE_CHUNK
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = (S + pad) // CE_CHUNK
    hc = h.reshape(B, n, CE_CHUNK, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, CE_CHUNK).transpose(1, 0, 2)
    out = jax.lax.map(jax.checkpoint(fn), (hc, lc))
    return out.transpose(1, 0, 2).reshape(B, S + pad)[:, :S]


def lm_head_loss(params, x, labels, *, mesh, batch_axes, model_axis,
                 strategy, label_mask=None):
    """Cross-entropy over the (possibly vocab-sharded) head.

    x: (B, S, d); labels: (B, S). Returns mean loss (fp32 scalar).
    """
    xf = x.astype(COMPUTE_DTYPE)
    if label_mask is None:
        label_mask = jnp.ones(labels.shape, jnp.float32)

    if strategy == "replicate" or mesh is None:
        # gold logit via a local gather of the *replicated* table row —
        # never take_along_axis on sharded logits (GSPMD would all-gather
        # the full (B,S,V) logits; observed 125 GiB/step on tinyllama).
        table = _constrain_table(params["table"], mesh, P(None, None))

        def ce_chunk(args):
            h_c, lab_c = args
            logits = (h_c @ table.T).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.einsum(
                "bsd,bsd->bs", h_c, jnp.take(table, lab_c, axis=0),
                preferred_element_type=jnp.float32)
            return lse - gold

        loss_tok = _seq_chunked(ce_chunk, xf, labels)
        loss = loss_tok * label_mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(label_mask), 1.0)

    if strategy != "vocab_parallel":
        raise ValueError(f"unknown head strategy {strategy}")

    def body(table_loc, x_loc, labels_loc, mask_loc):
        i = jax.lax.axis_index(model_axis)
        vshard = table_loc.shape[0]
        off = i * vshard
        tl = table_loc

        def chunk(args):
            x_c, lab_c = args
            logits = (x_c @ tl.T).astype(jnp.float32)      # (B,C,V/p)
            # distributed logsumexp: shard max -> global max -> sumexp.
            # stop_gradient on the operand: the logsumexp max shift
            # carries no gradient, and pmax has no VJP rule — a tangent-free
            # input keeps autodiff out of the collective.
            m = jax.lax.pmax(
                jax.lax.stop_gradient(jnp.max(logits, axis=-1)), model_axis)
            se = jax.lax.psum(
                jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                model_axis)
            lse = m + jnp.log(se)
            # gold logit: local gather of this shard's table rows.
            local = lab_c - off
            ok = (local >= 0) & (local < vshard)
            safe = jnp.clip(local, 0, vshard - 1)
            gold_loc = jnp.einsum(
                "bsd,bsd->bs", x_c, jnp.take(tl, safe, axis=0),
                preferred_element_type=jnp.float32)
            gold = jax.lax.psum(jnp.where(ok, gold_loc, 0.0), model_axis)
            return lse - gold

        loss = _seq_chunked(chunk, x_loc, labels_loc) * mask_loc
        return (jnp.sum(loss)[None], jnp.sum(mask_loc)[None])

    table = _constrain_table(params["table"], mesh, P(model_axis, None))
    tot, cnt = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(model_axis), P(batch_axes), P(batch_axes),
                  P(batch_axes)),
        out_specs=(P(batch_axes), P(batch_axes)),
    )(table, xf, labels, label_mask)
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def lm_head_logits(params, x, *, mesh, batch_axes, model_axis, strategy):
    """Decode-time logits. With vocab_parallel the argmax is resolved
    distributed and only the winning id crosses shards, never the logits."""
    xf = x.astype(COMPUTE_DTYPE)
    if strategy == "replicate" or mesh is None:
        table = _constrain_table(params["table"], mesh, P(None, None))
        return (xf @ table.T).astype(jnp.float32)

    def body(table_loc, x_loc):
        return (x_loc @ table_loc.T).astype(jnp.float32)

    table = _constrain_table(params["table"], mesh, P(model_axis, None))
    # Output stays vocab-sharded (P(..., model)): full logits never
    # replicate; downstream argmax/sampling reduces across shards in GSPMD.
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(model_axis), P(batch_axes)),
        out_specs=P(batch_axes, None, model_axis),
    )(table, xf)
