"""GQA attention: chunked (flash-style) causal training/prefill path and a
single-token KV-cache decode path.

The training path streams KV chunks past each query chunk with an online
softmax (running max / denominator), so the S x S score matrix never
materializes, and the inner KV step is checkpointed so the backward
recomputes scores flash-style instead of stashing per-block residuals.

Tensor parallelism is Megatron-shaped: KV heads are repeated up to the full
query-head count and the flat head axis is sharded over the model mesh axis
(explicit constraints via ``shard_ctx``) — without the constraint GSPMD
re-gathers KV blocks inside the scan every (q, k) block pair (measured
~100 GB/device/step on tinyllama before the fix).

``lower_triangular_schedule`` (a §Perf lever) skips fully-masked upper-
triangle chunk pairs via a dynamic-bound loop — inference paths only (no
VJP for dynamic trip counts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .common import COMPUTE_DTYPE, _dense_init, apply_rope

NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, kv_heads: int, head_dim: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "w_q": _dense_init(kq, (d_model, n_heads * head_dim)),
        "w_k": _dense_init(kk, (d_model, kv_heads * head_dim)),
        "w_v": _dense_init(kv, (d_model, kv_heads * head_dim)),
        "w_o": _dense_init(ko, (n_heads * head_dim, d_model),
                           scale=(n_heads * head_dim) ** -0.5),
    }


def _project_qkv(params, x, n_heads, kv_heads, head_dim, positions, theta):
    B, S, _ = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ params["w_q"].astype(COMPUTE_DTYPE)).reshape(
        B, S, n_heads, head_dim)
    k = (xc @ params["w_k"].astype(COMPUTE_DTYPE)).reshape(
        B, S, kv_heads, head_dim)
    v = (xc @ params["w_v"].astype(COMPUTE_DTYPE)).reshape(
        B, S, kv_heads, head_dim)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _attn_sharding_mode(shard_ctx, n_heads: int, q_chunk: int) -> str:
    """'head': shard the flat head axis over model (Megatron TP).
    'seq': heads don't divide the model axis (e.g. starcoder2 H=24,
    paligemma MQA H=8) — shard each query chunk's row dim instead
    (sequence-parallel attention; KV replicated, scores sharded).
    'none': no mesh."""
    if shard_ctx is None or shard_ctx[0] is None:
        return "none"
    mesh, _, model_axis = shard_ctx
    p = mesh.shape[model_axis]
    if n_heads % p == 0:
        return "head"
    if q_chunk % p == 0:
        return "seq"
    return "batch"


def _constrain(x, shard_ctx, spec_tail):
    mesh, batch_axes, model_axis = shard_ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(batch_axes, *spec_tail)))


def chunked_attention(q, k, v, *, kv_heads: int, causal: bool = True,
                      q_chunk: int = 256, k_chunk: int = 512,
                      window: int = 0,
                      lower_triangular_schedule: bool = False,
                      shard_ctx=None) -> jax.Array:
    """Online-softmax attention. q: (B,S,H,D); k,v: (B,S,G,D). Returns
    (B,S,H,D). ``window`` > 0 limits attention to the last ``window`` keys
    (sliding window for hybrid long-context)."""
    B, S, H, D = q.shape
    G = kv_heads
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, S)
    mode = _attn_sharding_mode(shard_ctx, H, q_chunk)
    if G != H and mode != "seq":
        # Megatron GQA: repeat KV to flat heads so the head axis shards.
        k = jnp.repeat(k, H // G, axis=2)
        v = jnp.repeat(v, H // G, axis=2)
    if mode == "head":
        mesh, _, model_axis = shard_ctx
        q = _constrain(q, shard_ctx, (None, model_axis, None))
        k = _constrain(k, shard_ctx, (None, model_axis, None))
        v = _constrain(v, shard_ctx, (None, model_axis, None))
    H_kv = k.shape[2]
    nq, nk = S // q_chunk, S // k_chunk
    assert S % q_chunk == 0 and S % k_chunk == 0, (S, q_chunk, k_chunk)
    scale = D ** -0.5

    qr = q.reshape(B, nq, q_chunk, H, D)
    kr = k.reshape(B, nk, k_chunk, H_kv, D)
    vr = v.reshape(B, nk, k_chunk, H_kv, D)
    if mode == "seq":
        mesh, _, model_axis = shard_ctx
        # sequence-parallel: split every query chunk's rows over model;
        # KV chunks replicated over model (small for GQA).
        qr = _constrain(qr, shard_ctx, (None, model_axis, None, None))
        kr = _constrain(kr, shard_ctx, (None, None, None, None))
        vr = _constrain(vr, shard_ctx, (None, None, None, None))
    if G != H and mode == "seq":
        kr = jnp.repeat(kr, H // G, axis=3)
        vr = jnp.repeat(vr, H // G, axis=3)
    q_pos = (jnp.arange(nq)[:, None] * q_chunk
             + jnp.arange(q_chunk)[None, :])          # (nq, Cq)
    k_pos = (jnp.arange(nk)[:, None] * k_chunk
             + jnp.arange(k_chunk)[None, :])          # (nk, Ck)

    def one_qblock(qi, qb):
        # qb: (B, Cq, H, D)
        def kv_step(carry, kj):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
            # scores materialize at XLA fusion boundaries (no Pallas
            # flash kernel on this backend): keep them bf16 — the running
            # max/denominator stay f32, so the online softmax is stable.
            s = (jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                            preferred_element_type=jnp.float32)
                 * scale).astype(COMPUTE_DTYPE)
            qp = jax.lax.dynamic_index_in_dim(q_pos, qi, 0, keepdims=False)
            kp = jax.lax.dynamic_index_in_dim(k_pos, kj, 0, keepdims=False)
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window > 0:
                mask &= kp[None, :] > qp[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF).astype(
                COMPUTE_DTYPE)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
            # p materializes bf16 (the f32 exp fuses into the convert); the
            # denominator accumulates f32 inside the reduce.
            p = jnp.exp(s.astype(jnp.float32)
                        - m_new[..., None]).astype(COMPUTE_DTYPE)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        # flash-style backward: recompute each (q,k)-block's scores in the
        # VJP instead of saving (nk, B, H, Cq, Ck) residuals — without this
        # the scan stashes every score block and the memory roofline term
        # explodes ~15x (measured on tinyllama train_4k).
        kv_step_ckpt = jax.checkpoint(
            kv_step, policy=jax.checkpoint_policies.nothing_saveable)
        if lower_triangular_schedule and causal and q_chunk == k_chunk:
            # Only visit kv blocks j <= i: dynamic-bound loop (no VJP —
            # inference paths only).
            def body(j, carry):
                c, _ = kv_step(carry, j)
                return c
            m, l, acc = jax.lax.fori_loop(0, qi + 1, body, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step_ckpt, (m0, l0, a0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(COMPUTE_DTYPE)  # cast HERE: the stacked map
        # output is bf16, not fp32 (halves the materialized bytes).

    outs = jax.lax.map(lambda i: one_qblock(i, qr[:, i]), jnp.arange(nq))
    # (nq, B, H, Cq, D) -> (B, S, H, D)
    outs = jnp.moveaxis(outs, 0, 1)                    # (B,nq,H,Cq,D)
    outs = jnp.transpose(outs, (0, 1, 3, 2, 4)).reshape(B, S, H, D)
    return outs


def attn_apply(params, x, *, n_heads, kv_heads, head_dim, theta,
               positions=None, q_chunk=256, k_chunk=512, window=0,
               lower_triangular_schedule=False, shard_ctx=None):
    """Full-sequence (train / prefill) attention, returns (y, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, head_dim, positions,
                           theta)
    y = chunked_attention(
        q, k, v, kv_heads=kv_heads, causal=True, q_chunk=q_chunk,
        k_chunk=k_chunk, window=window,
        lower_triangular_schedule=lower_triangular_schedule,
        shard_ctx=shard_ctx)
    out = y.reshape(B, S, n_heads * head_dim) @ params["w_o"].astype(
        COMPUTE_DTYPE)
    return out, (k, v)


def attn_decode(params, x, cache_k, cache_v, pos, *, n_heads, kv_heads,
                head_dim, theta, window=0):
    """One-token decode. x: (B,1,d); cache: (B,Smax,G,D); pos: (B,) current
    write position. Returns (y, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    positions = pos[:, None].astype(jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, head_dim, positions,
                           theta)
    # write k/v at pos
    idx = pos[:, None, None, None].astype(jnp.int32)
    onehot = (jnp.arange(cache_k.shape[1])[None, :, None, None] == idx)
    cache_k = jnp.where(onehot, k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(onehot, v.astype(cache_v.dtype), cache_v)

    G, Hg = kv_heads, n_heads // kv_heads
    qh = q.reshape(B, 1, G, Hg, head_dim)
    s = jnp.einsum("bqghd,bkgd->bghqk", qh, cache_k,
                   preferred_element_type=jnp.float32) * head_dim ** -0.5
    kpos = jnp.arange(cache_k.shape[1])[None, :]
    live = kpos <= pos[:, None]
    if window > 0:
        live &= kpos > (pos[:, None] - window)
    s = jnp.where(live[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    y = jnp.einsum("bghqk,bkgd->bghqd", p, cache_v)
    y = jnp.transpose(y, (0, 3, 1, 2, 4)).reshape(B, 1, n_heads * head_dim)
    out = y.astype(COMPUTE_DTYPE) @ params["w_o"].astype(COMPUTE_DTYPE)
    return out, cache_k, cache_v
