"""bloom_build / bloom_probe — TPU Pallas kernel pair: bit-packed bloom
filters over join keys (runtime-filter pushdown / sideways information
passing).

``bloom_build`` folds a table's join-key column into an ``m_bits``-wide
bloom filter packed into a ``(m_bits/32,)`` uint32 array; ``bloom_probe``
produces the keep-mask of a probe-side key column against that filter, to
be fused ahead of ``exchange.shuffle`` so rejected rows never ship.

The TPU formulation avoids scatter/gather entirely (same trick as
``partition_hist``): each key tile is expanded into one-hot word and bit
matrices, and

  * build: ``counts = word_onehot^T @ bit_onehot`` is an MXU matmul whose
    nonzero cells are exactly the (word, bit) pairs some key sets —
    OR-packing them gives the tile's filter words, accumulated across the
    grid with bitwise OR;
  * probe: the filter is pre-expanded to an ``(m_words, 32)`` bitmap and
    each key reads its bit via ``word_onehot @ bitmap`` — a dense matmul
    instead of a data-dependent gather.

Hash positions use Kirsch-Mitzenmacher double hashing ``h1 + i*h2`` over
the same murmur-style avalanche as the shuffle (decorrelated seeds), so
the k probes are independent and ``m_bits`` (a power of two) reduces by
mask, never by modulo.

Grid: (N // TN,), accumulating into / reading the full filter block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Filter sizing/accuracy math lives with the cost model (it prices the
# filter's broadcast against the exchange savings); re-exported here so
# kernel users need a single import.
from ..core.cost_model import bloom_fpr, bloom_params  # noqa: F401
from ..joins.slots import hash32

DEFAULT_TN = 1024

#: Decorrelated murmur3-style mix seeds for the two base hashes. They must
#: differ from SHUFFLE_SEED/BUCKET_SEED: a bloom position correlated with
#: the shuffle destination would make false positives pile onto single
#: partitions instead of spreading. Plain ints (converted at trace time):
#: module-level jnp constants would be captured by the Pallas kernels.
BLOOM_SEED_1 = 0x165667B1
BLOOM_SEED_2 = 0xD6E8FEB8


def _positions(keys: jax.Array, i: int, m_bits: int) -> jax.Array:
    """Bit position of hash i for each key (double hashing; h2 forced odd so
    the stride is a unit of the pow2 ring and probes never collapse)."""
    h1 = hash32(keys, jnp.uint32(BLOOM_SEED_1))
    h2 = hash32(keys, jnp.uint32(BLOOM_SEED_2)) | jnp.uint32(1)
    return (h1 + jnp.uint32(i) * h2) & jnp.uint32(m_bits - 1)


def _build_kernel(keys_ref, valid_ref, out_ref, *, m_bits: int, k: int):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]                   # (TN,) int32
    valid = valid_ref[...] != 0            # (TN,) invalid rows contribute 0
    m_words = m_bits // 32
    tn = keys.shape[0]
    words = jnp.zeros((m_words,), jnp.uint32)
    for i in range(k):
        pos = _positions(keys, i, m_bits)
        word = (pos >> 5).astype(jnp.int32)
        bit = (pos & 31).astype(jnp.int32)
        # One-hot expansions; counts[w, b] = #keys setting bit b of word w —
        # a (m_words, TN) x (TN, 32) MXU matmul (counts <= TN, f32-exact).
        woh = jnp.where(
            valid[:, None]
            & (word[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (tn, m_words), 1)), 1.0, 0.0).astype(jnp.float32)
        boh = (bit[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tn, 32), 1)).astype(jnp.float32)
        counts = jax.lax.dot(woh.T, boh)   # (m_words, 32)
        packed = jnp.sum(
            jnp.where(counts > 0.5,
                      jnp.uint32(1) << jax.lax.broadcasted_iota(
                          jnp.uint32, (m_words, 32), 1),
                      jnp.uint32(0)), axis=1, dtype=jnp.uint32)
        words = words | packed
    out_ref[...] |= words


def _probe_kernel(keys_ref, bitmap_ref, out_ref, *, m_bits: int, k: int):
    keys = keys_ref[...]                   # (TN,) int32
    bitmap = bitmap_ref[...]               # (m_words, 32) f32 0/1 bits
    m_words = m_bits // 32
    tn = keys.shape[0]
    keep = jnp.ones((tn,), jnp.bool_)
    for i in range(k):
        pos = _positions(keys, i, m_bits)
        word = (pos >> 5).astype(jnp.int32)
        bit = (pos & 31).astype(jnp.int32)
        woh = (word[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tn, m_words), 1)).astype(jnp.float32)
        boh = (bit[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tn, 32), 1)).astype(jnp.float32)
        # row n of (woh @ bitmap) is the 32-bit row of n's word; selecting
        # n's bit is an elementwise product + row sum — no gather anywhere.
        sel = jnp.sum(jax.lax.dot(woh, bitmap) * boh, axis=1)
        keep = keep & (sel > 0.5)
    out_ref[...] = keep


@functools.partial(jax.jit,
                   static_argnames=("m_bits", "k", "tn", "interpret"))
def bloom_build(keys: jax.Array, valid: jax.Array | None = None, *,
                m_bits: int, k: int, tn: int = DEFAULT_TN,
                interpret: bool = True) -> jax.Array:
    """Fold ``keys`` (any shape, integer dtype) into a bit-packed bloom
    filter: uint32 array of shape (m_bits/32,). Rows with ``valid`` False
    are excluded; an all-invalid (or empty) input yields the zero filter,
    whose probe mask rejects everything."""
    if m_bits % 32 or m_bits & (m_bits - 1):
        raise ValueError(f"m_bits must be a power of two >= 32, got {m_bits}")
    flat = keys.reshape(-1).astype(jnp.int32)
    v = (jnp.ones(flat.shape, jnp.int32) if valid is None
         else valid.reshape(-1).astype(jnp.int32))
    n = flat.shape[0]
    # Pow2-quantized tile (like compact_partitions' capacities): padded
    # lengths take few distinct values, so XLA reuses compilations across
    # build cardinalities instead of recompiling per row count.
    tn = min(tn, max(8, 1 << (max(n, 1) - 1).bit_length()))
    pad = (-n) % tn if n else tn
    flat = jnp.pad(flat, (0, pad))
    v = jnp.pad(v, (0, pad))
    return pl.pallas_call(
        functools.partial(_build_kernel, m_bits=m_bits, k=k),
        grid=(flat.shape[0] // tn,),
        in_specs=[pl.BlockSpec((tn,), lambda i: (i,)),
                  pl.BlockSpec((tn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((m_bits // 32,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m_bits // 32,), jnp.uint32),
        interpret=interpret,
    )(flat, v)


@functools.partial(jax.jit, static_argnames=("k", "tn", "interpret"))
def bloom_probe(keys: jax.Array, bits: jax.Array, *, k: int,
                tn: int = DEFAULT_TN, interpret: bool = True) -> jax.Array:
    """Keep-mask of ``keys`` against a ``bloom_build`` filter: True iff all
    k probed bits are set (never a false negative). Same shape as ``keys``."""
    m_bits = bits.shape[0] * 32
    shape = keys.shape
    flat = keys.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    tn = min(tn, max(8, 1 << (max(n, 1) - 1).bit_length()))
    pad = (-n) % tn if n else tn
    flat = jnp.pad(flat, (0, pad))
    # Pre-expand the packed words to an (m_words, 32) 0/1 bitmap once, so
    # the kernel's bit lookup is a pure matmul.
    bitmap = ((bits[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :])
              & jnp.uint32(1)).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_probe_kernel, m_bits=m_bits, k=k),
        grid=(flat.shape[0] // tn,),
        in_specs=[pl.BlockSpec((tn,), lambda i: (i,)),
                  pl.BlockSpec((m_bits // 32, 32), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat.shape[0],), jnp.bool_),
        interpret=interpret,
    )(flat, bitmap)
    return out[:n].reshape(shape)


def bloom_build_ref(keys, valid=None, *, m_bits: int, k: int):
    """Pure-numpy reference of ``bloom_build`` (test oracle)."""
    import numpy as np
    flat = np.asarray(keys, dtype=np.int32).reshape(-1)
    v = (np.ones(flat.shape, bool) if valid is None
         else np.asarray(valid, bool).reshape(-1))
    words = np.zeros(m_bits // 32, np.uint32)
    h1 = _np_hash32(flat, BLOOM_SEED_1)
    h2 = _np_hash32(flat, BLOOM_SEED_2) | np.uint32(1)
    for i in range(k):
        pos = (h1 + np.uint32(i) * h2) & np.uint32(m_bits - 1)
        for p in pos[v]:
            words[int(p) >> 5] |= np.uint32(1) << np.uint32(int(p) & 31)
    return words


def _np_hash32(keys, seed: int):
    import numpy as np
    with np.errstate(over="ignore"):
        h = keys.astype(np.uint32) * np.uint32(seed)
        h ^= h >> np.uint32(15)
        h *= np.uint32(0xC2B2AE35)
        h ^= h >> np.uint32(13)
    return h
