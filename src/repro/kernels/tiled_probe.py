"""tiled_probe — TPU Pallas kernel for the probe step of the hash-family joins.

TPU adaptation (DESIGN.md §2): a chaining hash map is pointer-chasing and
hostile to the VPU/MXU. The TPU-native probe is a *dense tiled key match*:
stream (TA,)-tiles of probe keys and (TB,)-tiles of build keys through VMEM,
compute the TA x TB equality matrix on the VPU, and reduce each row to the
first matching build-side index. The radix-bucketed caller (joins.local_join)
bounds TB per probe row, giving the hash join's O(|A| + |B|) workload; this
kernel is the inner dense primitive.

Grid: (Na // TA, Nb // TB); the build axis is the innermost (fastest) grid
dimension, so the output tile for a fixed probe tile stays resident while
build tiles stream past (accumulator pattern).

No-match sentinel inside the kernel is INT32_MAX (monotone under min-
accumulation); the public wrapper converts it to -1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT32_MAX = jnp.iinfo(jnp.int32).max

# Hardware-aligned defaults: lanes = 128, probe tile a multiple of 8 sublanes.
DEFAULT_TA = 256
DEFAULT_TB = 512


def _probe_kernel(a_ref, b_ref, out_ref, *, tb: int):
    """One (TA, TB) tile: out[i] = min(out[i], first j where b[j] == a[i])."""
    jb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, INT32_MAX)

    a = a_ref[...]  # (TA,)
    b = b_ref[...]  # (TB,)
    # (TA, TB) equality matrix on the VPU. TPU requires >=2d iota.
    eq = a[:, None] == b[None, :]
    col = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1) + jb * tb
    cand = jnp.min(jnp.where(eq, col, INT32_MAX), axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("ta", "tb", "interpret"))
def tiled_probe(a_keys: jax.Array, b_keys: jax.Array, *,
                ta: int = DEFAULT_TA, tb: int = DEFAULT_TB,
                interpret: bool = True) -> jax.Array:
    """First-match probe: out[i] = min{{j : b_keys[j] == a_keys[i]}} else -1.

    Both inputs are int32; callers encode invalid rows with distinct negative
    sentinels so they can never match. Shapes are padded to tile multiples.
    """
    if a_keys.dtype != jnp.int32 or b_keys.dtype != jnp.int32:
        raise TypeError("tiled_probe expects int32 keys")
    na, nb = a_keys.shape[0], b_keys.shape[0]
    ta = min(ta, max(8, na))
    tb = min(tb, max(128, nb))
    pa = (-na) % ta
    pb = (-nb) % tb
    # Pad with non-matching sentinels (a: -1, b: -2).
    a_pad = jnp.pad(a_keys, (0, pa), constant_values=-1)
    b_pad = jnp.pad(b_keys, (0, pb), constant_values=-2)

    grid = (a_pad.shape[0] // ta, b_pad.shape[0] // tb)
    out = pl.pallas_call(
        functools.partial(_probe_kernel, tb=tb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((ta,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((a_pad.shape[0],), jnp.int32),
        interpret=interpret,
    )(a_pad, b_pad)
    out = out[:na]
    # matches landing in the padded tail (a probe key equal to the pad
    # sentinel -2) are not real build rows — found by hypothesis.
    return jnp.where((out == INT32_MAX) | (out >= nb), -1, out)


# ---------------------------------------------------------------------------
# 3-way extension: one fused probe of two key columns against two builds.
# ---------------------------------------------------------------------------


def _probe3_kernel(a1_ref, a2_ref, b_ref, c_ref, out1_ref, out2_ref, *,
                   tb: int):
    """One (TA, TB) step of the fused 3-way probe: both equality matrices
    share the probe tile's VMEM residency and the same grid walk."""
    jb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        out1_ref[...] = jnp.full_like(out1_ref, INT32_MAX)
        out2_ref[...] = jnp.full_like(out2_ref, INT32_MAX)

    col = jax.lax.broadcasted_iota(
        jnp.int32, (a1_ref.shape[0], tb), 1) + jb * tb
    eq1 = a1_ref[...][:, None] == b_ref[...][None, :]
    out1_ref[...] = jnp.minimum(
        out1_ref[...], jnp.min(jnp.where(eq1, col, INT32_MAX), axis=1))
    eq2 = a2_ref[...][:, None] == c_ref[...][None, :]
    out2_ref[...] = jnp.minimum(
        out2_ref[...], jnp.min(jnp.where(eq2, col, INT32_MAX), axis=1))


@functools.partial(jax.jit, static_argnames=("ta", "tb", "interpret"))
def tiled_probe3(a1_keys: jax.Array, a2_keys: jax.Array,
                 b_keys: jax.Array, c_keys: jax.Array, *,
                 ta: int = DEFAULT_TA, tb: int = DEFAULT_TB,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused first-match probe for the hypercube 3-way local join: for each
    probe row i, find the first j with ``b_keys[j] == a1_keys[i]`` and the
    first k with ``c_keys[k] == a2_keys[i]`` in ONE kernel.

    Both build sides are padded to a common tile-multiple length so a single
    grid walk streams them side by side; each grid step min-accumulates two
    output tiles against the resident probe tile. Sentinel conventions match
    ``tiled_probe`` (invalid probe -1, invalid/pad build -2; INT32_MAX
    no-match converted to -1).
    """
    for k in (a1_keys, a2_keys, b_keys, c_keys):
        if k.dtype != jnp.int32:
            raise TypeError("tiled_probe3 expects int32 keys")
    na = a1_keys.shape[0]
    nb, nc = b_keys.shape[0], c_keys.shape[0]
    ta = min(ta, max(8, na))
    tb = min(tb, max(128, max(nb, nc)))
    n_build = max(nb, nc)
    n_build += (-n_build) % tb
    a_pad = (-na) % ta
    a1_p = jnp.pad(a1_keys, (0, a_pad), constant_values=-1)
    a2_p = jnp.pad(a2_keys, (0, a_pad), constant_values=-1)
    b_p = jnp.pad(b_keys, (0, n_build - nb), constant_values=-2)
    c_p = jnp.pad(c_keys, (0, n_build - nc), constant_values=-2)

    grid = (a1_p.shape[0] // ta, n_build // tb)
    out1, out2 = pl.pallas_call(
        functools.partial(_probe3_kernel, tb=tb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta,), lambda i, j: (i,)),
            pl.BlockSpec((ta,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (j,)),
            pl.BlockSpec((tb,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((ta,), lambda i, j: (i,)),
            pl.BlockSpec((ta,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a1_p.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((a1_p.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(a1_p, a2_p, b_p, c_p)
    out1, out2 = out1[:na], out2[:na]
    out1 = jnp.where((out1 == INT32_MAX) | (out1 >= nb), -1, out1)
    out2 = jnp.where((out2 == INT32_MAX) | (out2 >= nc), -1, out2)
    return out1, out2
