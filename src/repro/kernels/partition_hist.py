"""partition_hist — TPU Pallas kernel: histogram of shuffle/radix destinations.

Counts how many rows target each of ``nd`` partitions. Used for (a) sizing
slotted all-to-all capacities, and (b) hot-key / skew detection (DESIGN.md
straggler mitigation). The TPU formulation avoids scatter entirely: each key
tile is compared against the destination iota, producing a (TN, nd) one-hot
matrix that is column-summed on the VPU — a dense, MXU-friendly bincount.

Grid: (N // TN,), accumulating into the full (nd,) output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TN = 1024


def _hist_kernel(dest_ref, out_ref, *, nd: int):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = dest_ref[...]  # (TN,) int32; invalid rows carry dest = -1
    onehot = (d[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (d.shape[0], nd), 1))
    out_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("nd", "tn", "interpret"))
def partition_hist(dest: jax.Array, *, nd: int, tn: int = DEFAULT_TN,
                   interpret: bool = True) -> jax.Array:
    """counts[k] = #{i : dest[i] == k}; dest < 0 rows are not counted."""
    if dest.dtype != jnp.int32:
        raise TypeError("partition_hist expects int32 destinations")
    n = dest.shape[0]
    tn = min(tn, max(8, n))
    pad = (-n) % tn
    d = jnp.pad(dest, (0, pad), constant_values=-1)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, nd=nd),
        grid=(d.shape[0] // tn,),
        in_specs=[pl.BlockSpec((tn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((nd,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nd,), jnp.int32),
        interpret=interpret,
    )(d)
    return out
