"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax.numpy as jnp


def tiled_probe_ref(a_keys: jnp.ndarray, b_keys: jnp.ndarray) -> jnp.ndarray:
    """out[i] = first j with b_keys[j] == a_keys[i], else -1 (O(Na*Nb))."""
    eq = a_keys[:, None] == b_keys[None, :]
    nb = b_keys.shape[0]
    col = jnp.arange(nb, dtype=jnp.int32)[None, :]
    big = jnp.iinfo(jnp.int32).max
    first = jnp.min(jnp.where(eq, col, big), axis=1)
    return jnp.where(first == big, -1, first).astype(jnp.int32)


def partition_hist_ref(dest: jnp.ndarray, nd: int) -> jnp.ndarray:
    """counts[k] = #{i : dest[i] == k} (dest < 0 ignored)."""
    valid = (dest >= 0).astype(jnp.int32)
    return jnp.bincount(jnp.where(valid == 1, dest, 0), weights=valid,
                        length=nd).astype(jnp.int32)


def bitonic_sort_ref(keys: jnp.ndarray, values: jnp.ndarray):
    """Stable ascending sort of (key, value) pairs by key."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], values[order]
