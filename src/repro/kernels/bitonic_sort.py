"""bitonic_sort — TPU Pallas kernel: in-VMEM tile sort for the sort join.

The shuffle-sort join's local phase sorts each partition by key. On TPU the
tile-level primitive is a bitonic network: data-independent compare-exchange
stages that vectorize perfectly on the VPU (no data-dependent control flow).
This kernel sorts one power-of-two tile of (key, payload) pairs entirely in
VMEM; larger arrays are handled by the ops-level wrapper (tile sort + merge,
or XLA sort fallback).

The compare-exchange partner ``i ^ j`` is expressed with static reshapes
(N/(2j), 2, j) instead of gathers: element (m, 0, t) pairs with (m, 1, t).
Stages are unrolled at trace time (log2(N)^2 stages, N <= 4096).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_TILE = 4096


def _bitonic_kernel(key_ref, val_ref, key_out, val_out, *, n: int):
    keys = key_ref[...]
    vals = val_ref[...]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            kr = keys.reshape(n // (2 * j), 2, j)
            vr = vals.reshape(n // (2 * j), 2, j)
            lo_k, hi_k = kr[:, 0, :], kr[:, 1, :]
            lo_v, hi_v = vr[:, 0, :], vr[:, 1, :]
            # Ascending iff (i & k) == 0 for the element's global index.
            base = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), j), 0)
            idx = base * 2 * j + jax.lax.broadcasted_iota(
                jnp.int32, (n // (2 * j), j), 1)
            asc = (idx & k) == 0
            swap = jnp.where(asc, lo_k > hi_k, lo_k < hi_k)
            new_lo_k = jnp.where(swap, hi_k, lo_k)
            new_hi_k = jnp.where(swap, lo_k, hi_k)
            new_lo_v = jnp.where(swap, hi_v, lo_v)
            new_hi_v = jnp.where(swap, lo_v, hi_v)
            keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(n)
            vals = jnp.stack([new_lo_v, new_hi_v], axis=1).reshape(n)
            j //= 2
        k *= 2
    key_out[...] = keys
    val_out[...] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_tile(keys: jax.Array, values: jax.Array, *,
                      interpret: bool = True):
    """Sort one power-of-two tile (N <= 4096) of int32 (key, value) pairs
    ascending by key. Returns (sorted_keys, permuted_values)."""
    n = keys.shape[0]
    if n & (n - 1) or n > MAX_TILE:
        raise ValueError(f"tile size must be a power of two <= {MAX_TILE}, "
                         f"got {n}")
    if keys.dtype != jnp.int32 or values.dtype != jnp.int32:
        raise TypeError("bitonic_sort_tile expects int32 keys and values")
    return pl.pallas_call(
        functools.partial(_bitonic_kernel, n=n),
        in_specs=[pl.BlockSpec((n,), lambda: (0,)),
                  pl.BlockSpec((n,), lambda: (0,))],
        out_specs=[pl.BlockSpec((n,), lambda: (0,)),
                   pl.BlockSpec((n,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(keys, values)
