"""Public jit'd wrappers around the Pallas kernels.

On the CPU CI container the kernels run in interpret mode (the kernel body
executes in Python, validating the exact TPU program); on a TPU backend they
compile natively. Callers use these wrappers, never pallas_call directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitonic_sort import MAX_TILE, bitonic_sort_tile
from .partition_hist import partition_hist
from .tiled_probe import tiled_probe, tiled_probe3


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def probe(a_keys: jax.Array, b_keys: jax.Array, *, ta: int = 256,
          tb: int = 512) -> jax.Array:
    """First-match index of each probe key in the build keys (-1 if none)."""
    return tiled_probe(a_keys, b_keys, ta=ta, tb=tb, interpret=_interpret())


def probe3(a1_keys: jax.Array, a2_keys: jax.Array, b_keys: jax.Array,
           c_keys: jax.Array, *, ta: int = 256, tb: int = 512
           ) -> tuple[jax.Array, jax.Array]:
    """Fused two-build first-match probe (hypercube 3-way local join)."""
    return tiled_probe3(a1_keys, a2_keys, b_keys, c_keys, ta=ta, tb=tb,
                        interpret=_interpret())


def hist(dest: jax.Array, nd: int, *, tn: int = 1024) -> jax.Array:
    """Partition-destination histogram (skew/capacity statistics)."""
    return partition_hist(dest, nd=nd, tn=tn, interpret=_interpret())


def sort_pairs(keys: jax.Array, values: jax.Array):
    """Ascending sort of int32 (key, value) pairs.

    Uses the in-VMEM bitonic kernel for power-of-two tiles up to MAX_TILE
    (the TPU tile primitive); falls back to XLA variadic sort for other
    shapes (which XLA itself lowers to a bitonic network on TPU).
    """
    n = keys.shape[0]
    if n and not (n & (n - 1)) and n <= MAX_TILE:
        return bitonic_sort_tile(keys, values, interpret=_interpret())
    order = jnp.argsort(keys)
    return keys[order], values[order]
