"""key_range / range_probe — TPU Pallas tiled min/max reduce: zone-map
runtime filters over join keys.

A zone map is the cheapest sideways-information-passing operator the cost
model knows: the build side's surviving join keys are folded into a single
``[min, max]`` interval (8 bytes on the wire, vs a bloom filter's m/8),
and the probe side keeps only rows whose key falls inside it. For
band-shaped key sets — range predicates on the key itself, e.g. TPC-DS
date windows where ``d_date_sk`` is ordered by date — the interval is
*exact*: keep fraction equals the true match fraction with zero false
positives, at a fraction of a bloom filter's broadcast cost.

``key_range`` is the build reduce: a tiled Pallas kernel in the same shape
as ``partition_hist`` — grid over key tiles, accumulating elementwise
min/max into a tiny (1, 2) output block that stays resident across the
grid. Invalid rows are masked to the identity elements (+INT_MAX for min,
-INT_MAX-ish for max), so an empty or all-invalid build yields the empty
interval (lo > hi) whose probe mask rejects every row — the same
degenerate-build contract as the zero bloom filter.

``range_probe`` needs no kernel: the keep mask is two vectorized compares
fused into the caller by XLA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TN = 1024

#: Identity elements of the (min, max) reduction. An untouched zone map is
#: the empty interval [INT32_MAX, INT32_MIN]: lo > hi, matches nothing.
_LO_IDENT = 2 ** 31 - 1
_HI_IDENT = -(2 ** 31)


def _minmax_kernel(keys_ref, valid_ref, out_ref):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(_LO_IDENT)
        out_ref[0, 1] = jnp.int32(_HI_IDENT)

    keys = keys_ref[...]                  # (TN,) int32
    valid = valid_ref[...] != 0           # (TN,)
    lo = jnp.min(jnp.where(valid, keys, jnp.int32(_LO_IDENT)))
    hi = jnp.max(jnp.where(valid, keys, jnp.int32(_HI_IDENT)))
    out_ref[0, 0] = jnp.minimum(out_ref[0, 0], lo)
    out_ref[0, 1] = jnp.maximum(out_ref[0, 1], hi)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def key_range(keys: jax.Array, valid: jax.Array | None = None, *,
              tn: int = DEFAULT_TN, interpret: bool = True) -> jax.Array:
    """(min, max) of the valid entries of ``keys`` as an int32 (2,) array.

    Any input shape / integer dtype (viewed as int32, like the bloom pair).
    All-invalid or empty input returns the empty interval (lo > hi).
    """
    flat = keys.reshape(-1).astype(jnp.int32)
    v = (jnp.ones(flat.shape, jnp.int32) if valid is None
         else valid.reshape(-1).astype(jnp.int32))
    n = flat.shape[0]
    # Pow2-quantized tile (compact_partitions convention): padded lengths
    # take few distinct values so XLA reuses compilations across builds.
    tn = min(tn, max(8, 1 << (max(n, 1) - 1).bit_length()))
    pad = (-n) % tn if n else tn
    flat = jnp.pad(flat, (0, pad))
    v = jnp.pad(v, (0, pad))
    out = pl.pallas_call(
        _minmax_kernel,
        grid=(flat.shape[0] // tn,),
        in_specs=[pl.BlockSpec((tn,), lambda i: (i,)),
                  pl.BlockSpec((tn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.int32),
        interpret=interpret,
    )(flat, v)
    return out[0]


def merge_ranges(parts: jax.Array) -> jax.Array:
    """Merge stacked ``(k, 2)`` partial intervals into one ``(2,)`` zone
    map: elementwise min of the mins, max of the maxes. The min/max merge
    is associative, commutative and has the empty interval as identity, so
    any merge order — a reduce tree, an all_gather + local fold, or this
    single fused reduce — yields the same interval: the distributed-build
    equivalence ``dist_zone_map_build`` rests on."""
    return jnp.stack([jnp.min(parts[:, 0]), jnp.max(parts[:, 1])])


def range_probe(keys: jax.Array, lo_hi: jax.Array) -> jax.Array:
    """Keep-mask of ``keys`` against a ``key_range`` interval: True iff
    lo <= key <= hi. Exact for band-shaped build key sets (no false
    negatives ever: every build key lies inside its own min/max)."""
    k = keys.astype(jnp.int32)
    return (k >= lo_hi[0]) & (k <= lo_hi[1])


def key_range_ref(keys, valid=None):
    """Pure-numpy reference of ``key_range`` (test oracle)."""
    import numpy as np
    flat = np.asarray(keys, dtype=np.int32).reshape(-1)
    v = (np.ones(flat.shape, bool) if valid is None
         else np.asarray(valid, bool).reshape(-1))
    live = flat[v]
    if live.size == 0:
        return np.array([_LO_IDENT, _HI_IDENT], np.int32)
    return np.array([live.min(), live.max()], np.int32)
