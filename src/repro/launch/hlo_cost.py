"""Trip-count-aware cost analysis over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes/collectives by ~n_layers
(verified on this backend: scan(8 layers) reports 1/8 the flops of the
unrolled version). This module re-derives the three roofline inputs by
walking the HLO module with loop-trip multipliers:

  * computations are parsed into op lines with a per-computation symbol
    table (op name -> result shape);
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":N}}``;
    the body/cond computations inherit multiplier x N;
  * FLOPs: 2 * |result| * K summed over ``dot`` ops (K = product of the
    lhs contracting dims) — matmul-dominated models by construction;
  * bytes: HBM traffic under a TPU-fusion model — only *materializing*
    ops count (fusion roots, dots, copies, slices/updates, reduces, sorts,
    gathers/scatters, transposes, collectives): result bytes written +
    operand bytes read. Top-level elementwise/broadcast/reshape ops are
    treated as fusable (they would fuse on the TPU backend; the CPU
    backend's weaker fusion must not inflate the TPU roofline);
  * collectives: per-op wire bytes with ring-algorithm factors (see
    roofline.py) times the multiplier.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"([a-z\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "custom-call", "iota"}

#: ops whose results/operands hit HBM even under aggressive fusion.
_MATERIALIZING = {"fusion", "dot", "copy", "dynamic-slice",
                  "dynamic-update-slice", "reduce", "sort", "scatter",
                  "gather", "pad", "concatenate", "transpose",
                  "reduce-window", "rng-bit-generator"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class OpLine:
    name: str
    dtype: str
    dims: Tuple[int, ...]
    opcode: str
    text: str

    @property
    def result_elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def result_bytes(self) -> int:
        return self.result_elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class CostSummary:
    flops: float                      # per-device, one execution
    bytes_accessed: float             # per-device
    collective_wire_bytes: Dict[str, float]  # per-device by kind

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_wire_bytes.values())


def _parse_computations(text: str) -> Dict[str, List[OpLine]]:
    comps: Dict[str, List[OpLine]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: `[ENTRY] %name (args...) -> type {` — args may
        # contain nested parens (tuple params), so match loosely.
        if (stripped.endswith("{") and "->" in stripped and not
                line.startswith(" ")
                and (stripped.startswith("ENTRY")
                     or stripped.startswith("%"))):
            mh = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if mh:
                cur = mh.group(1)
                comps[cur] = []
                if stripped.startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, dtype, dims, opcode = mo.groups()
            dims_t = tuple(int(d) for d in dims.split(",") if d)
            comps[cur].append(OpLine(name, dtype, dims_t, opcode, line))
        else:
            # tuple-shaped results: record name with no dims for symtab
            mt = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(", line)
            if mt:
                op = re.search(r"\)\s*([a-z\-]+)\(", line)
                comps[cur].append(OpLine(mt.group(1), "pred", (),
                                         op.group(1) if op else "tuple",
                                         line))
    _parse_computations.entry = entry  # type: ignore[attr-defined]
    return comps


def _multipliers(comps: Dict[str, List[OpLine]]) -> Dict[str, float]:
    """Execution-count multiplier per computation (loop nesting)."""
    entry = getattr(_parse_computations, "entry", None)
    if entry not in comps:
        entry = next(n for n in comps if n.startswith("main"))
    mult: Dict[str, float] = {}
    fusion_body: set = set()

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comps[name]:
            if op.opcode == "while":
                trip = 1.0
                mt = _TRIP_RE.search(op.text)
                if mt:
                    trip = float(mt.group(1))
                mb = _BODY_RE.search(op.text)
                mc = _COND_RE.search(op.text)
                if mb:
                    visit(mb.group(1), m * trip)
                if mc:
                    visit(mc.group(1), m * (trip + 1))
            elif op.opcode in ("fusion", "call", "conditional",
                               "custom-call", "async-start"):
                for callee in _CALLS_RE.findall(op.text):
                    if op.opcode == "fusion":
                        fusion_body.add(callee)
                    visit(callee, m)
                mb = _BODY_RE.search(op.text)
                if mb:
                    visit(mb.group(1), m)

    visit(entry, 1.0)
    _multipliers.fusion_bodies = fusion_body  # type: ignore[attr-defined]
    return mult


def _operand_names(op: OpLine) -> List[str]:
    """Operand op-names of an HLO instruction, in order.

    Handles both operand syntaxes: the bare ``dot(%a, %b)`` of older dumps
    and the typed ``dot(f32[128,128]{1,0} %a, ...)`` of newer ones — the
    type annotations carry commas inside brackets, so comma-splitting is
    only safe when no ``%``-prefixed names are present.
    """
    mo = _OPERANDS_RE.search(op.text)
    if not mo:
        return []
    group = mo.group(1)
    names = re.findall(r"%([\w.\-]+)", group)
    if names:
        return names
    return [p.strip() for p in group.split(",") if p.strip()]


def _operand_bytes(op: OpLine,
                   symtab: Dict[str, Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for name in _operand_names(op):
        dtype, dims = symtab.get(name, (None, None))
        if dims is None:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _dot_flops(op: OpLine, symtab: Dict[str, Tuple[str, Tuple[int, ...]]]
               ) -> float:
    operands = _operand_names(op)
    lhs = operands[0] if operands else ""
    lhs_shape = symtab.get(lhs, (None, ()))[1]
    mc = _LHS_CONTRACT_RE.search(op.text)
    k = 1
    if mc and lhs_shape:
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(lhs_shape):
                k *= lhs_shape[idx]
    return 2.0 * op.result_elems * k


def _nth_operand_bytes(op: OpLine, symtab, idx: int) -> float:
    names = _operand_names(op)
    if idx >= len(names):
        return 0.0
    dtype, dims = symtab.get(names[idx], (None, None))
    if dims is None:
        return 0.0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


def _op_traffic(op: OpLine, symtab, fusion_kinds=None) -> float:
    """HBM traffic of one materializing op. Indexed ops only touch the
    selected rows, NOT the whole operand: a token gather reads B*S rows of
    the embedding table, not all 2.5 GB of it, and a scan's per-layer
    stash (fused dynamic-update-slice into the (L, B, S, d) buffer, which
    XLA updates in place) writes one slice, not the whole stack."""
    if op.opcode in ("gather", "dynamic-slice"):
        return 2.0 * op.result_bytes
    if op.opcode == "dynamic-update-slice":
        return 2.0 * _nth_operand_bytes(op, symtab, 1)
    if op.opcode == "scatter":
        upd = _nth_operand_bytes(op, symtab, 2)
        return 2.0 * (upd if upd else op.result_bytes)
    if op.opcode == "fusion" and fusion_kinds is not None:
        callee = _CALLS_RE.findall(op.text)
        kind = fusion_kinds.get(callee[0]) if callee else None
        if kind == "dus":
            # in-place windowed update: traffic = the update slice r/w.
            return 2.0 * fusion_kinds.get(callee[0] + "/update_bytes", 0.0)
        if kind == "slice":
            return 2.0 * op.result_bytes
    return op.result_bytes + _operand_bytes(op, symtab)


def _classify_fusions(comps) -> dict:
    """fusion body name -> 'dus' | 'slice' | None (+ update byte size)."""
    kinds: dict = {}
    for name, ops in comps.items():
        symtab = {op.name: (op.dtype, op.dims) for op in ops}
        has_dot = any(op.opcode == "dot" for op in ops)
        if has_dot:
            continue
        dus = [op for op in ops if op.opcode == "dynamic-update-slice"]
        ds = [op for op in ops if op.opcode in ("dynamic-slice", "gather")]
        if dus:
            kinds[name] = "dus"
            kinds[name + "/update_bytes"] = sum(
                _nth_operand_bytes(op, symtab, 1) for op in dus)
        elif ds:
            kinds[name] = "slice"
    return kinds


def analyze(text: str, default_group: int = 1) -> CostSummary:
    comps = _parse_computations(text)
    mult = _multipliers(comps)
    fusion_bodies = getattr(_multipliers, "fusion_bodies", set())
    fusion_kinds = _classify_fusions(comps)

    flops = 0.0
    nbytes = 0.0
    coll: Dict[str, float] = {}
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = {op.name: (op.dtype, op.dims) for op in ops}
        in_fusion = cname in fusion_bodies
        for op in ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, symtab)
            if op.opcode in _COLLECTIVES or op.opcode.replace(
                    "-start", "") in _COLLECTIVES:
                kind = op.opcode.replace("-start", "")
                g = default_group
                mg = _GROUPS_RE.search(op.text)
                if mg:
                    g = len(mg.group(1).split(","))
                b = op.result_bytes
                if g > 1 and b:
                    ring = (g - 1) / g
                    wire = {"all-reduce": 2 * b * ring,
                            "all-gather": b * ring,
                            "reduce-scatter": b * (g - 1),
                            "all-to-all": b * ring,
                            "collective-permute": float(b)}[kind]
                    coll[kind] = coll.get(kind, 0.0) + m * wire
            if (not in_fusion and op.opcode in _MATERIALIZING):
                nbytes += m * _op_traffic(op, symtab, fusion_kinds)
    return CostSummary(flops, nbytes, coll)
