"""Serving launcher: batched requests through the continuous-batching
engine with RelShard stage-boundary re-planning.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse

import jax

from ..configs import ARCH_ALIASES, get_config, get_smoke_config
from ..core.relshard import plan_model
from ..models import lm
from ..models.config import ShapeConfig
from ..serving.engine import Request, ServeEngine
from .mesh import make_host_mesh, mesh_axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    arch = ARCH_ALIASES.get(args.arch, args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    mesh = make_host_mesh(args.data_par, args.model_par)
    axes = mesh_axes(mesh)
    shape = ShapeConfig("serve", args.max_seq, args.max_batch, "decode")
    plan = plan_model(cfg, axes, shape, fsdp=False)
    print(plan.explain())

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, plan, None if mesh.devices.size == 1 else mesh,
                      params, max_batch=args.max_batch,
                      max_seq=args.max_seq, mesh_axes=axes, shape=shape)
    for rid in range(args.requests):
        eng.submit(Request(rid, [1 + rid % 7, 2, 3], args.max_new))
    steps = 0
    done = []
    while (eng.queue or eng.occupancy()) and steps < 10_000:
        eng.step()
        if steps % 8 == 0:
            eng.maybe_replan()
        steps += 1
    print(f"[serve] completed {args.requests} requests in {steps} decode "
          f"steps; replan events: {eng.replan_events or 'none'}")


if __name__ == "__main__":
    main()
