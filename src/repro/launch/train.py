"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 256 --smoke --ckpt /tmp/ckpt [--resume]

On the CI container this drives the smoke-size configs on a host mesh; on
real hardware the same entry point takes ``--data-par/--model-par`` matching
the slice topology. Fault tolerance: periodic atomic checkpoints + resume.
"""

from __future__ import annotations

import argparse

from ..configs import ARCH_ALIASES, get_config, get_smoke_config
from ..core.relshard import plan_model
from ..models.config import ShapeConfig
from ..training.optimizer import OptConfig
from ..training.train_loop import train
from .mesh import make_host_mesh, mesh_axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()

    arch = ARCH_ALIASES.get(args.arch, args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    mesh = make_host_mesh(args.data_par, args.model_par)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = plan_model(cfg, mesh_axes(mesh), shape,
                      fsdp=args.data_par > 1)
    print(plan.explain())
    opt = OptConfig(name=cfg.optimizer, lr=args.lr,
                    grad_dtype=args.grad_dtype)
    train(cfg, plan, mesh, steps=args.steps, global_batch=args.batch,
          seq_len=args.seq, opt_cfg=opt, ckpt_dir=args.ckpt or None,
          ckpt_every=args.ckpt_every, resume=args.resume)


if __name__ == "__main__":
    main()
