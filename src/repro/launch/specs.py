"""ShapeDtypeStruct stand-ins for every entry-point input (dry-run inputs:
weak-type-correct, shardable, no device allocation) and the sharding trees
for each (arch x shape x mesh) cell."""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.relshard import ShardingPlan
from ..models import lm
from ..models.config import ModelConfig, ShapeConfig
from ..training.optimizer import OptConfig, init_opt_state, opt_state_specs


def _batch_shards(plan: ShardingPlan, mesh) -> int:
    return math.prod(mesh.shape[a] for a in plan.batch_axes)


def batch_pspec(plan: ShardingPlan, mesh, global_batch: int) -> P:
    """Batch dim sharding; replicated when the batch doesn't divide (e.g.
    long_500k's single sequence — model-parallel only, data axes idle)."""
    if global_batch % _batch_shards(plan, mesh) == 0:
        return P(plan.batch_axes)
    return P()


def input_specs(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan,
                mesh) -> Dict[str, Any]:
    """ShapeDtypeStructs + NamedShardings for the cell's model inputs."""
    B = shape.global_batch
    bp = batch_pspec(plan, mesh, B)

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, spec))

    if shape.kind == "decode":
        out = {"tokens": sds((B, 1), jnp.int32, bp)}
        cache = lm.init_cache  # structure via eval_shape below
        cache_shape = jax.eval_shape(
            lambda: lm.init_cache(cfg, B, shape.seq_len))
        out["cache"] = jax.tree.map(
            lambda s: sds(s.shape, s.dtype,
                          _cache_pspec(s.shape, cfg, plan, mesh, B)),
            cache_shape)
        return out

    S_text = shape.seq_len - cfg.n_cond_tokens
    out = {"tokens": sds((B, S_text), jnp.int32, bp)}
    if cfg.n_cond_tokens:
        out["cond_emb"] = sds((B, cfg.n_cond_tokens, cfg.d_model),
                              jnp.bfloat16, bp)
    return out


def _cache_pspec(shp: Tuple[int, ...], cfg: ModelConfig, plan: ShardingPlan,
                 mesh, B: int) -> P:
    """Cache sharding: batch dim over data axes when divisible; otherwise
    shard the sequence dim of KV caches over the data axes (sequence-
    sharded long-context decode) and KV heads over model when divisible."""
    bs = _batch_shards(plan, mesh)
    model = plan.model_axis
    m = mesh.shape[model]
    if len(shp) == 1:   # pos
        return P()
    batch_ok = (B % bs == 0)
    bdim = plan.batch_axes if batch_ok else None
    if len(shp) == 5 and shp[2] >= 1024:    # (L/seg, B, S, G, hd) KV cache
        sdim = None if batch_ok else plan.batch_axes
        gdim = model if shp[3] % m == 0 else None
        return P(None, bdim, sdim, gdim, None)
    if len(shp) >= 3:
        return P(None, bdim, *(None,) * (len(shp) - 2))
    return P(None, bdim)


def model_shardings(cfg: ModelConfig, plan: ShardingPlan, mesh,
                    opt_cfg: OptConfig | None = None):
    """(param ShapeDtypeStructs+shardings, opt state ditto, spec trees)."""
    params_shape = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    specs = lm.param_specs(cfg, params_shape, plan)
    p_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        params_shape, specs, is_leaf=lambda x: isinstance(
            x, jax.ShapeDtypeStruct))
    if opt_cfg is None:
        return p_sds, None, specs
    opt_shape = jax.eval_shape(lambda: init_opt_state(opt_cfg, params_shape))
    o_specs = opt_state_specs(opt_cfg, specs)
    o_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        opt_shape, o_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return p_sds, o_sds, specs
