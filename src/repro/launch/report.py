"""Render experiments/dryrun/*.json into the EXPERIMENTS.md §Dry-run and
§Roofline tables.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    recs = {}
    for f in glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json")):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2 ** 30:.2f}"


def dryrun_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        f"### Mesh: {mesh} "
        f"({'2x16x16 = 512 chips' if mesh == 'multi' else '16x16 = 256 chips'})",
        "",
        "| arch | shape | status | plan (embed/head/moe) | GiB/dev (args+temp) "
        "| GFLOPs/dev | coll GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape) in sorted(recs, key=lambda t: (t[0],
                                SHAPE_ORDER.index(t[1]))):
        r = recs[(arch, shape)]
        if r["status"] == "skip":
            lines.append(f"| {arch} | {shape} | SKIP(full-attention) | — | — "
                         f"| — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | **ERROR** "
                         f"| {r.get('error', '?')[:60]} | — | — | — | — |")
            continue
        plan = r["plan"]
        mem = r["memory"]
        coll = sum(r["collectives_per_device"].values())
        lines.append(
            f"| {arch} | {shape} | ok "
            f"| {plan['embed'][:5]}/{plan['head'][:5]}/{plan['moe'][:6]} "
            f"| {fmt_bytes(mem['argument_bytes_per_device'])}+"
            f"{fmt_bytes(mem['temp_bytes_per_device'])} "
            f"| {r['cost']['flops_per_device'] / 1e9:.0f} "
            f"| {fmt_bytes(coll)} | {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = load("single")
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound "
        "| step s | roofline frac | 6ND/HLO |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape) in sorted(recs, key=lambda t: (t[0],
                                SHAPE_ORDER.index(t[1]))):
        r = recs[(arch, shape)]
        if r["status"] != "ok":
            tag = ("SKIP" if r["status"] == "skip" else "ERROR")
            lines.append(f"| {arch} | {shape} | — | — | — | {tag} | — | — "
                         f"| — |")
            continue
        rf = r["roofline"]
        step = rf["step_time_s"]
        frac = rf["compute_s"] / step if step else 0
        lines.append(
            f"| {arch} | {shape} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| {rf['bound']} | {step:.4f} | {frac:.2f} "
            f"| {r['model_flops_ratio']:.2f} |")
    return "\n".join(lines)


def summary():
    recs_s, recs_m = load("single"), load("multi")
    ok_s = sum(r["status"] == "ok" for r in recs_s.values())
    sk_s = sum(r["status"] == "skip" for r in recs_s.values())
    er_s = sum(r["status"] == "error" for r in recs_s.values())
    ok_m = sum(r["status"] == "ok" for r in recs_m.values())
    sk_m = sum(r["status"] == "skip" for r in recs_m.values())
    er_m = sum(r["status"] == "error" for r in recs_m.values())
    return (f"single-pod: {ok_s} ok / {sk_s} skip / {er_s} error; "
            f"multi-pod: {ok_m} ok / {sk_m} skip / {er_m} error "
            f"(of 40 cells each)")


def main():
    print("## Dry-run summary\n")
    print(summary(), "\n")
    print(dryrun_table("single"), "\n")
    print(dryrun_table("multi"), "\n")
    print("## Roofline (single-pod, 256 chips)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
