import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: build the production
mesh, derive the RelShard plan, lower + compile the cell's entry point
(train_step / prefill / serve_step) against ShapeDtypeStruct inputs (no
allocation), print memory_analysis + cost_analysis, and persist the
roofline terms to experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from ..configs import ARCH_ALIASES, ARCH_IDS, get_config          # noqa: E402
from ..core.relshard import plan_model                            # noqa: E402
from ..models import lm                                           # noqa: E402
from ..models.config import SHAPES, SHAPE_BY_NAME, shape_applicable  # noqa: E402
from ..training.optimizer import OptConfig                        # noqa: E402
from ..training.train_loop import make_train_step                 # noqa: E402
from .mesh import make_production_mesh, mesh_axes                 # noqa: E402
from .roofline import model_flops, roofline_from_compiled         # noqa: E402
from .specs import input_specs, model_shardings                   # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell; returns (record, compiled)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPE_BY_NAME[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": skip}, None
    if shape_name == "long_500k" and cfg.attn_window == 0 \
            and cfg.family.value == "hybrid":
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_window=4096)

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(mesh)
    plan = plan_model(cfg, axes, shape)
    specs = input_specs(cfg, shape, plan, mesh)
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = OptConfig(name=cfg.optimizer)
        p_sds, o_sds, _ = model_shardings(cfg, plan, mesh, opt_cfg)
        step = make_train_step(cfg, plan, mesh, opt_cfg)
        batch = {k: v for k, v in specs.items()}
        fn = jax.jit(step,
                     in_shardings=(jax.tree.map(lambda s: s.sharding, p_sds),
                                   jax.tree.map(lambda s: s.sharding, o_sds),
                                   jax.tree.map(lambda s: s.sharding, batch)),
                     donate_argnums=(0, 1))
        lowered = fn.lower(p_sds, o_sds, batch)
    elif shape.kind == "prefill":
        p_sds, _, _ = model_shardings(cfg, plan, mesh)

        def entry(params, tokens, cond_emb=None):
            return lm.prefill(params, cfg, plan, mesh, tokens, cond_emb)
        args = [p_sds, specs["tokens"]]
        if "cond_emb" in specs:
            args.append(specs["cond_emb"])
        lowered = jax.jit(entry).lower(*args)
    else:  # decode
        p_sds, _, _ = model_shardings(cfg, plan, mesh)

        def entry(params, tokens, cache):
            return lm.decode_step(params, cfg, plan, mesh, tokens, cache)
        lowered = jax.jit(entry).lower(p_sds, specs["tokens"],
                                       specs["cache"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    n_dev = mesh.devices.size
    hlo = compiled.as_text()
    rf = roofline_from_compiled(compiled, n_dev, hlo)
    mf = model_flops(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "chips": n_dev,
        "plan": {"embed": plan.embed_strategy, "head": plan.head_strategy,
                 "moe": plan.moe_strategy, "w": plan.w},
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_per_device": (mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         + mem.temp_size_in_bytes
                                         - mem.alias_size_in_bytes),
        },
        "cost": {"flops_per_device": rf.flops / n_dev,
                 "bytes_per_device": rf.hbm_bytes / n_dev},
        "collectives_per_device": rf.per_collective,
        "roofline": {"compute_s": rf.compute_s, "memory_s": rf.memory_s,
                     "collective_s": rf.collective_s, "bound": rf.bound,
                     "step_time_s": rf.step_time_s()},
        "model_flops": mf,
        "model_flops_ratio": rf.model_flops_ratio(mf),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    return record, compiled


def run_and_save(arch, shape_name, multi_pod, out_dir=RESULTS_DIR,
                 overrides=None, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    fname = os.path.join(out_dir,
                         f"{arch}_{shape_name}_{mesh_tag}{tag}.json")
    try:
        record, compiled = lower_cell(arch, shape_name, multi_pod, overrides)
        if compiled is not None:
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    with open(fname, "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = (f" bound={record['roofline']['bound']}"
             f" step={record['roofline']['step_time_s']:.4f}s"
             if status == "ok" else
             record.get("reason", record.get("error", ""))[:120])
    print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_tag:6s} "
          f"{status.upper():5s} {extra}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (hyphenated ok)")
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for s in SHAPES:
                cells.append((arch, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((ARCH_ALIASES.get(args.arch, args.arch), args.shape))

    n_ok = n_skip = n_err = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = "multi" if mp else "single"
            fname = os.path.join(args.out,
                                 f"{arch}_{shape_name}_{tag}.json")
            if args.skip_existing and os.path.exists(fname):
                with open(fname) as f:
                    if json.load(f).get("status") in ("ok", "skip"):
                        continue
            rec = run_and_save(arch, shape_name, mp, args.out)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skip"
            n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
