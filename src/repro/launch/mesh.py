"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use;
tests and benches keep their 1-device view).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(mesh) -> tuple:
    """((name, size), ...) in mesh order — the planner's mesh description."""
    return tuple(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests, examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
