"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use;
tests and benches keep their 1-device view).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types only exists on newer jax; older versions are Auto-only
    anyway, so omitting the kwarg is semantically identical."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_axes(mesh) -> tuple:
    """((name, size), ...) in mesh order — the planner's mesh description."""
    return tuple(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
