"""Roofline term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` gives FLOPs/bytes (per-device program; multiply by chip
count for cluster totals). collective_bytes is parsed from the post-SPMD
module text: per collective op, wire bytes per device are estimated from
the result shape, the participant group size, and the op's ring-algorithm
factor, then multiplied by the chip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e target constants.
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|((?:pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
    r"f64)\[[0-9,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_bytes(line: str) -> float:
    """Total result bytes of a (possibly tuple-shaped) collective op."""
    # take the result shape(s): text between '= ' and the op name
    m = re.search(r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|"
                  r"all-to-all|collective-permute)", line)
    if not m:
        return 0.0
    return sum(_shape_bytes(d, s)
               for d, s in _TUPLE_SHAPE_RE.findall(m.group(1)))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    return len(m.group(1).split(","))


def parse_collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (one program execution)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done" in line:
            continue  # paired with -start; counted once
        g = _group_size(line, n_devices)
        b = _line_bytes(line)
        if g <= 1 or b == 0:
            continue
        ring = (g - 1) / g
        if kind == "all-reduce":
            wire = 2 * b * ring
        elif kind == "all-gather":
            wire = b * ring              # result is the gathered buffer
        elif kind == "reduce-scatter":
            wire = b * (g - 1)           # result is the scattered shard
        elif kind == "all-to-all":
            wire = b * ring
        else:  # collective-permute
            wire = b
        out[kind] = out.get(kind, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # cluster total
    hbm_bytes: float              # cluster total
    collective_bytes: float       # cluster total (wire)
    chips: int
    per_collective: Dict[str, float]
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bound: str = ""

    def __post_init__(self):
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hbm_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * LINK_BW)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bound = max(terms, key=terms.get)

    def step_time_s(self) -> float:
        """Roofline-optimistic step time (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def model_flops_ratio(self, model_flops: float) -> float:
        return model_flops / self.flops if self.flops else 0.0


def roofline_from_compiled(compiled, n_devices: int,
                           hlo_text: Optional[str] = None) -> Roofline:
    """Derive the three terms from the post-SPMD module via the trip-count-
    aware analyzer (hlo_cost) — XLA's own cost_analysis counts while bodies
    once, under-reporting scanned models by ~n_layers."""
    from .hlo_cost import analyze
    text = hlo_text if hlo_text is not None else compiled.as_text()
    summary = analyze(text, default_group=n_devices)
    return Roofline(flops=summary.flops * n_devices,
                    hbm_bytes=summary.bytes_accessed * n_devices,
                    collective_bytes=summary.total_collective_bytes
                    * n_devices,
                    chips=n_devices,
                    per_collective=summary.collective_wire_bytes)


def model_flops(cfg, shape, per_token_factor: float = 6.0) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch
        return 2.0 * n * tokens     # forward only
    tokens = shape.global_batch * shape.seq_len
    factor = per_token_factor if shape.kind == "train" else 2.0
    return factor * n * tokens
