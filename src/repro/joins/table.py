"""Columnar tables on JAX arrays with static shapes + validity masks.

XLA needs static shapes, so a Table has a fixed row *capacity*; the live rows
are marked in ``valid``. A *stacked* table carries a leading partition axis
``(p, cap)`` — the engine's unit of distribution; an *unstacked* table
``(cap,)`` is a single partition (or a broadcast replica).

The measured (size, cardinality) of the valid rows IS the paper's adaptive
runtime statistic; ``measure()`` produces it after every exchange.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.stats import StatsSource, TableStats


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """Columnar table: dict of same-shape arrays + validity mask.

    ``partitioned_by`` records the hash-partitioning key when the table was
    produced by a shuffle on that key (Spark's output-partitioning property):
    a subsequent shuffle on the same key is elided (§3.7's key-dependency
    case where C_shuffle = 0).
    """

    columns: Dict[str, jax.Array]
    valid: jax.Array  # bool, shape == each column's shape
    partitioned_by: str | None = None

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        leaves = tuple(self.columns[n] for n in names) + (self.valid,)
        return leaves, (names, self.partitioned_by)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        names, part = aux
        return cls(dict(zip(names, leaves[:-1])), leaves[-1], part)

    # -- structure ----------------------------------------------------------

    @property
    def stacked(self) -> bool:
        return self.valid.ndim == 2

    @property
    def num_partitions(self) -> int:
        return self.valid.shape[0] if self.stacked else 1

    @property
    def capacity(self) -> int:
        return self.valid.shape[-1]

    @property
    def row_bytes(self) -> int:
        return int(sum(np.dtype(c.dtype).itemsize
                       for c in self.columns.values()))

    def column(self, name: str) -> jax.Array:
        return self.columns[name]

    def with_columns(self, columns: Dict[str, jax.Array]) -> "Table":
        return Table(columns, self.valid, self.partitioned_by)

    def with_valid(self, valid: jax.Array) -> "Table":
        return Table(self.columns, valid, self.partitioned_by)

    def select(self, names) -> "Table":
        part = self.partitioned_by if self.partitioned_by in names else None
        return Table({n: self.columns[n] for n in names}, self.valid, part)

    # -- statistics ----------------------------------------------------------

    def count(self) -> int:
        """Concrete number of valid rows (host sync)."""
        return int(jnp.sum(self.valid))

    def measure(self) -> TableStats:
        """Adaptive runtime statistic of this materialized dataset."""
        rows = self.count()
        return TableStats(rows * self.row_bytes, rows, StatsSource.RUNTIME)

    # -- conversion ----------------------------------------------------------

    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Compacted valid rows as numpy (host-side; for tests/oracles)."""
        v = np.asarray(self.valid).reshape(-1)
        out = {}
        for n, c in self.columns.items():
            out[n] = np.asarray(c).reshape(-1)[v]
        return out


def from_numpy(columns: Dict[str, np.ndarray], capacity: int | None = None
               ) -> Table:
    """Build an unstacked table; pads to ``capacity`` with invalid rows."""
    n = len(next(iter(columns.values())))
    cap = capacity or n
    if cap < n:
        raise ValueError(f"capacity {cap} < rows {n}")
    cols, pad = {}, cap - n
    for name, arr in columns.items():
        a = np.asarray(arr)
        if a.dtype == np.int64:
            a = a.astype(np.int32)
        if a.dtype == np.float64:
            a = a.astype(np.float32)
        cols[name] = jnp.asarray(np.pad(a, (0, pad)))
    valid = jnp.asarray(np.arange(cap) < n)
    return Table(cols, valid)


def partition_round_robin(table: Table, p: int) -> Table:
    """Split an unstacked table into p partitions (initial data placement,
    like HDFS blocks landing on executors). Capacity must divide by p."""
    if table.stacked:
        raise ValueError("already stacked")
    cap = table.capacity
    per = -(-cap // p)
    pad = per * p - cap
    cols = {n: jnp.pad(c, (0, pad)).reshape(p, per)
            for n, c in table.columns.items()}
    valid = jnp.pad(table.valid, (0, pad), constant_values=False
                    ).reshape(p, per)
    return Table(cols, valid)


def compact_partitions(table: Table, capacity: int | None = None,
                       slack: float = 1.1) -> Table:
    """Pack valid rows to the front of each partition and shrink capacity.

    Keeps post-join tables from growing unboundedly across a join chain
    (Spark analog: AQE's post-stage partition coalescing). Host-syncs the
    max per-partition live count, like any stage materialization.

    The chosen capacity is rounded up to a power of two: downstream join
    kernels then see a small set of distinct shapes, so XLA compilations
    are reused across stages, queries, and strategies instead of
    recompiling for every data-dependent row count.
    """
    if not table.stacked:
        raise ValueError("compact expects a stacked table")
    counts = jnp.sum(table.valid, axis=1)
    need = int(jnp.max(counts))
    cap = capacity or max(8, 1 << (max(int(need * slack), 1) - 1).bit_length())
    cap = min(cap, table.capacity)

    order = jnp.argsort(~table.valid, axis=1, stable=True)[:, :cap]
    cols = {n: jnp.take_along_axis(c, order, axis=1)
            for n, c in table.columns.items()}
    valid = jnp.take_along_axis(table.valid, order, axis=1)
    return Table(cols, valid, table.partitioned_by)


def concat_partitions(table: Table) -> Table:
    """Flatten a stacked table into a single logical partition view."""
    if not table.stacked:
        return table
    cols = {n: c.reshape(-1) for n, c in table.columns.items()}
    return Table(cols, table.valid.reshape(-1))
