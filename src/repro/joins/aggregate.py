"""Group-by aggregation — the other exchange-bounded operation (paper §1:
"every join or group-by-like operation" updates runtime statistics).

Distributed plan: shuffle rows by group key (same exchange as the shuffle
joins), then aggregate each co-partition locally: sort by key, mark segment
heads, segment-sum. Static shapes throughout; output rows are the segment
heads (cardinality = #groups, the runtime statistic of the stage).
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from .exchange import ExchangeReport, shuffle
from .table import Table

AGG_OPS = ("sum", "count", "min", "max", "mean")


def _local_group_agg(key: jax.Array, valid: jax.Array,
                     cols: Dict[str, jax.Array],
                     aggs: Sequence[Tuple[str, str]]):
    """Aggregate one partition by key. Returns (out_cols, out_valid)."""
    n = key.shape[0]
    big = jnp.iinfo(jnp.int32).max
    k = jnp.where(valid, key, big).astype(jnp.int32)
    order = jnp.argsort(k)
    ks = k[order]
    head = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    seg = jnp.cumsum(head.astype(jnp.int32)) - 1          # group id per row
    out_valid = head & (ks != big)

    out_cols = {"_group_key": jnp.where(out_valid, ks, 0)}
    live = (ks != big)
    for col, op in aggs:
        v = cols[col][order]
        if op == "count":
            data = live.astype(jnp.int32)
            seg_out = jax.ops.segment_sum(data, seg, num_segments=n)
        elif op in ("sum", "mean"):
            data = jnp.where(live, v, 0)
            seg_out = jax.ops.segment_sum(data, seg, num_segments=n)
            if op == "mean":
                cnt = jax.ops.segment_sum(live.astype(v.dtype), seg,
                                          num_segments=n)
                seg_out = seg_out / jnp.maximum(cnt, 1)
        elif op == "min":
            data = jnp.where(live, v, jnp.asarray(jnp.inf, v.dtype)
                             if jnp.issubdtype(v.dtype, jnp.floating)
                             else jnp.iinfo(v.dtype).max)
            seg_out = jax.ops.segment_min(data, seg, num_segments=n)
        elif op == "max":
            data = jnp.where(live, v, jnp.asarray(-jnp.inf, v.dtype)
                             if jnp.issubdtype(v.dtype, jnp.floating)
                             else jnp.iinfo(v.dtype).min)
            seg_out = jax.ops.segment_max(data, seg, num_segments=n)
        else:
            raise ValueError(f"unknown agg op {op}")
        # Each row reads its group's aggregate; only head rows stay valid.
        out_cols[f"{op}_{col}"] = jnp.take(seg_out, seg)
    # Head rows carry the group results; others are invalid.
    return out_cols, out_valid


def group_aggregate(table: Table, key: str,
                    aggs: Sequence[Tuple[str, str]],
                    capacity_factor: float = 2.0
                    ) -> tuple[Table, ExchangeReport]:
    """Distributed group-by: shuffle by key + local segment aggregation."""
    if not table.stacked:
        raise ValueError("group_aggregate expects a stacked table")
    shuffled, report = shuffle(table, key, capacity_factor)
    out_cols, out_valid = jax.vmap(
        lambda k, v, c: _local_group_agg(k, v, c, tuple(aggs))
    )(shuffled.column(key), shuffled.valid, shuffled.columns)
    out_cols = dict(out_cols)
    out_cols[key] = out_cols.pop("_group_key")
    # Output is hash-partitioned by the group key: downstream shuffles on
    # the same key are elided (§3.7 key-dependency).
    return Table(out_cols, out_valid, partitioned_by=key), report


def global_aggregate(table: Table, aggs: Sequence[Tuple[str, str]]
                     ) -> Dict[str, float]:
    """Whole-table scalar aggregates (query result tails)."""
    out = {}
    v = table.valid
    for col, op in aggs:
        c = table.column(col)
        if op == "count":
            out[f"count_{col}"] = float(jnp.sum(v))
        elif op == "sum":
            out[f"sum_{col}"] = float(jnp.sum(jnp.where(v, c, 0)))
        elif op == "mean":
            s = float(jnp.sum(jnp.where(v, c, 0)))
            n = float(jnp.sum(v))
            out[f"mean_{col}"] = s / max(n, 1.0)
        elif op == "min":
            out[f"min_{col}"] = float(jnp.min(jnp.where(v, c, jnp.inf)))
        elif op == "max":
            out[f"max_{col}"] = float(jnp.max(jnp.where(v, c, -jnp.inf)))
    return out
