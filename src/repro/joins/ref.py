"""Pure numpy oracle for distributed join semantics (test ground truth)."""

from __future__ import annotations

from typing import Dict

import numpy as np


def ref_equi_join(a_cols: Dict[str, np.ndarray], b_cols: Dict[str, np.ndarray],
                  a_key: str, b_key: str, join_type: str = "inner"
                  ) -> Dict[str, np.ndarray]:
    """FK->PK equi-join oracle (build keys unique). Row order is undefined;
    compare as multisets of rows."""
    bk = b_cols[b_key]
    assert len(np.unique(bk)) == len(bk), "oracle requires unique build keys"
    lookup = {int(k): i for i, k in enumerate(bk)}
    ak = a_cols[a_key]
    # Explicit dtype: an empty probe side would otherwise produce a float64
    # index array, which numpy rejects as an index.
    idx = np.asarray([lookup.get(int(k), -1) for k in ak], dtype=np.int64)
    found = idx >= 0

    if join_type == "left_semi":
        return {n: c[found] for n, c in a_cols.items()}
    if join_type == "left_anti":
        return {n: c[~found] for n, c in a_cols.items()}

    keep = found if join_type == "inner" else np.ones_like(found)
    out = {n: c[keep] for n, c in a_cols.items()}
    sel = idx[keep]
    for n, c in b_cols.items():
        name = n if n not in out else f"{n}_r"
        col = c[np.maximum(sel, 0)]
        if join_type == "left_outer":
            col = np.where(sel >= 0, col, 0)
        out[name] = col
    if join_type == "left_outer":
        out[f"{b_key}_matched"] = sel >= 0
    return out


def ref_multiway_join(tables, links, checks=()) -> Dict[str, np.ndarray]:
    """Multi-way cyclic-join oracle. ``tables[0]`` is the probe; each link
    ``(build_index, probe_col, build_col)`` is an FK->PK lookup into
    ``tables[build_index]`` (build keys unique) whose columns are gathered
    into the output row; ``checks`` are residual ``(col_a, col_b)``
    equalities — the closing edges of the cyclic core — applied to the
    fully gathered row."""
    out = {n: np.asarray(c) for n, c in tables[0].items()}
    for bi, pcol, bcol in links:
        out = ref_equi_join(out, tables[bi], pcol, bcol)
    for ca, cb in checks:
        keep = out[ca] == out[cb]
        out = {n: c[keep] for n, c in out.items()}
    return out


def rows_as_set(cols: Dict[str, np.ndarray]):
    """Multiset-comparable representation of a table's rows."""
    names = sorted(cols)
    n = len(cols[names[0]]) if names else 0
    return sorted(tuple(float(cols[c][i]) for c in names) for i in range(n))


def rows_close(a, b, rel: float = 1e-3) -> bool:
    """Compare two rows_as_set lists; float aggregates may differ in
    summation order across physical plans, so compare with tolerance."""
    if len(a) != len(b):
        return False
    import math
    for ra, rb in zip(a, b):
        if len(ra) != len(rb):
            return False
        for va, vb in zip(ra, rb):
            if not math.isclose(va, vb, rel_tol=rel, abs_tol=1e-4):
                return False
    return True
