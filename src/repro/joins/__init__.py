"""Distributed join engine: columnar tables, exchange collectives, local
join algorithms, and the physical distributed join methods (six binary
methods plus the hypercube multi-way shuffle)."""

from .exchange import (ExchangeReport, broadcast, hypercube_shuffle,
                       key_skew, salted_shuffle, shuffle)
from .methods import (HypercubeLink, HypercubeSpec, JoinReport,
                      broadcast_hash_join, broadcast_nl_join, cartesian_join,
                      hypercube_multiway_join, run_equi_join,
                      salted_shuffle_hash_join, shuffle_hash_join,
                      shuffle_sort_join)
from .table import Table, concat_partitions, from_numpy, partition_round_robin

__all__ = [
    "ExchangeReport", "broadcast", "hypercube_shuffle", "key_skew",
    "salted_shuffle", "shuffle",
    "HypercubeLink", "HypercubeSpec", "JoinReport", "broadcast_hash_join",
    "broadcast_nl_join", "cartesian_join", "hypercube_multiway_join",
    "run_equi_join", "salted_shuffle_hash_join", "shuffle_hash_join",
    "shuffle_sort_join", "Table", "concat_partitions", "from_numpy",
    "partition_round_robin",
]
