"""Distributed join engine: columnar tables, exchange collectives, local
join algorithms, and the five physical distributed join methods."""

from .exchange import (ExchangeReport, broadcast, key_skew, salted_shuffle,
                       shuffle)
from .methods import (JoinReport, broadcast_hash_join, broadcast_nl_join,
                      cartesian_join, run_equi_join,
                      salted_shuffle_hash_join, shuffle_hash_join,
                      shuffle_sort_join)
from .table import Table, concat_partitions, from_numpy, partition_round_robin

__all__ = [
    "ExchangeReport", "broadcast", "key_skew", "salted_shuffle", "shuffle",
    "JoinReport", "broadcast_hash_join", "broadcast_nl_join",
    "cartesian_join", "run_equi_join", "salted_shuffle_hash_join",
    "shuffle_hash_join", "shuffle_sort_join", "Table", "concat_partitions",
    "from_numpy", "partition_round_robin",
]
