"""Local join phase (paper §2.1.2) — per-partition pure functions.

Each local join resolves, for every probe row of A, the matching build row
of B (PK build side: unique keys, FK->PK star joins), returning
``(match_idx, found)``. The distributed methods gather B's payload columns
through ``match_idx`` afterwards.

TPU adaptation (DESIGN.md §2): the *hash* join is a radix hash join —
bucket both sides by a multiplicative hash, then run a dense tiled key-match
within each bucket (the ``tiled_probe`` Pallas kernel is the in-VMEM
primitive; a jnp path with identical semantics is the CPU default). The
*sort* join sorts both sides (bitonic tile kernel / XLA sort) and merges via
binary search. The *nested loop* compares all pairs with an arbitrary
predicate.

Invalid-row sentinels: probe side -1, build side -2 (never equal).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..kernels import ref as kref
from .slots import BUCKET_SEED, hash32, slot_scatter

A_SENTINEL = -1
B_SENTINEL = -2


class LocalJoinResult(NamedTuple):
    match_idx: jax.Array  # (na,) int32 row index into the B arrays, -1 = none
    found: jax.Array      # (na,) bool


def _sanitize(keys: jax.Array, valid: jax.Array, sentinel: int) -> jax.Array:
    return jnp.where(valid, keys, sentinel).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Hash join (radix-bucketed tiled match).
# ---------------------------------------------------------------------------

def _bucket_of(keys: jax.Array, nb: int) -> jax.Array:
    return (hash32(keys, BUCKET_SEED) % jnp.uint32(nb)).astype(jnp.int32)


def hash_join(a_keys: jax.Array, a_valid: jax.Array,
              b_keys: jax.Array, b_valid: jax.Array,
              *, n_buckets: int | None = None,
              bucket_cap_factor: float = 4.0,
              use_kernel: bool = False) -> LocalJoinResult:
    """Radix hash join of one partition. Build side keys must be unique.

    Build: scatter B rows into ``nb`` hash buckets of static capacity
    (C'_build ~ |B|). Probe: each A row inspects only its bucket's keys
    (C_probe ~ |A| + fanout*|B|). With ``use_kernel`` both sides are
    bucketed and each bucket pair runs the dense ``tiled_probe`` Pallas
    match (the TPU execution plan); the default jnp path gathers each probe
    row's bucket tile and compares — identical semantics, fast on CPU.
    """
    na, b_cap = a_keys.shape[0], b_keys.shape[0]
    ak = _sanitize(a_keys, a_valid, A_SENTINEL)
    bk = _sanitize(b_keys, b_valid, B_SENTINEL)

    nb = n_buckets or max(1, min(1 << (max(b_cap, 1) - 1).bit_length(),
                                 max(8, b_cap // 32)))
    b_slot_cap = max(8, int(-(-b_cap * bucket_cap_factor) // nb))

    # Build: bucket B (the "hash map" is the slotted (nb, cap) layout).
    bb = _bucket_of(bk, nb)
    scat_b = slot_scatter(bb, b_valid, nb, b_slot_cap)
    bk_bucketed = jnp.where(scat_b.idx >= 0,
                            jnp.take(bk, jnp.maximum(scat_b.idx, 0)),
                            B_SENTINEL)  # (nb, cap_b)
    ab = _bucket_of(ak, nb)

    if not use_kernel:
        # Probe: gather each A row's bucket tile and match within it.
        cand_keys = jnp.take(bk_bucketed, ab, axis=0)      # (na, cap_b)
        cand_rows = jnp.take(scat_b.idx, ab, axis=0)       # (na, cap_b)
        hit = cand_keys == ak[:, None]
        slot = jnp.argmax(hit, axis=1)
        found = jnp.any(hit, axis=1)
        idx = jnp.take_along_axis(cand_rows, slot[:, None], axis=1)[:, 0]
        found = found & (idx >= 0) & a_valid
        return LocalJoinResult(jnp.where(found, idx, -1).astype(jnp.int32),
                               found)

    # Kernel path: bucket A as well, run one dense tile match per bucket.
    a_slot_cap = max(8, int(-(-na * bucket_cap_factor) // nb))
    scat_a = slot_scatter(ab, a_valid, nb, a_slot_cap)
    ak_bucketed = jnp.where(scat_a.idx >= 0,
                            jnp.take(ak, jnp.maximum(scat_a.idx, 0)),
                            A_SENTINEL)  # (nb, cap_a)
    slot_in_bucket = jax.vmap(
        lambda aks, bks: kops.probe(aks, bks))(ak_bucketed, bk_bucketed)
    # Resolve to B row ids and scatter back to A's original row order.
    b_rows = jnp.take_along_axis(
        scat_b.idx, jnp.maximum(slot_in_bucket, 0), axis=1)
    b_rows = jnp.where(slot_in_bucket >= 0, b_rows, -1)  # (nb, cap_a)
    out = jnp.full((na,), -1, jnp.int32)
    out = out.at[jnp.where(scat_a.idx >= 0, scat_a.idx, na).reshape(-1)
                 ].set(b_rows.reshape(-1), mode="drop")
    found = (out >= 0) & a_valid
    return LocalJoinResult(jnp.where(found, out, -1), found)


# ---------------------------------------------------------------------------
# Sort join (sort both sides, merge by binary search).
# ---------------------------------------------------------------------------

def sort_join(a_keys: jax.Array, a_valid: jax.Array,
              b_keys: jax.Array, b_valid: jax.Array,
              *, use_kernel_sort: bool = False) -> LocalJoinResult:
    """Sort-merge join of one partition. Build side keys must be unique.

    Both sides are sorted by key (C_sort ~ |A|log a/p + |B|log b/p); the
    merge walks A in key order probing the sorted B run (C_merge ~ |A|+|B|).
    Output rows remain addressed in A's original order (match_idx aligns
    with the unsorted probe side; the sort is internal to the method).
    """
    ak = _sanitize(a_keys, a_valid, jnp.iinfo(jnp.int32).max)  # invalid last
    bk = _sanitize(b_keys, b_valid, jnp.iinfo(jnp.int32).max)
    nb = bk.shape[0]

    rows_b = jnp.arange(nb, dtype=jnp.int32)
    if use_kernel_sort:
        bk_sorted, b_perm = kops.sort_pairs(bk, rows_b)
    else:
        bk_sorted, b_perm = kref.bitonic_sort_ref(bk, rows_b)

    # Sort A as the method prescribes (workload accounting); the merge below
    # is order-insensitive so correctness is unaffected.
    pos = jnp.searchsorted(bk_sorted, ak).astype(jnp.int32)
    pos = jnp.minimum(pos, nb - 1)
    found = (jnp.take(bk_sorted, pos) == ak) & a_valid
    idx = jnp.take(b_perm, pos)
    b_ok = jnp.take(b_valid, jnp.maximum(idx, 0))
    found = found & b_ok
    return LocalJoinResult(jnp.where(found, idx, -1).astype(jnp.int32), found)


# ---------------------------------------------------------------------------
# Nested loop (arbitrary predicate; O(na * nb)).
# ---------------------------------------------------------------------------

def nested_loop_join(a_cols: dict, a_valid: jax.Array,
                     b_cols: dict, b_valid: jax.Array,
                     predicate: Callable[[dict, dict], jax.Array]
                     ) -> LocalJoinResult:
    """First-match nested loop with an arbitrary row predicate.

    ``predicate`` receives A columns shaped (na, 1) and B columns shaped
    (1, nb) and returns an (na, nb) boolean matrix.
    """
    a_b = {n: c[:, None] for n, c in a_cols.items()}
    b_b = {n: c[None, :] for n, c in b_cols.items()}
    hit = predicate(a_b, b_b) & a_valid[:, None] & b_valid[None, :]
    found = jnp.any(hit, axis=1)
    idx = jnp.argmax(hit, axis=1).astype(jnp.int32)
    return LocalJoinResult(jnp.where(found, idx, -1), found)
