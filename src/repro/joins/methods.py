"""The five distributed join methods (paper §2.1, §3) — global-view
executables on stacked tables.

Each method = exchange phase + local join phase, mirroring the cost model's
decomposition. All methods produce the same logical result for FK->PK
equi-joins: the probe table's rows (original partition layout) extended with
the matched build-side payload columns, and a per-method JoinReport with
*measured* phase workloads for cost-model validation.

Join types: inner, left_outer, left_semi, left_anti (probe side preserved;
the engine puts the larger table on the probe side as §3.1.4 prescribes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.cost_model import JoinMethod
from ..kernels import ops as kops
from .exchange import (ExchangeReport, broadcast, hypercube_shuffle,
                       salted_shuffle, shuffle)
from .local_join import (A_SENTINEL, B_SENTINEL, hash_join, nested_loop_join,
                         sort_join)
from .slots import gather_rows
from .table import Table


@dataclasses.dataclass
class JoinReport:
    method: JoinMethod
    exchanges: list          # ExchangeReport per exchanged input
    local_bytes: float       # measured local-join phase workload (bytes)
    output_rows: int


def _merge_payload(a: Table, b_cols: dict, b_valid_src: jax.Array,
                   idx: jax.Array, found: jax.Array, join_type: str,
                   b_key: str) -> Table:
    """Attach matched B payload to the probe table A (vmapped per partition
    by the callers; here everything is per-partition arrays)."""
    cols = dict(a.columns)
    if join_type == "left_semi":
        valid = a.valid & found
        return Table(cols, valid)
    if join_type == "left_anti":
        valid = a.valid & ~found
        return Table(cols, valid)
    gathered, _ = gather_rows(b_cols, idx)
    for name, col in gathered.items():
        out_name = name if name not in cols else f"{name}_r"
        if join_type == "left_outer":
            col = jnp.where(found, col, jnp.zeros_like(col))
        cols[out_name] = col
    if join_type == "inner":
        valid = a.valid & found
    elif join_type == "left_outer":
        valid = a.valid
        cols[f"{b_key}_matched"] = found
    else:
        raise ValueError(f"unsupported join type {join_type}")
    return Table(cols, valid)


def _finish(a: Table, b_cols: dict, b_valid: jax.Array, res, join_type: str,
            b_key: str, vmap_b: bool) -> Table:
    in_axes = (0, 0 if vmap_b else None, 0 if vmap_b else None, 0, 0)
    fn = lambda at, bc, bv, idx, fnd: _merge_payload(  # noqa: E731
        at, bc, bv, idx, fnd, join_type, b_key)
    return jax.vmap(fn, in_axes=in_axes)(a, b_cols, b_valid, res.match_idx,
                                         res.found)


def _local_bytes(a: Table, b_rows: int, b_row_bytes: int, p: int,
                 build_replicated: bool) -> float:
    """Measured compute workload: build (p|B| or |B|) + probe (|A| + |B|)."""
    a_bytes = a.count() * a.row_bytes
    b_bytes = b_rows * b_row_bytes
    build = (p if build_replicated else 1) * b_bytes
    return float(build + a_bytes + b_bytes)


# ---------------------------------------------------------------------------


def broadcast_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                        join_type: str = "inner",
                        use_kernel: bool = False) -> tuple[Table, JoinReport]:
    """Broadcast B to every partition; radix-hash probe A's partitions."""
    p = a.num_partitions
    b_full, ex = broadcast(b)
    res = jax.vmap(
        lambda ak, av: hash_join(ak, av, b_full.column(b_key), b_full.valid,
                                 use_kernel=use_kernel),
        in_axes=(0, 0))(a.column(a_key), a.valid)
    out = _finish(a, b_full.columns, b_full.valid, res, join_type, b_key,
                  vmap_b=False)
    out.partitioned_by = a.partitioned_by
    rep = JoinReport(JoinMethod.BROADCAST_HASH, [ex],
                     _local_bytes(a, b_full.count(), b_full.row_bytes, p,
                                  build_replicated=True),
                     out.count())
    return out, rep


def shuffle_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                      join_type: str = "inner",
                      capacity_factor: float = 2.0,
                      use_kernel: bool = False) -> tuple[Table, JoinReport]:
    """Shuffle both sides by key; radix-hash join each co-partition."""
    p = a.num_partitions
    a_sh, ex_a = shuffle(a, a_key, capacity_factor)
    b_sh, ex_b = shuffle(b, b_key, capacity_factor)
    res = jax.vmap(
        lambda ak, av, bk, bv: hash_join(ak, av, bk, bv,
                                         use_kernel=use_kernel)
    )(a_sh.column(a_key), a_sh.valid, b_sh.column(b_key), b_sh.valid)
    out = _finish(a_sh, b_sh.columns, b_sh.valid, res, join_type, b_key,
                  vmap_b=True)
    out.partitioned_by = a_key
    rep = JoinReport(JoinMethod.SHUFFLE_HASH, [ex_a, ex_b],
                     _local_bytes(a_sh, b_sh.count(), b_sh.row_bytes, p,
                                  build_replicated=False),
                     out.count())
    return out, rep


def salted_shuffle_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                             join_type: str = "inner",
                             salt_r: int = 2,
                             capacity_factor: float = 2.0,
                             use_kernel: bool = False
                             ) -> tuple[Table, JoinReport]:
    """Skew-mitigating shuffle hash join: salt hot probe keys over ``salt_r``
    destinations and replicate the matching build rows once per salt, then
    radix-hash join each co-partition like the plain shuffle hash join.

    The output is NOT hash-partitioned by the join key (it is partitioned by
    (key, salt)), so downstream shuffles on the key are not elided — the
    price of flattening the straggler, and exactly what the salted cost
    model's replication surcharge pays for.
    """
    p = a.num_partitions
    a_sh, b_sh, ex_a, ex_b = salted_shuffle(a, a_key, b, b_key, salt_r,
                                            capacity_factor)
    res = jax.vmap(
        lambda ak, av, bk, bv: hash_join(ak, av, bk, bv,
                                         use_kernel=use_kernel)
    )(a_sh.column(a_key), a_sh.valid, b_sh.column(b_key), b_sh.valid)
    out = _finish(a_sh, b_sh.columns, b_sh.valid, res, join_type, b_key,
                  vmap_b=True)
    out.partitioned_by = None
    rep = JoinReport(JoinMethod.SALTED_SHUFFLE_HASH, [ex_a, ex_b],
                     _local_bytes(a_sh, b_sh.count(), b_sh.row_bytes, p,
                                  build_replicated=False),
                     out.count())
    return out, rep


def shuffle_sort_join(a: Table, b: Table, a_key: str, b_key: str,
                      join_type: str = "inner",
                      capacity_factor: float = 2.0,
                      use_kernel: bool = False) -> tuple[Table, JoinReport]:
    """Shuffle both sides by key; sort-merge join each co-partition."""
    a_sh, ex_a = shuffle(a, a_key, capacity_factor)
    b_sh, ex_b = shuffle(b, b_key, capacity_factor)
    res = jax.vmap(
        lambda ak, av, bk, bv: sort_join(ak, av, bk, bv,
                                         use_kernel_sort=use_kernel)
    )(a_sh.column(a_key), a_sh.valid, b_sh.column(b_key), b_sh.valid)
    out = _finish(a_sh, b_sh.columns, b_sh.valid, res, join_type, b_key,
                  vmap_b=True)
    out.partitioned_by = a_key
    # Sort join's measured compute adds the n log n sort passes; we report
    # the touched bytes (sort reads+writes both sides ~log passes).
    import math
    pa = max(a_sh.count() / a_sh.num_partitions, 1.0)
    pb = max(b_sh.count() / b_sh.num_partitions, 1.0)
    sort_bytes = (a_sh.count() * a_sh.row_bytes * math.log2(max(pa, 1.0) + 1)
                  + b_sh.count() * b_sh.row_bytes * math.log2(max(pb, 1.0) + 1))
    merge_bytes = (a_sh.count() * a_sh.row_bytes
                   + b_sh.count() * b_sh.row_bytes)
    rep = JoinReport(JoinMethod.SHUFFLE_SORT, [ex_a, ex_b],
                     float(sort_bytes + merge_bytes), out.count())
    return out, rep


def broadcast_nl_join(a: Table, b: Table,
                      predicate: Callable[[dict, dict], jax.Array],
                      join_type: str = "inner",
                      b_key: str = "") -> tuple[Table, JoinReport]:
    """Broadcast B; nested-loop each A partition against the replica."""
    p = a.num_partitions
    b_full, ex = broadcast(b)
    res = jax.vmap(
        lambda acols, av: nested_loop_join(acols, av, b_full.columns,
                                           b_full.valid, predicate),
        in_axes=(0, 0))(a.columns, a.valid)
    out = _finish(a, b_full.columns, b_full.valid, res, join_type, b_key,
                  vmap_b=False)
    nl_bytes = float(a.count() * a.row_bytes
                     + a.count() * b_full.count() * b_full.row_bytes / 1.0)
    rep = JoinReport(JoinMethod.BROADCAST_NL, [ex], nl_bytes, out.count())
    return out, rep


def cartesian_join(a: Table, b: Table,
                   predicate: Callable[[dict, dict], jax.Array],
                   join_type: str = "inner",
                   b_key: str = "") -> tuple[Table, JoinReport]:
    """Shuffle-NL: co-shuffle by a synthetic round-robin key so every
    (A-partition, B-partition) pair meets once; NL within pairs.

    Implementation mirrors Spark's CartesianProduct for *selective*
    predicates with first-match semantics (the engine's NL joins resolve at
    most one build match per probe row — sufficient for the non-equi
    predicates in the query suite).
    """
    p = a.num_partitions
    b_full, ex = broadcast(b)  # logically a shuffle-replication; see report
    res = jax.vmap(
        lambda acols, av: nested_loop_join(acols, av, b_full.columns,
                                           b_full.valid, predicate),
        in_axes=(0, 0))(a.columns, a.valid)
    out = _finish(a, b_full.columns, b_full.valid, res, join_type, b_key,
                  vmap_b=False)
    # Cartesian's exchange is a shuffle of both sides (Eq. 5): measure it so.
    rows_b = b_full.count()
    shuffle_like = ExchangeReport(
        "shuffle",
        network_bytes=(p - 1) / p * (a.count() * a.row_bytes
                                     + rows_b * b_full.row_bytes),
        local_bytes=(a.count() * a.row_bytes + rows_b * b_full.row_bytes) / p)
    nl_bytes = float(a.count() * a.row_bytes
                     + a.count() / p * rows_b * b_full.row_bytes)
    rep = JoinReport(JoinMethod.CARTESIAN, [shuffle_like], nl_bytes,
                     out.count())
    return out, rep


# ---------------------------------------------------------------------------
# Hypercube multi-way shuffle join (cyclic join graphs).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HypercubeLink:
    """One equi-edge of the local multi-way probe: look up ``probe_col`` of
    the accumulated probe row (a relation-0 column, or a column gathered
    from an earlier link's build) in ``build_col`` of relation ``build``."""

    build: int       # index into the relation list (>= 1)
    probe_col: str   # key column available on the accumulated probe row
    build_col: str   # unique key column of the build relation


@dataclasses.dataclass(frozen=True)
class HypercubeSpec:
    """Physical plan of one hypercube multi-way join.

    ``dims`` is the cube shape (prod = p, one axis per join variable);
    ``axis_keys[i]`` lists relation i's owned (axis, key column) pairs —
    it is hash-partitioned on those and replicated along the rest.
    ``links`` are resolved in order; ``checks`` are the closing column
    equalities evaluated on the fully joined row (the cyclic edges the
    binary engine would have to re-shuffle for).
    """

    dims: tuple
    axis_keys: tuple
    links: tuple
    checks: tuple


def _sanitized(t: Table, col: str, sentinel: int) -> jax.Array:
    return jnp.where(t.valid, t.column(col), sentinel).astype(jnp.int32)


def hypercube_multiway_join(tables: list, spec: HypercubeSpec,
                            capacity_factor: float = 2.0,
                            use_kernel: bool = False
                            ) -> tuple[Table, JoinReport]:
    """Hypercube multi-way shuffle join: one replication exchange per
    relation, then a single local probe chain per partition — no binary
    intermediates ever cross the network.

    Every relation is cube-partitioned by ``hypercube_shuffle``; because
    each output tuple's variable assignment lands on exactly one cube cell
    and the build key columns are globally unique, a chain of first-match
    local probes plus the closing ``checks`` produces each result row
    exactly once (no cross-partition dedup needed). The probe relation is
    index 0; its rows (with gathered build payloads) form the output.
    """
    shards: list[Table] = []
    exs: list[ExchangeReport] = []
    for t, ak in zip(tables, spec.axis_keys):
        sh, ex = hypercube_shuffle(t, spec.dims, tuple(ak), capacity_factor)
        shards.append(sh)
        exs.append(ex)

    probe = shards[0]
    cols = dict(probe.columns)
    valid = probe.valid

    fused = (use_kernel and len(spec.links) == 2
             and all(lk.probe_col in probe.columns for lk in spec.links))
    if fused:
        # 3-way case on the TPU path: both probe key columns stream through
        # one fused Pallas kernel (dense in-partition match; build keys are
        # unique so first-match is exact).
        l1, l2 = spec.links
        b1, b2 = shards[l1.build], shards[l2.build]
        idx1, idx2 = jax.vmap(
            lambda a1, a2, bk, ck: kops.probe3(a1, a2, bk, ck))(
            jnp.where(valid, cols[l1.probe_col], A_SENTINEL).astype(jnp.int32),
            jnp.where(valid, cols[l2.probe_col], A_SENTINEL).astype(jnp.int32),
            _sanitized(b1, l1.build_col, B_SENTINEL),
            _sanitized(b2, l2.build_col, B_SENTINEL))
        for b, idx in ((b1, idx1), (b2, idx2)):
            gathered = jax.vmap(lambda bc, ix: gather_rows(bc, ix)[0])(
                b.columns, jnp.maximum(idx, 0))
            for name, col in gathered.items():
                if name in cols:
                    raise ValueError(f"duplicate column {name!r} in "
                                     "multi-way join")
                cols[name] = col
            valid = valid & (idx >= 0)
    else:
        for lk in spec.links:
            b = shards[lk.build]
            res = jax.vmap(
                lambda ak_, av, bk, bv: hash_join(ak_, av, bk, bv,
                                                  use_kernel=use_kernel)
            )(cols[lk.probe_col], valid, b.column(lk.build_col), b.valid)
            gathered = jax.vmap(lambda bc, ix: gather_rows(bc, ix)[0])(
                b.columns, jnp.maximum(res.match_idx, 0))
            for name, col in gathered.items():
                if name in cols:
                    raise ValueError(f"duplicate column {name!r} in "
                                     "multi-way join")
                cols[name] = col
            valid = valid & res.found

    for c1, c2 in spec.checks:
        valid = valid & (cols[c1] == cols[c2])

    out = Table(cols, valid)
    out.partitioned_by = None
    # Measured local workload mirrors the binary methods' convention: one
    # probe pass over the (replicated) probe side, build + probe touch of
    # each (replicated) build side.
    local = float(probe.count() * probe.row_bytes
                  + sum(2.0 * s.count() * s.row_bytes for s in shards[1:]))
    rep = JoinReport(JoinMethod.HYPERCUBE_SHUFFLE, exs, local, out.count())
    return out, rep


# ---------------------------------------------------------------------------

EQUI_METHODS = {
    JoinMethod.BROADCAST_HASH: broadcast_hash_join,
    JoinMethod.SHUFFLE_HASH: shuffle_hash_join,
    JoinMethod.SALTED_SHUFFLE_HASH: salted_shuffle_hash_join,
    JoinMethod.SHUFFLE_SORT: shuffle_sort_join,
}


def run_equi_join(method: JoinMethod, a: Table, b: Table, a_key: str,
                  b_key: str, join_type: str = "inner",
                  use_kernel: bool = False,
                  capacity_factor: float = 2.0,
                  salt_r: int = 2) -> tuple[Table, JoinReport]:
    """Dispatch an equi-join to the selected physical method."""
    if method in (JoinMethod.BROADCAST_NL, JoinMethod.CARTESIAN):
        pred = lambda ac, bc: ac[a_key] == bc[b_key]  # noqa: E731
        fn = (broadcast_nl_join if method is JoinMethod.BROADCAST_NL
              else cartesian_join)
        return fn(a, b, pred, join_type, b_key)
    if method is JoinMethod.BROADCAST_HASH:
        return broadcast_hash_join(a, b, a_key, b_key, join_type, use_kernel)
    if method is JoinMethod.SHUFFLE_HASH:
        return shuffle_hash_join(a, b, a_key, b_key, join_type,
                                 capacity_factor, use_kernel)
    if method is JoinMethod.SALTED_SHUFFLE_HASH:
        # salt_r < 2 (e.g. a bare hint) is clamped inside salted_shuffle.
        return salted_shuffle_hash_join(a, b, a_key, b_key, join_type,
                                        salt_r, capacity_factor, use_kernel)
    if method is JoinMethod.SHUFFLE_SORT:
        return shuffle_sort_join(a, b, a_key, b_key, join_type,
                                 capacity_factor, use_kernel)
    raise ValueError(f"unknown method {method}")
