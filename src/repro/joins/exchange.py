"""Data-exchange phase: broadcast and shuffle (paper §2.1.1).

Global-view implementations on stacked ``(p, cap)`` tables. The same
per-partition send-side logic runs unchanged inside ``shard_map`` in
``distributed.py``, with the axis transpose replaced by ``lax.all_to_all``
and the replication by ``lax.all_gather`` — the global-view functions are
the single-device-executable semantic spec of the collectives.

Every exchange returns an ``ExchangeReport`` whose byte counts are *measured*
(from live rows), so benchmarks can compare the paper's modeled workloads
(Eqs. 1, 5) against ground truth.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .slots import (SHUFFLE_SEED, gather_rows, hash32, pair_capacity,
                    slot_scatter)
from .table import Table, concat_partitions


@dataclasses.dataclass
class ExchangeReport:
    """Measured workload of one exchange (host-side ints/floats)."""

    kind: str                  # "broadcast" | "shuffle"
    network_bytes: float       # bytes that crossed partition boundaries
    local_bytes: float         # bytes that stayed partition-local
    overflow_rows: int = 0     # rows dropped by capacity (skew signal)
    elided: bool = False       # exchange skipped (already co-partitioned)


def _dest_partition(key: jax.Array, p: int) -> jax.Array:
    return (hash32(key, SHUFFLE_SEED) % jnp.uint32(p)).astype(jnp.int32)


def broadcast(table: Table) -> tuple[Table, ExchangeReport]:
    """Broadcast exchange: every task receives a full replica of ``table``.

    Global view: returns the concatenated unstacked table (each local join
    task consumes it with in_axes=None == a replica). Network workload is
    Eq. 1's (p-1)|B|: each of p tasks fetches the (p-1)/p it doesn't hold.
    """
    if not table.stacked:
        raise ValueError("broadcast expects a stacked table")
    p = table.num_partitions
    full = concat_partitions(table)
    rows = full.count()
    bytes_all = rows * full.row_bytes
    report = ExchangeReport("broadcast",
                            network_bytes=(p - 1) * bytes_all,
                            local_bytes=bytes_all)
    return full, report


def shuffle(table: Table, key: str, capacity_factor: float = 2.0
            ) -> tuple[Table, ExchangeReport]:
    """Shuffle exchange: repartition rows by hash(key) across p partitions.

    Slotted all-to-all: each source partition packs rows into per-destination
    slots of fixed capacity; the (p_src, p_dst, cap) buffer is exchanged
    (global view: a transpose) and flattened to (p_dst, p_src*cap).

    Network workload is *measured*: bytes of rows whose destination differs
    from their source (Eq. 5 models this as ((p-1)/p)(|A|+|B|)).
    """
    if not table.stacked:
        raise ValueError("shuffle expects a stacked table")
    if table.partitioned_by == key:
        # Already hash-partitioned on this key: the exchange is a no-op
        # (paper §3.7: all rows pre-placed -> C_shuffle = 0).
        return table, ExchangeReport("shuffle", 0.0, 0.0, elided=True)
    p, cap = table.num_partitions, table.capacity
    pair_cap = pair_capacity(cap, p, capacity_factor)

    dest = _dest_partition(table.column(key), p)  # (p, cap)
    scat = jax.vmap(lambda d, v: slot_scatter(d, v, p, pair_cap))(
        dest, table.valid)  # idx: (p_src, p_dst, pair_cap)

    send_cols, send_valid = jax.vmap(gather_rows)(table.columns, scat.idx)
    # all_to_all == axis transpose in the global view.
    recv_cols = {n: jnp.swapaxes(c, 0, 1).reshape(p, p * pair_cap)
                 for n, c in send_cols.items()}
    recv_valid = jnp.swapaxes(send_valid, 0, 1).reshape(p, p * pair_cap)
    out = Table(recv_cols, recv_valid, partitioned_by=key)

    # Measured workload: rows that actually crossed partitions.
    src_ids = jnp.arange(p, dtype=jnp.int32)[:, None]
    moved = jnp.sum(table.valid & (dest != src_ids))
    stayed = jnp.sum(table.valid & (dest == src_ids))
    rb = table.row_bytes
    report = ExchangeReport(
        "shuffle",
        network_bytes=float(moved) * rb,
        local_bytes=float(stayed) * rb,
        overflow_rows=int(jnp.sum(scat.overflow)),
    )
    return out, report
