"""Data-exchange phase: broadcast, shuffle and salted shuffle (paper §2.1.1
plus the skew-aware extension).

Global-view implementations on stacked ``(p, cap)`` tables. The same
per-partition send-side logic runs unchanged inside ``shard_map`` in
``distributed.py``, with the axis transpose replaced by ``lax.all_to_all``
and the replication by ``lax.all_gather`` — the global-view functions are
the single-device-executable semantic spec of the collectives.

Every exchange returns an ``ExchangeReport`` whose byte counts are *measured*
(from live rows), so benchmarks can compare the paper's modeled workloads
(Eqs. 1, 5) against ground truth. ``straggler_bytes`` — the load of the
hottest destination partition, counted with the ``partition_hist`` kernel —
is the skew signal: under Zipf keys it, not the mean, bounds wall-clock.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels.partition_hist import partition_hist
from .slots import (SHUFFLE_SEED, gather_rows, hash32, pair_capacity,
                    slot_scatter)
from .table import Table, concat_partitions

#: Decorrelated from SHUFFLE_SEED/BUCKET_SEED: salt hashing must not undo
#: the destination hash (murmur3 finalizer constant).
SALT_SEED = jnp.uint32(0x27D4EB2F)

#: Hot-key detection granularity: nf = HOT_FINE_MULT * p fine hash buckets.
HOT_FINE_MULT = 16

#: A fine bucket is *hot* when its probe mass alone exceeds this share of a
#: partition's fair share (total/p) — routing it unsalted would measurably
#: tilt one partition.
HOT_PARTITION_SHARE = 0.25


@dataclasses.dataclass
class ExchangeReport:
    """Measured workload of one exchange (host-side ints/floats)."""

    kind: str   # "broadcast" | "shuffle" | "salted_shuffle" | "hypercube"
    network_bytes: float       # bytes that crossed partition boundaries
    local_bytes: float         # bytes that stayed partition-local
    overflow_rows: int = 0     # rows dropped by capacity (skew signal)
    elided: bool = False       # exchange skipped (already co-partitioned)
    straggler_bytes: float = 0.0  # bytes landing on the hottest partition


def _dest_partition(key: jax.Array, p: int) -> jax.Array:
    return (hash32(key, SHUFFLE_SEED) % jnp.uint32(p)).astype(jnp.int32)


def _fine_bucket(key: jax.Array, nf: int) -> jax.Array:
    """Fine hash bucket of a key. With nf a multiple of p, h % nf refines
    h % p exactly: every fine bucket maps wholly into one partition."""
    return (hash32(key, SHUFFLE_SEED) % jnp.uint32(nf)).astype(jnp.int32)


def _salted_dest(key: jax.Array, salt: jax.Array, p: int) -> jax.Array:
    """Destination of a (key, salt) pair. Deterministic in both, and salt 0
    reproduces the plain shuffle destination, so cold (unsalted) rows land
    exactly where ``shuffle`` would send them."""
    h = hash32(key, SHUFFLE_SEED) + hash32(salt, SALT_SEED)
    return (h % jnp.uint32(p)).astype(jnp.int32)


def broadcast(table: Table) -> tuple[Table, ExchangeReport]:
    """Broadcast exchange: every task receives a full replica of ``table``.

    Global view: returns the concatenated unstacked table (each local join
    task consumes it with in_axes=None == a replica). Network workload is
    Eq. 1's (p-1)|B|: each of p tasks fetches the (p-1)/p it doesn't hold.
    Broadcast is skew-invariant: every partition ends up with identical
    load, so the straggler equals the replica size.
    """
    if not table.stacked:
        raise ValueError("broadcast expects a stacked table")
    p = table.num_partitions
    full = concat_partitions(table)
    rows = full.count()
    bytes_all = rows * full.row_bytes
    report = ExchangeReport("broadcast",
                            network_bytes=(p - 1) * bytes_all,
                            local_bytes=bytes_all,
                            straggler_bytes=float(bytes_all))
    return full, report


def _exchange_by_dest(table: Table, dest: jax.Array, pair_cap: int,
                      partitioned_by: str | None, kind: str = "shuffle"
                      ) -> tuple[Table, ExchangeReport]:
    """Slotted all-to-all by explicit per-row destinations.

    Each source partition packs rows into per-destination slots of fixed
    capacity; the (p_src, p_dst, cap) buffer is exchanged (global view: a
    transpose) and flattened to (p_dst, p_src*cap). Network workload is
    *measured*: bytes of rows whose destination differs from their source;
    the straggler is the hottest destination's landed bytes (partition_hist
    bincount of the destination ids).
    """
    p = table.num_partitions
    scat = jax.vmap(lambda d, v: slot_scatter(d, v, p, pair_cap))(
        dest, table.valid)  # idx: (p_src, p_dst, pair_cap)

    send_cols, send_valid = jax.vmap(gather_rows)(table.columns, scat.idx)
    # all_to_all == axis transpose in the global view.
    recv_cols = {n: jnp.swapaxes(c, 0, 1).reshape(p, p * pair_cap)
                 for n, c in send_cols.items()}
    recv_valid = jnp.swapaxes(send_valid, 0, 1).reshape(p, p * pair_cap)
    out = Table(recv_cols, recv_valid, partitioned_by=partitioned_by)

    # Measured workload: rows that actually crossed partitions, plus the
    # per-destination load histogram for straggler accounting.
    src_ids = jnp.arange(p, dtype=jnp.int32)[:, None]
    moved = jnp.sum(table.valid & (dest != src_ids))
    stayed = jnp.sum(table.valid & (dest == src_ids))
    loads = partition_hist(
        jnp.where(table.valid, dest, -1).reshape(-1), nd=p)
    rb = table.row_bytes
    report = ExchangeReport(
        kind,
        network_bytes=float(moved) * rb,
        local_bytes=float(stayed) * rb,
        overflow_rows=int(jnp.sum(scat.overflow)),
        straggler_bytes=float(jnp.max(loads)) * rb,
    )
    return out, report


def shuffle(table: Table, key: str, capacity_factor: float = 2.0
            ) -> tuple[Table, ExchangeReport]:
    """Shuffle exchange: repartition rows by hash(key) across p partitions.

    Network workload is *measured*: bytes of rows whose destination differs
    from their source (Eq. 5 models this as ((p-1)/p)(|A|+|B|)).
    """
    if not table.stacked:
        raise ValueError("shuffle expects a stacked table")
    if table.partitioned_by == key:
        # Already hash-partitioned on this key: the exchange is a no-op
        # (paper §3.7: all rows pre-placed -> C_shuffle = 0).
        return table, ExchangeReport("shuffle", 0.0, 0.0, elided=True)
    p, cap = table.num_partitions, table.capacity
    pair_cap = pair_capacity(cap, p, capacity_factor)
    dest = _dest_partition(table.column(key), p)  # (p, cap)
    return _exchange_by_dest(table, dest, pair_cap, key)


# ---------------------------------------------------------------------------
# Hypercube replication exchange (multi-way joins on cyclic join graphs).
# ---------------------------------------------------------------------------


def hypercube_shuffle(table: Table, dims: tuple[int, ...],
                      axis_keys: tuple[tuple[int, str], ...],
                      capacity_factor: float = 2.0
                      ) -> tuple[Table, ExchangeReport]:
    """Hypercube exchange: the p partitions are a cube of shape ``dims``
    (one axis per join variable, prod(dims) = p, C-order flattening) and
    ``axis_keys`` lists the (axis, key column) pairs this relation *owns*.

    Each row is hash-partitioned on its owned axes' coordinates
    (``hash(key) % dims[axis]``, the same hash both sides of a shared
    variable use) and **replicated** along every axis the relation does not
    own — one copy per combination of free-axis coordinates, a factor
    f = p / prod(owned shares). Any tuple of rows agreeing on all shared
    variables therefore meets on exactly one partition, which is what lets
    the local multi-way probe evaluate a cyclic core without binary
    intermediates. Network workload is *measured* over all f copies —
    ground truth for the modeled replication volume |R| * (p / p_i).

    Degenerate cases fall out naturally: at p = 1 (all shares 1) nothing
    moves, and a flat mesh (one axis of share p, everything else share 1)
    reproduces a plain key shuffle for the axis owner.
    """
    if not table.stacked:
        raise ValueError("hypercube_shuffle expects a stacked table")
    p = 1
    for d in dims:
        p *= d
    if p != table.num_partitions:
        raise ValueError(f"cube {dims} has {p} cells but table has "
                         f"{table.num_partitions} partitions")
    owned = {ax for ax, _ in axis_keys}
    if any(ax < 0 or ax >= len(dims) for ax in owned):
        raise ValueError(f"axis out of range for cube {dims}: {axis_keys}")
    free = [ax for ax in range(len(dims)) if ax not in owned]
    f = 1
    for ax in free:
        f *= dims[ax]
    # C-order flat index: stride of axis j is prod(dims[j+1:]).
    strides = [1] * len(dims)
    for j in range(len(dims) - 2, -1, -1):
        strides[j] = strides[j + 1] * dims[j + 1]
    cap = table.capacity
    wide_cols = {n: jnp.tile(c, (1, f)) for n, c in table.columns.items()}
    wide_valid = jnp.tile(table.valid, (1, f))
    dest = jnp.zeros(wide_valid.shape, jnp.int32)
    for ax, col in axis_keys:
        coord = (hash32(wide_cols[col], SHUFFLE_SEED)
                 % jnp.uint32(dims[ax])).astype(jnp.int32)
        dest = dest + coord * strides[ax]
    # Replica r of a row takes the r-th combination of free-axis
    # coordinates (mixed radix over the free shares).
    rep = jnp.repeat(jnp.arange(f, dtype=jnp.int32), cap)[None, :]
    rem = jnp.broadcast_to(rep, wide_valid.shape)
    for ax in free:
        dest = dest + (rem % dims[ax]) * strides[ax]
        rem = rem // dims[ax]
    wide = Table(wide_cols, wide_valid)
    pair_cap = pair_capacity(cap * f, p, capacity_factor)
    return _exchange_by_dest(wide, dest, pair_cap, None, kind="hypercube")


# ---------------------------------------------------------------------------
# Skew mitigation: salted shuffle (hot-key spreading + build replication).
# ---------------------------------------------------------------------------


def hot_fine_buckets(table: Table, key: str, nf: int, p: int,
                     hot_share: float = HOT_PARTITION_SHARE
                     ) -> tuple[jax.Array, jax.Array]:
    """Hot-bucket mask of ``table``'s key column.

    Bucket mass comes from the ``partition_hist`` kernel over nf fine hash
    buckets; a bucket is hot when its mass alone exceeds ``hot_share`` of a
    partition's fair share (total/p). On uniform keys no bucket comes close
    (fine means are total/nf = total/(16p)), so nothing is salted.

    Returns ``(hot, fine)``: the boolean (nf,) mask and the key column's own
    fine-bucket ids (so the caller need not re-hash the hot table).
    """
    fine = _fine_bucket(table.column(key), nf)
    counts = partition_hist(jnp.where(table.valid, fine, -1).reshape(-1),
                            nd=nf)
    threshold = hot_share * jnp.sum(counts) / p
    return counts > threshold, fine


def salted_shuffle(a: Table, a_key: str, b: Table, b_key: str, r: int,
                   capacity_factor: float = 2.0,
                   fine_mult: int = HOT_FINE_MULT,
                   hot_share: float = HOT_PARTITION_SHARE
                   ) -> tuple[Table, Table, ExchangeReport, ExchangeReport]:
    """Skew-mitigating co-shuffle of probe side A and build side B.

    Hot keys are detected at fine-hash-bucket granularity from A's key
    histogram. Hot probe rows get a deterministic salt in [0, r) — spreading
    each hot key over r destinations — while build rows whose key falls in a
    hot bucket are replicated once per salt value, so every destination a
    salted probe row can reach holds the matching build row. Cold rows keep
    salt 0, whose destination equals the plain shuffle destination.

    Hotness is a pure function of the key (via A's histogram) applied
    identically on both sides: a probe row is salted iff its build match is
    replicated, which is exactly the agreement the join needs.
    """
    if not (a.stacked and b.stacked):
        raise ValueError("salted_shuffle expects stacked tables")
    p = a.num_partitions
    r = max(2, int(r))
    nf = fine_mult * p
    hot, a_fine = hot_fine_buckets(a, a_key, nf, p, hot_share)

    # Probe: deterministic per-row salt for hot rows (round-robin within the
    # source partition, offset by the partition id to decorrelate sources).
    ak = a.column(a_key)
    a_hot = jnp.take(hot, a_fine)
    row = jax.lax.broadcasted_iota(jnp.int32, ak.shape, 1)
    src = jax.lax.broadcasted_iota(jnp.int32, ak.shape, 0)
    salt_a = jnp.where(a_hot, (row + src) % r, 0).astype(jnp.int32)
    a_sh, ex_a = _exchange_by_dest(
        a, _salted_dest(ak, salt_a, p),
        pair_capacity(a.capacity, p, capacity_factor),
        None, kind="salted_shuffle")

    # Build: replicate along the capacity axis; replica j of a row is live
    # iff j == 0 (the plain copy) or the row's key is hot. Replicas of the
    # same key landing on one partition leave duplicate build keys there —
    # harmless for FK->PK joins (identical payload, first match wins).
    bk = b.column(b_key)
    b_hot = jnp.take(hot, _fine_bucket(bk, nf))
    cap_b = b.capacity
    salt_b = jnp.repeat(jnp.arange(r, dtype=jnp.int32), cap_b)[None, :]
    wide_valid = (jnp.tile(b.valid, (1, r))
                  & ((salt_b == 0) | jnp.tile(b_hot, (1, r))))
    b_wide = Table({n: jnp.tile(c, (1, r)) for n, c in b.columns.items()},
                   wide_valid)
    dest_b = _salted_dest(jnp.tile(bk, (1, r)),
                          jnp.broadcast_to(salt_b, wide_valid.shape), p)
    b_sh, ex_b = _exchange_by_dest(
        b_wide, dest_b, pair_capacity(cap_b, p, capacity_factor),
        None, kind="salted_shuffle")
    return a_sh, b_sh, ex_a, ex_b


def key_skew(table: Table, key: str, p: int | None = None,
             floor: float = 1.1) -> float:
    """Measured straggler factor of hash-partitioning ``table`` by ``key``:
    s = max_partition_load / mean_partition_load over p destinations
    (partition_hist bincount of the would-be shuffle destinations).

    Values below ``floor`` are statistical fluctuation of uniform hashing
    and snap to 1.0, so skew-aware selection on uniform data reproduces the
    paper's Algorithm 1 decisions exactly.
    """
    p = p or table.num_partitions
    dest = jnp.where(table.valid, _dest_partition(table.column(key), p), -1)
    counts = partition_hist(dest.reshape(-1), nd=p)
    total = int(jnp.sum(counts))
    if total == 0:
        return 1.0
    s = float(jnp.max(counts)) * p / total
    return s if s >= floor else 1.0
