"""Slotted scatter — the static-shape primitive behind shuffle and radix
bucketing.

Given per-row destination ids, place each valid row into a fixed-capacity
slot array ``(nd, cap)`` of *source row indices* (-1 = empty). Rows beyond a
destination's capacity are dropped and counted as overflow — the engine's
skew signal (DESIGN.md: capacity-factor + hot-key detection).

Pure per-partition function: used under vmap (global view) and inside
shard_map (distributed executor) unchanged.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Distinct multiplicative mix seeds: shuffle destinations and radix buckets
# must be decorrelated or post-shuffle partitions would collapse into a few
# buckets (murmur3 finalizer constants).
SHUFFLE_SEED = jnp.uint32(0x9E3779B1)
BUCKET_SEED = jnp.uint32(0x85EBCA6B)


def pair_capacity(cap: int, nd: int, factor: float = 2.0) -> int:
    """Slot capacity per (source, destination) pair.

    Mean occupancy is cap/nd; the binomial tail needs ~sqrt slack for small
    partitions, on top of the user's skew ``factor`` (paper §3.7 maps skew
    handling to capacity sizing).
    """
    mean = cap / nd
    return max(8, int(mean * factor + 4.0 * mean ** 0.5 + 8))


def hash32(keys: jax.Array, seed: jax.Array) -> jax.Array:
    """Murmur-style avalanche of int32 keys -> uint32 hashes."""
    h = keys.astype(jnp.uint32) * seed
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 13)
    return h


class SlotScatter(NamedTuple):
    idx: jax.Array       # (nd, cap) int32 source row index, -1 = empty
    overflow: jax.Array  # () int32 number of dropped valid rows


def slot_scatter(dest: jax.Array, valid: jax.Array, nd: int, cap: int
                 ) -> SlotScatter:
    """Group rows by destination into fixed slots.

    dest: (n,) int32 in [0, nd); valid: (n,) bool.
    """
    n = dest.shape[0]
    d = jnp.where(valid, dest, nd).astype(jnp.int32)  # invalid -> virtual bin
    order = jnp.argsort(d, stable=True)               # rows grouped by dest
    d_sorted = d[order]
    starts = jnp.searchsorted(d_sorted, jnp.arange(nd + 1, dtype=jnp.int32))
    pos = jnp.arange(n, dtype=jnp.int32) - starts[d_sorted]
    keep = (d_sorted < nd) & (pos < cap)
    flat = jnp.where(keep, d_sorted * cap + pos, nd * cap)  # OOB -> dropped
    out = jnp.full((nd * cap,), -1, jnp.int32)
    out = out.at[flat].set(order.astype(jnp.int32), mode="drop")
    overflow = jnp.sum((d_sorted < nd) & (pos >= cap)).astype(jnp.int32)
    return SlotScatter(out.reshape(nd, cap), overflow)


def gather_rows(columns: dict, idx: jax.Array):
    """Gather rows by (possibly -1) source indices; returns (columns, valid)."""
    safe = jnp.maximum(idx, 0)
    cols = {n: jnp.take(c, safe, axis=0) for n, c in columns.items()}
    return cols, idx >= 0
