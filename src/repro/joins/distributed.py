"""Distributed execution of the join methods under ``jax.shard_map``.

The global-view functions in ``methods.py`` are the semantic spec; here the
partition axis is a real mesh axis ``"p"`` and the exchanges are actual
collectives:

    broadcast  ->  jax.lax.all_gather   (paper's broadcast, Eq. 1)
    shuffle    ->  jax.lax.all_to_all   (paper's shuffle,   Eq. 5)

The per-partition compute (slot packing, radix hash join, sort join) is the
*same code* as the global view — only the exchange primitive differs. On the
CPU CI container this runs on ``--xla_force_host_platform_device_count``
placeholder devices (see tests/test_distributed_join.py); on a real cluster
the identical program spans pods.

Every runtime-filter kind also gets its **distributed build** here, one
per reducer:

    dist_bloom_build      partial bloom arrays, OR-merged      (bloom)
    dist_zone_map_build   per-device (min, max), min/max merge (zone_map)
    dist_key_set_build    per-device distinct keys, all_gather
                          + merge-dedupe                       (semi_join)

All three share one **distributed-equivalence contract**: the distributed
build's result is bit-/value-identical to the corresponding global-view
build (``kernels.bloom.bloom_build``, ``kernels.zone_map.key_range``,
``core.psts.key_set``) over the concatenated column, at *any* device
count — because each merge operator (bitwise OR, elementwise min/max,
sorted set-union) is associative, commutative, and neutral on empty
partitions, the result cannot depend on how rows land on devices.
``tests/test_distributed_filters.py`` pins the contract at device counts
{1, 8}. The cost model charges each build its actual merge shape
(``filter_reduce_cost(kind=...)``): a ceil(log2 p) reduce tree for the
constant-size bloom/zone-map payloads, the (p-1)·m/8 all_gather volume
for the semi-join key lists, whose disjoint partials cannot be compressed
mid-tree.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.psts import key_set
from ..kernels.bloom import _positions
from ..kernels.zone_map import _HI_IDENT, _LO_IDENT, merge_ranges
from .local_join import hash_join, sort_join
from .methods import HypercubeSpec
from .slots import (SHUFFLE_SEED, gather_rows, hash32, pair_capacity,
                    slot_scatter)
from .table import Table

AXIS = "p"

# jax.shard_map became a top-level API only after 0.4.x; fall back to the
# experimental home so the distributed tier runs on the pinned toolchain.
try:
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map


def make_join_mesh(p: int) -> Mesh:
    """1-D mesh over the join parallelism p."""
    from ..launch.mesh import _axis_type_kwargs
    return jax.make_mesh((p,), (AXIS,), **_axis_type_kwargs(1))


def cube_axis_names(n_axes: int) -> tuple[str, ...]:
    """Axis names of the hypercube mesh (one axis per join variable)."""
    return tuple(f"hc{i}" for i in range(n_axes))


def make_cube_mesh(dims: tuple[int, ...]) -> Mesh:
    """Multi-axis mesh for the hypercube multi-way shuffle: the p devices
    arranged as a cube of shape ``dims`` (C-order, matching the global-view
    ``hypercube_shuffle``'s flat cell index). A flat mesh is the degenerate
    cube ``(p, 1, ..., 1)`` — same devices, same program, share-1 axes make
    their collectives identities."""
    from ..launch.mesh import _axis_type_kwargs
    return jax.make_mesh(tuple(dims), cube_axis_names(len(dims)),
                         **_axis_type_kwargs(len(dims)))


def place_cube(table: Table, mesh: Mesh) -> Table:
    """Place a stacked table with its partition axis sharded jointly over
    all cube axes (partition i on cube cell i in C-order)."""
    sh = NamedSharding(mesh, P(mesh.axis_names))
    cols = {n: jax.device_put(c, sh) for n, c in table.columns.items()}
    return Table(cols, jax.device_put(table.valid, sh))


def place(table: Table, mesh: Mesh) -> Table:
    """Place a stacked table so partition i lives on device i."""
    sh = NamedSharding(mesh, P(AXIS))
    cols = {n: jax.device_put(c, sh) for n, c in table.columns.items()}
    return Table(cols, jax.device_put(table.valid, sh))


# -- per-shard exchange primitives (run inside shard_map; local leading axis
#    is 1 because each device owns exactly one partition) -------------------

def _local_shuffle(cols: Dict[str, jax.Array], valid: jax.Array, key: str,
                   p: int, pair_cap: int):
    """Pack rows into per-destination slots and all_to_all them."""
    dest = (hash32(cols[key], SHUFFLE_SEED) % jnp.uint32(p)).astype(jnp.int32)
    scat = slot_scatter(dest, valid, p, pair_cap)      # idx: (p, pair_cap)
    send_cols, send_valid = gather_rows(cols, scat.idx)
    recv_cols = {
        n: jax.lax.all_to_all(c, AXIS, split_axis=0, concat_axis=0
                              ).reshape(p * pair_cap)
        for n, c in send_cols.items()}
    recv_valid = jax.lax.all_to_all(send_valid, AXIS, split_axis=0,
                                    concat_axis=0).reshape(p * pair_cap)
    return recv_cols, recv_valid


def _local_broadcast(cols: Dict[str, jax.Array], valid: jax.Array, p: int):
    """all_gather a full replica of the table onto every device."""
    full_cols = {n: jax.lax.all_gather(c, AXIS).reshape(-1)
                 for n, c in cols.items()}
    full_valid = jax.lax.all_gather(valid, AXIS).reshape(-1)
    return full_cols, full_valid


# -- distributed join methods ------------------------------------------------

def _attach(a_cols, a_valid, b_cols, res):
    out = dict(a_cols)
    gathered, _ = gather_rows(b_cols, res.match_idx)
    for n, c in gathered.items():
        out[n if n not in out else f"{n}_r"] = c
    return out, a_valid & res.found


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh",
                                              "capacity_factor"))
def dist_shuffle_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                           mesh: Mesh, capacity_factor: float = 2.0) -> Table:
    p = mesh.shape[AXIS]
    cap_a = pair_capacity(a.capacity, p, capacity_factor)
    cap_b = pair_capacity(b.capacity, p, capacity_factor)

    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        ra_cols, ra_valid = _local_shuffle(a_cols, a_valid[0], a_key, p, cap_a)
        rb_cols, rb_valid = _local_shuffle(b_cols, b_valid[0], b_key, p, cap_b)
        res = hash_join(ra_cols[a_key], ra_valid, rb_cols[b_key], rb_valid)
        out_cols, out_valid = _attach(ra_cols, ra_valid, rb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = _shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh",
                                              "capacity_factor"))
def dist_shuffle_sort_join(a: Table, b: Table, a_key: str, b_key: str,
                           mesh: Mesh, capacity_factor: float = 2.0) -> Table:
    p = mesh.shape[AXIS]
    cap_a = pair_capacity(a.capacity, p, capacity_factor)
    cap_b = pair_capacity(b.capacity, p, capacity_factor)

    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        ra_cols, ra_valid = _local_shuffle(a_cols, a_valid[0], a_key, p, cap_a)
        rb_cols, rb_valid = _local_shuffle(b_cols, b_valid[0], b_key, p, cap_b)
        res = sort_join(ra_cols[a_key], ra_valid, rb_cols[b_key], rb_valid)
        out_cols, out_valid = _attach(ra_cols, ra_valid, rb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = _shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)


@functools.partial(jax.jit, static_argnames=("spec", "mesh",
                                             "capacity_factor"))
def dist_hypercube_join(tables: tuple, spec: HypercubeSpec, mesh: Mesh,
                        capacity_factor: float = 2.0) -> Table:
    """Hypercube multi-way join under ``shard_map`` over the multi-axis
    cube mesh — the distributed twin of ``methods.hypercube_multiway_join``.

    Per relation the cube exchange is compositional in the mesh axes:
    one ``all_to_all`` along each *owned* axis routes rows to their
    hash coordinate, then one ``all_gather`` along each *free* axis
    replicates the shard across the slice the relation does not own.
    After the exchange every cube cell holds exactly the global view's
    cell content, so the same local probe chain + closing checks run
    unchanged. Tables must be placed with ``place_cube``.
    """
    names = mesh.axis_names
    dims = tuple(mesh.shape[n] for n in names)

    def cube_exchange(cols, valid, axis_keys):
        owned = {ax for ax, _ in axis_keys}
        for ax, col in axis_keys:
            d = dims[ax]
            cap = pair_capacity(valid.shape[0], d, capacity_factor)
            dest = (hash32(cols[col], SHUFFLE_SEED)
                    % jnp.uint32(d)).astype(jnp.int32)
            scat = slot_scatter(dest, valid, d, cap)
            send_cols, send_valid = gather_rows(cols, scat.idx)
            cols = {n: jax.lax.all_to_all(c, names[ax], split_axis=0,
                                          concat_axis=0).reshape(d * cap)
                    for n, c in send_cols.items()}
            valid = jax.lax.all_to_all(send_valid, names[ax], split_axis=0,
                                       concat_axis=0).reshape(d * cap)
        for ax in range(len(dims)):
            if ax in owned:
                continue
            cols = {n: jax.lax.all_gather(c, names[ax]).reshape(-1)
                    for n, c in cols.items()}
            valid = jax.lax.all_gather(valid, names[ax]).reshape(-1)
        return cols, valid

    def f(cols_list, valid_list):
        shards = []
        for cols, valid, ak in zip(cols_list, valid_list, spec.axis_keys):
            cols = {n: c[0] for n, c in cols.items()}
            shards.append(cube_exchange(cols, valid[0], tuple(ak)))
        cols, valid = dict(shards[0][0]), shards[0][1]
        for lk in spec.links:
            b_cols, b_valid = shards[lk.build]
            res = hash_join(cols[lk.probe_col], valid, b_cols[lk.build_col],
                            b_valid)
            gathered, _ = gather_rows(b_cols, res.match_idx)
            for n, c in gathered.items():
                if n in cols:
                    raise ValueError(f"duplicate column {n!r} in "
                                     "multi-way join")
                cols[n] = c
            valid = valid & res.found
        for c1, c2 in spec.checks:
            valid = valid & (cols[c1] == cols[c2])
        return ({n: c[None] for n, c in cols.items()}, valid[None])

    spec_all = P(names)
    cols, valid = _shard_map(
        f, mesh=mesh,
        in_specs=(spec_all, spec_all),
        out_specs=(spec_all, spec_all),
    )(tuple(t.columns for t in tables), tuple(t.valid for t in tables))
    return Table(cols, valid)


# -- distributed runtime-filter build ----------------------------------------

def _partial_bloom_words(keys: jax.Array, valid: jax.Array, m_bits: int,
                         k: int) -> jax.Array:
    """Partial bloom filter of one partition's live keys: a dense jnp
    build (scatter is fine outside Pallas) sharing ``_positions`` with the
    kernel pair, so partial ORs compose to the exact global bit array."""
    flat = keys.reshape(-1).astype(jnp.int32)
    v = valid.reshape(-1)
    bits = jnp.zeros((m_bits,), jnp.bool_)
    for i in range(k):
        pos = _positions(flat, i, m_bits).astype(jnp.int32)
        # Invalid rows scatter out of range and are dropped.
        pos = jnp.where(v, pos, m_bits)
        bits = bits.at[pos].set(True, mode="drop")
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(
        jnp.where(bits.reshape(m_bits // 32, 32),
                  jnp.uint32(1) << shifts[None, :], jnp.uint32(0)),
        axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("key", "mesh", "m_bits", "k"))
def dist_bloom_build(table: Table, key: str, mesh: Mesh, *, m_bits: int,
                     k: int) -> jax.Array:
    """Distributed bloom build: per-device partial filters OR-merged
    across the mesh, then held replicated on every device.

    Returns the merged (m_bits/32,) uint32 array — bit-identical to the
    global-view ``bloom_build`` over the concatenated column, because OR
    accumulation is order- and partition-invariant. The all_gather +
    local OR here is the semantic spec of a bitwise-or all-reduce (XLA
    has no uint32 OR all-reduce primitive); the cost model prices the
    operation as the reduce tree a real all-reduce executes —
    ceil(log2 p) rounds of m/8 bytes (``filter_reduce_cost``) — not the
    gather's (p-1)·m/8.
    """
    p = mesh.shape[AXIS]

    def f(col, valid):
        part = _partial_bloom_words(col[0], valid[0], m_bits, k)
        parts = jax.lax.all_gather(part, AXIS)        # (p, m_words)
        merged = parts[0]
        for i in range(1, p):
            merged = merged | parts[i]
        return merged[None]

    words = _shard_map(
        f, mesh=mesh, in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS),
    )(table.column(key), table.valid)
    # Every device holds the identical merged filter; take one replica.
    return words[0]


@functools.partial(jax.jit, static_argnames=("key", "mesh"))
def dist_zone_map_build(table: Table, key: str, mesh: Mesh) -> jax.Array:
    """Distributed zone-map build: per-device (min, max) partial intervals
    merged across the mesh with an elementwise min/max reduce.

    Returns the merged int32 ``(2,)`` interval — value-identical to the
    global-view ``kernels.zone_map.key_range`` over the concatenated
    column at any device count: min/max is associative and commutative,
    and an empty (all-invalid) partition contributes the empty-interval
    identity ``[INT32_MAX, INT32_MIN]``, which is neutral under the
    merge. As with ``dist_bloom_build``, the all_gather + local fold is
    the semantic spec of the min/max all-reduce tree the cost model
    charges — ceil(log2 p) rounds of the 8-byte payload
    (``filter_reduce_cost(ZONE_MAP_BITS, kind="zone_map")``).
    """

    def f(col, valid):
        flat = col[0].reshape(-1).astype(jnp.int32)
        v = valid[0].reshape(-1)
        part = jnp.stack([
            jnp.min(jnp.where(v, flat, jnp.int32(_LO_IDENT))),
            jnp.max(jnp.where(v, flat, jnp.int32(_HI_IDENT)))])
        parts = jax.lax.all_gather(part, AXIS)        # (p, 2)
        return merge_ranges(parts)[None]

    out = _shard_map(
        f, mesh=mesh, in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS),
    )(table.column(key), table.valid)
    # Every device holds the identical merged interval; take one replica.
    return out[0]


@functools.partial(jax.jit, static_argnames=("key", "mesh"))
def dist_key_set_build(table: Table, key: str, mesh: Mesh
                       ) -> tuple[jax.Array, jax.Array]:
    """Distributed semi-join build: per-device *distinct* key lists,
    all_gather + merge-dedupe on the sorted machinery in ``core.psts``.

    Each device first folds its own partition into a local ``key_set``
    (sorted distinct live keys + sentinel padding) — local dedupe before
    the exchange, so duplicated hot keys are shipped once per device, not
    once per row. The padded partial lists are then all_gathered — the
    (p-1)·m/8-byte wire volume ``filter_reduce_cost(kind="semi_join")``
    charges — and merge-deduped with a second ``key_set`` pass over the
    gathered material, masking each partial to its live prefix.

    Returns ``(sorted_keys, n_distinct)`` with the same static shape as —
    and value-identical to — the global-view ``key_set`` over the
    concatenated column at any device count: distinct-of-union equals
    union-of-distincts, and sorting canonicalizes the order.
    """

    def f(col, valid):
        local, n_local = key_set(col[0], valid[0])
        gathered = jax.lax.all_gather(local, AXIS)     # (p, cap)
        counts = jax.lax.all_gather(n_local, AXIS)     # (p,)
        live = (jnp.arange(gathered.shape[1])[None, :] < counts[:, None])
        merged, n = key_set(gathered.reshape(-1), live.reshape(-1))
        return merged[None], n[None]

    keys, n = _shard_map(
        f, mesh=mesh, in_specs=(P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(table.column(key), table.valid)
    # Every device holds the identical merged key set; take one replica.
    return keys[0], n[0]


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh"))
def dist_broadcast_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                             mesh: Mesh) -> Table:
    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        fb_cols, fb_valid = _local_broadcast(b_cols, b_valid[0],
                                             mesh.shape[AXIS])
        res = hash_join(a_cols[a_key], a_valid[0], fb_cols[b_key], fb_valid)
        out_cols, out_valid = _attach(a_cols, a_valid[0], fb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = _shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)
