"""Distributed execution of the join methods under ``jax.shard_map``.

The global-view functions in ``methods.py`` are the semantic spec; here the
partition axis is a real mesh axis ``"p"`` and the exchanges are actual
collectives:

    broadcast  ->  jax.lax.all_gather   (paper's broadcast, Eq. 1)
    shuffle    ->  jax.lax.all_to_all   (paper's shuffle,   Eq. 5)

The per-partition compute (slot packing, radix hash join, sort join) is the
*same code* as the global view — only the exchange primitive differs. On the
CPU CI container this runs on ``--xla_force_host_platform_device_count``
placeholder devices (see tests/test_distributed_join.py); on a real cluster
the identical program spans pods.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .local_join import hash_join, sort_join
from .slots import (SHUFFLE_SEED, gather_rows, hash32, pair_capacity,
                    slot_scatter)
from .table import Table

AXIS = "p"


def make_join_mesh(p: int) -> Mesh:
    """1-D mesh over the join parallelism p."""
    from ..launch.mesh import _axis_type_kwargs
    return jax.make_mesh((p,), (AXIS,), **_axis_type_kwargs(1))


def place(table: Table, mesh: Mesh) -> Table:
    """Place a stacked table so partition i lives on device i."""
    sh = NamedSharding(mesh, P(AXIS))
    cols = {n: jax.device_put(c, sh) for n, c in table.columns.items()}
    return Table(cols, jax.device_put(table.valid, sh))


# -- per-shard exchange primitives (run inside shard_map; local leading axis
#    is 1 because each device owns exactly one partition) -------------------

def _local_shuffle(cols: Dict[str, jax.Array], valid: jax.Array, key: str,
                   p: int, pair_cap: int):
    """Pack rows into per-destination slots and all_to_all them."""
    dest = (hash32(cols[key], SHUFFLE_SEED) % jnp.uint32(p)).astype(jnp.int32)
    scat = slot_scatter(dest, valid, p, pair_cap)      # idx: (p, pair_cap)
    send_cols, send_valid = gather_rows(cols, scat.idx)
    recv_cols = {
        n: jax.lax.all_to_all(c, AXIS, split_axis=0, concat_axis=0
                              ).reshape(p * pair_cap)
        for n, c in send_cols.items()}
    recv_valid = jax.lax.all_to_all(send_valid, AXIS, split_axis=0,
                                    concat_axis=0).reshape(p * pair_cap)
    return recv_cols, recv_valid


def _local_broadcast(cols: Dict[str, jax.Array], valid: jax.Array, p: int):
    """all_gather a full replica of the table onto every device."""
    full_cols = {n: jax.lax.all_gather(c, AXIS).reshape(-1)
                 for n, c in cols.items()}
    full_valid = jax.lax.all_gather(valid, AXIS).reshape(-1)
    return full_cols, full_valid


# -- distributed join methods ------------------------------------------------

def _attach(a_cols, a_valid, b_cols, res):
    out = dict(a_cols)
    gathered, _ = gather_rows(b_cols, res.match_idx)
    for n, c in gathered.items():
        out[n if n not in out else f"{n}_r"] = c
    return out, a_valid & res.found


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh",
                                              "capacity_factor"))
def dist_shuffle_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                           mesh: Mesh, capacity_factor: float = 2.0) -> Table:
    p = mesh.shape[AXIS]
    cap_a = pair_capacity(a.capacity, p, capacity_factor)
    cap_b = pair_capacity(b.capacity, p, capacity_factor)

    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        ra_cols, ra_valid = _local_shuffle(a_cols, a_valid[0], a_key, p, cap_a)
        rb_cols, rb_valid = _local_shuffle(b_cols, b_valid[0], b_key, p, cap_b)
        res = hash_join(ra_cols[a_key], ra_valid, rb_cols[b_key], rb_valid)
        out_cols, out_valid = _attach(ra_cols, ra_valid, rb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh",
                                              "capacity_factor"))
def dist_shuffle_sort_join(a: Table, b: Table, a_key: str, b_key: str,
                           mesh: Mesh, capacity_factor: float = 2.0) -> Table:
    p = mesh.shape[AXIS]
    cap_a = pair_capacity(a.capacity, p, capacity_factor)
    cap_b = pair_capacity(b.capacity, p, capacity_factor)

    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        ra_cols, ra_valid = _local_shuffle(a_cols, a_valid[0], a_key, p, cap_a)
        rb_cols, rb_valid = _local_shuffle(b_cols, b_valid[0], b_key, p, cap_b)
        res = sort_join(ra_cols[a_key], ra_valid, rb_cols[b_key], rb_valid)
        out_cols, out_valid = _attach(ra_cols, ra_valid, rb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)


@functools.partial(jax.jit, static_argnames=("a_key", "b_key", "mesh"))
def dist_broadcast_hash_join(a: Table, b: Table, a_key: str, b_key: str,
                             mesh: Mesh) -> Table:
    def f(a_cols, a_valid, b_cols, b_valid):
        a_cols = {n: c[0] for n, c in a_cols.items()}
        b_cols = {n: c[0] for n, c in b_cols.items()}
        fb_cols, fb_valid = _local_broadcast(b_cols, b_valid[0],
                                             mesh.shape[AXIS])
        res = hash_join(a_cols[a_key], a_valid[0], fb_cols[b_key], fb_valid)
        out_cols, out_valid = _attach(a_cols, a_valid[0], fb_cols, res)
        return ({n: c[None] for n, c in out_cols.items()}, out_valid[None])

    cols, valid = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
    )(a.columns, a.valid, b.columns, b.valid)
    return Table(cols, valid)
