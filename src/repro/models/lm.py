"""Decoder LM composer: builds params, forward/train/prefill/decode for all
assigned architecture families, with scan-over-layers and RelShard-planned
distribution.

Param pytrees are plain nested dicts; per-layer blocks are *stacked* along a
leading layer axis and consumed by ``lax.scan`` (one compiled block body
regardless of depth — the only way 94-layer configs compile fast on the
dry-run host). Sharding is expressed as a congruent tree of PartitionSpecs
(``param_specs``), derived from leaf paths + the ShardingPlan.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.relshard import ShardingPlan
from ..layers import attention as attn
from ..layers import common as cm
from ..layers import embedding as emb
from ..layers import moe as moe_mod
from ..layers import rwkv as rwkv_mod
from ..layers import ssm as ssm_mod
from .config import Family, ModelConfig

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": emb.embedding_init(keys[0], cfg.vocab, cfg.d_model),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = emb.head_init(keys[1], cfg.vocab, cfg.d_model)

    if cfg.family is Family.SSM:  # rwkv6
        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "tm_norm": cm.rmsnorm_init(cfg.d_model),
                "time_mix": rwkv_mod.rwkv_init(k1, cfg.d_model,
                                               cfg.rwkv_head_dim),
                "cm_norm": cm.rmsnorm_init(cfg.d_model),
                "channel_mix": rwkv_mod.channel_mix_init(k2, cfg.d_model,
                                                         cfg.d_ff),
            }
        params["blocks"] = _stack_init(one, keys[2], cfg.n_layers)
        return params

    if cfg.family is Family.HYBRID:  # zamba2
        heads = cfg.ssm_heads or (2 * cfg.d_model) // 64

        def one(k):
            return {"norm": cm.rmsnorm_init(cfg.d_model),
                    "ssm": ssm_mod.ssm_init(k, cfg.d_model, cfg.ssm_state,
                                            heads)}
        params["blocks"] = _stack_init(one, keys[2], cfg.n_layers)
        k1, k2 = jax.random.split(keys[3])
        params["shared_attn"] = {
            "attn_norm": cm.rmsnorm_init(cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.kv_heads, cfg.hd),
            "mlp_norm": cm.rmsnorm_init(cfg.d_model),
            "mlp": cm.mlp_init(k2, cfg.d_model, cfg.d_ff,
                               cfg.mlp_activation),
        }
        return params

    # dense / moe / vlm / audio: uniform transformer blocks
    def one(k):
        k1, k2 = jax.random.split(k)
        block = {
            "attn_norm": cm.rmsnorm_init(cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.kv_heads, cfg.hd),
            "mlp_norm": cm.rmsnorm_init(cfg.d_model),
        }
        if cfg.is_moe:
            block["moe"] = moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff,
                                            cfg.n_experts)
        else:
            block["mlp"] = cm.mlp_init(k2, cfg.d_model, cfg.d_ff,
                                       cfg.mlp_activation)
        return block
    params["blocks"] = _stack_init(one, keys[2], cfg.n_layers)
    return params


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

#: leaf name -> (row axis kind, col axis kind) for 2-D weight matrices.
_COL_SHARDED = {"w_q", "w_k", "w_v", "w_gate", "w_up", "w_in", "w_r", "w_g",
                "w_kc", "decay_a", "router"}
_ROW_SHARDED = {"w_o", "w_down", "w_out", "w_vc", "decay_b"}


def param_specs(cfg: ModelConfig, params, plan: ShardingPlan):
    """PartitionSpec tree congruent with ``params``."""
    fsdp = plan.fsdp_axes[0] if plan.fsdp_axes else None
    model = plan.model_axis

    replicated_tp = plan.tp == "replicated"

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        name = path[-1]
        stacked = path[0] == "blocks"
        lead = (None,) if stacked else ()
        nd = leaf.ndim - len(lead)
        if replicated_tp and path[0] not in ("embed", "head") and nd == 2 \
                and (name in _COL_SHARDED or name in _ROW_SHARDED):
            # storage spreads over fsdp x model; compute gathers both.
            return P(*lead, fsdp, model)
        if path[0] in ("embed", "head"):
            strat = (plan.embed_strategy if path[0] == "embed"
                     else plan.head_strategy)
            if strat == "vocab_parallel":
                return P(model, fsdp)
            return P(None, fsdp)
        if name in ("w_gate", "w_up", "w_down") and nd == 3:  # MoE experts
            if plan.moe_strategy == "expert_parallel":
                return P(*lead, model, fsdp, None)
            return P(*lead, None, fsdp, None)
        if nd == 2:
            if name in _COL_SHARDED:
                return P(*lead, fsdp, model)
            if name in _ROW_SHARDED:
                return P(*lead, model, fsdp)
            return P(*lead, None, None)
        if nd == 1:
            return P(*lead, None)
        return P(*lead, *(None,) * nd)

    paths_leaves = jax.tree_util.tree_flatten_with_path(params)[0]

    def key_to_names(kp):
        return tuple(k.key for k in kp)

    flat = {key_to_names(kp): spec_for(key_to_names(kp), leaf)
            for kp, leaf in paths_leaves}

    def rebuild(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: rebuild(v, prefix + (k,)) for k, v in tree.items()}
        return flat[prefix]

    return rebuild(params)


# ---------------------------------------------------------------------------
# FSDP weight gathering
# ---------------------------------------------------------------------------

def _strip_fsdp(spec: P, fsdp_axes, strip_model: str | None = None) -> P:
    """Compute-time sharding: drop the fsdp axes (and, for replicated-TP
    plans, the model axis) from a param spec."""
    drop = set(fsdp_axes) | ({strip_model} if strip_model else set())
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a not in drop)
            out.append(kept if kept else None)
        else:
            out.append(None if e in drop else e)
    return P(*out)


def block_compute_shardings(cfg: ModelConfig, params, plan: ShardingPlan,
                            mesh):
    """NamedShardings for one scanned block's params with fsdp stripped
    (leading layer axis removed). Constraining weights to these inside the
    block body makes XLA emit the FSDP pattern: bf16 all-gather of weights
    in forward, bf16 reduce-scatter of grads in backward — instead of
    partial-sum all-reduces over activation-sized tensors."""
    from jax.sharding import NamedSharding
    specs = param_specs(cfg, params, plan)

    strip_model = plan.model_axis if plan.tp == "replicated" else None

    def per_block(subtree):
        return jax.tree.map(
            lambda s: NamedSharding(
                mesh, _strip_fsdp(P(*tuple(s)[1:]), plan.fsdp_axes,
                                  strip_model)),
            subtree, is_leaf=lambda s: isinstance(s, P))

    out = {"blocks": per_block(specs["blocks"])}
    if "shared_attn" in specs:
        out["shared_attn"] = jax.tree.map(
            lambda s: NamedSharding(
                mesh, _strip_fsdp(s, plan.fsdp_axes, strip_model)),
            specs["shared_attn"], is_leaf=lambda s: isinstance(s, P))
    return out


def _gather_weights(bp, shardings):
    """Cast to compute dtype then constrain: the all-gather moves bf16."""
    if shardings is None:
        return bp

    def one(w, s):
        wc = w.astype(cm.COMPUTE_DTYPE) if jnp.issubdtype(
            w.dtype, jnp.floating) else w
        return jax.lax.with_sharding_constraint(wc, s)
    return jax.tree.map(one, bp, shardings)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

class ForwardAux(NamedTuple):
    moe_load: Optional[jax.Array]      # (L, E) router counts (runtime stats)
    moe_aux_loss: jax.Array            # scalar
    moe_dropped: jax.Array             # scalar


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)


def _dense_block(bp, x, cfg: ModelConfig, plan, mesh, positions,
                 lt_schedule=False):
    h = cm.rmsnorm(bp["attn_norm"], x, cfg.rms_eps)
    a, _kv = attn.attn_apply(
        bp["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.hd, theta=cfg.rope_theta, positions=positions,
        window=cfg.attn_window, lower_triangular_schedule=lt_schedule,
        shard_ctx=(mesh, plan.batch_axes, plan.model_axis))
    x = x + a
    h = cm.rmsnorm(bp["mlp_norm"], x, cfg.rms_eps)
    if cfg.is_moe:
        y, aux = moe_mod.moe_apply(
            bp["moe"], h, mesh=mesh, batch_axes=plan.batch_axes,
            model_axis=plan.model_axis, n_experts=cfg.n_experts,
            top_k=cfg.top_k, strategy=plan.moe_strategy)
        return x + y, (aux.load, aux.aux_loss, aux.dropped)
    y = cm.mlp_apply(bp["mlp"], h, cfg.mlp_activation)
    zero = jnp.zeros((), jnp.float32)
    return x + y, (jnp.zeros((max(cfg.n_experts, 1),), jnp.float32), zero,
                   zero)


def forward(params, cfg: ModelConfig, plan: ShardingPlan, mesh, tokens,
            cond_emb=None, lt_schedule: bool = False):
    """Full-sequence forward to final hidden states.

    tokens: (B, S_text); cond_emb: (B, n_cond, d) stub frontend output.
    Returns (hidden (B, S_total, d), ForwardAux).
    """
    x = emb.embed_apply(params["embed"], tokens, mesh=mesh,
                        batch_axes=plan.batch_axes,
                        model_axis=plan.model_axis,
                        strategy=plan.embed_strategy)
    if cond_emb is not None:
        x = jnp.concatenate([cond_emb.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    cs = (block_compute_shardings(cfg, params, plan, mesh)
          if mesh is not None else None)

    if cfg.family is Family.SSM:
        def block(x, bp):
            bp = _gather_weights(bp, cs["blocks"] if cs else None)
            h = cm.rmsnorm(bp["tm_norm"], x, cfg.rms_eps)
            st0 = rwkv_mod.RWKVState(
                jnp.zeros((B, cfg.d_model // cfg.rwkv_head_dim,
                           cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                          jnp.float32),
                jnp.zeros((B, cfg.d_model), cm.COMPUTE_DTYPE))
            y, _st = rwkv_mod.rwkv_time_mix(
                bp["time_mix"], h, st0, head_dim=cfg.rwkv_head_dim,
                shard_ctx=(mesh, plan.batch_axes, plan.model_axis))
            x = x + y
            h = cm.rmsnorm(bp["cm_norm"], x, cfg.rms_eps)
            h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]],
                                     axis=1)
            x = x + rwkv_mod.channel_mix(bp["channel_mix"], h, h_prev)
            return x, None
        body = _remat(block, cfg.remat_policy)
        x, _ = jax.lax.scan(lambda c, bp: body(c, bp), x, params["blocks"])
        aux = ForwardAux(None, jnp.zeros(()), jnp.zeros(()))

    elif cfg.family is Family.HYBRID:
        heads = cfg.ssm_heads or (2 * cfg.d_model) // 64

        def mamba_block(x, bp):
            bp = _gather_weights(bp, cs["blocks"] if cs else None)
            h = cm.rmsnorm(bp["norm"], x, cfg.rms_eps)
            y, _st = ssm_mod.ssm_apply(bp["ssm"], h, n_state=cfg.ssm_state,
                                       n_heads=heads)
            return x + y, None
        body = _remat(mamba_block, cfg.remat_policy)

        def shared_attn_block(x):
            sp = _gather_weights(params["shared_attn"],
                                 cs["shared_attn"] if cs else None)
            h = cm.rmsnorm(sp["attn_norm"], x, cfg.rms_eps)
            a, _ = attn.attn_apply(
                sp["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                head_dim=cfg.hd, theta=cfg.rope_theta, positions=positions,
                window=cfg.attn_window,
                shard_ctx=(mesh, plan.batch_axes, plan.model_axis))
            x = x + a
            h = cm.rmsnorm(sp["mlp_norm"], x, cfg.rms_eps)
            return x + cm.mlp_apply(sp["mlp"], h, cfg.mlp_activation)
        shared = _remat(shared_attn_block, cfg.remat_policy)

        period = cfg.attn_every or cfg.n_layers
        n_seg, rem = divmod(cfg.n_layers, period)
        idx = 0
        for _ in range(n_seg):
            seg = jax.tree.map(lambda a: a[idx:idx + period],
                               params["blocks"])
            x, _ = jax.lax.scan(lambda c, bp: body(c, bp), x, seg)
            x = shared(x)
            idx += period
        if rem:
            seg = jax.tree.map(lambda a: a[idx:], params["blocks"])
            x, _ = jax.lax.scan(lambda c, bp: body(c, bp), x, seg)
        aux = ForwardAux(None, jnp.zeros(()), jnp.zeros(()))

    else:
        def block(x, bp):
            bp = _gather_weights(bp, cs["blocks"] if cs else None)
            return _dense_block(bp, x, cfg, plan, mesh, positions,
                                lt_schedule)
        body = _remat(block, cfg.remat_policy)
        x, (loads, auxl, drop) = jax.lax.scan(
            lambda c, bp: body(c, bp), x, params["blocks"])
        aux = ForwardAux(loads if cfg.is_moe else None,
                         jnp.mean(auxl), jnp.mean(drop))

    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return x, aux


def _head_params(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def train_loss(params, cfg: ModelConfig, plan: ShardingPlan, mesh, batch,
               moe_aux_weight: float = 0.01, lt_schedule: bool = False):
    """batch: {"tokens": (B,S), optional "cond_emb": (B,n_cond,d)}.
    Next-token CE over text positions. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    cond = batch.get("cond_emb")
    n_cond = 0 if cond is None else cond.shape[1]
    hidden, aux = forward(params, cfg, plan, mesh, tokens, cond,
                          lt_schedule=lt_schedule)
    # predict tokens[:, 1:] from hidden at absolute pos n_cond .. end-1
    h = hidden[:, n_cond:-1]
    labels = tokens[:, 1:]
    loss = emb.lm_head_loss(_head_params(params, cfg), h, labels,
                            mesh=mesh, batch_axes=plan.batch_axes,
                            model_axis=plan.model_axis,
                            strategy=plan.head_strategy)
    total = loss + moe_aux_weight * aux.moe_aux_loss
    metrics = {"ce_loss": loss, "moe_aux": aux.moe_aux_loss,
               "moe_dropped": aux.moe_dropped}
    if aux.moe_load is not None:
        metrics["moe_load"] = aux.moe_load
    return total, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode-state pytree for one generation session."""
    if cfg.family is Family.SSM:
        H = cfg.d_model // cfg.rwkv_head_dim
        return {
            "s": jnp.zeros((cfg.n_layers, batch, H, cfg.rwkv_head_dim,
                            cfg.rwkv_head_dim), jnp.float32),
            "x_prev_tm": jnp.zeros((cfg.n_layers, batch, cfg.d_model),
                                   cm.COMPUTE_DTYPE),
            "x_prev_cm": jnp.zeros((cfg.n_layers, batch, cfg.d_model),
                                   cm.COMPUTE_DTYPE),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.family is Family.HYBRID:
        heads = cfg.ssm_heads or (2 * cfg.d_model) // 64
        hd_i = (2 * cfg.d_model) // heads
        n_seg = cfg.n_layers // (cfg.attn_every or cfg.n_layers)
        return {
            "ssm_s": jnp.zeros((cfg.n_layers, batch, heads, hd_i,
                                cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, 2 * cfg.d_model,
                               ssm_mod.CONV_K - 1), cm.COMPUTE_DTYPE),
            "attn_k": jnp.zeros((n_seg, batch, max_seq, cfg.kv_heads,
                                 cfg.hd), cm.COMPUTE_DTYPE),
            "attn_v": jnp.zeros((n_seg, batch, max_seq, cfg.kv_heads,
                                 cfg.hd), cm.COMPUTE_DTYPE),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.kv_heads, cfg.hd),
                       cm.COMPUTE_DTYPE),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.kv_heads, cfg.hd),
                       cm.COMPUTE_DTYPE),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, plan: ShardingPlan, mesh, token,
                cache):
    """One serve step: token (B, 1) + cache -> (logits (B, vocab), cache)."""
    x = emb.embed_apply(params["embed"], token, mesh=mesh,
                        batch_axes=plan.batch_axes,
                        model_axis=plan.model_axis,
                        strategy=plan.embed_strategy)
    B = x.shape[0]
    pos = cache["pos"]
    cs = (block_compute_shardings(cfg, params, plan, mesh)
          if mesh is not None else None)

    if cfg.family is Family.SSM:
        def step(x, inp):
            bp, s, xtm, xcm = inp
            bp = _gather_weights(bp, cs["blocks"] if cs else None)
            h = cm.rmsnorm(bp["tm_norm"], x, cfg.rms_eps)
            st = rwkv_mod.RWKVState(s, xtm)
            y, st2 = rwkv_mod.rwkv_decode(bp["time_mix"], h, st,
                                          head_dim=cfg.rwkv_head_dim)
            x = x + y
            h = cm.rmsnorm(bp["cm_norm"], x, cfg.rms_eps)
            x = x + rwkv_mod.channel_mix(bp["channel_mix"], h,
                                         xcm[:, None, :])
            return x, (st2.s, st2.x_prev, h[:, 0])
        x, (s_new, xtm_new, xcm_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["s"], cache["x_prev_tm"],
                      cache["x_prev_cm"]))
        new_cache = {"s": s_new, "x_prev_tm": xtm_new, "x_prev_cm": xcm_new,
                     "pos": pos + 1}

    elif cfg.family is Family.HYBRID:
        heads = cfg.ssm_heads or (2 * cfg.d_model) // 64
        period = cfg.attn_every or cfg.n_layers
        n_seg, rem = divmod(cfg.n_layers, period)
        sp = _gather_weights(params["shared_attn"],
                             cs["shared_attn"] if cs else None)
        new_s, new_conv = [], []
        new_k, new_v = [], []
        idx = 0
        for seg_i in range(n_seg):
            seg = jax.tree.map(lambda a: a[idx:idx + period],
                               params["blocks"])

            def mstep(x, inp):
                bp, s, conv = inp
                bp = _gather_weights(bp, cs["blocks"] if cs else None)
                h = cm.rmsnorm(bp["norm"], x, cfg.rms_eps)
                y, st = ssm_mod.ssm_decode(bp["ssm"], h, ssm_mod.SSMState(
                    s, conv), n_state=cfg.ssm_state, n_heads=heads)
                return x + y, (st.s, st.conv)
            x, (s2, c2) = jax.lax.scan(
                mstep, x, (seg, cache["ssm_s"][idx:idx + period],
                           cache["conv"][idx:idx + period]))
            new_s.append(s2)
            new_conv.append(c2)
            h = cm.rmsnorm(sp["attn_norm"], x, cfg.rms_eps)
            a, k2, v2 = attn.attn_decode(
                sp["attn"], h, cache["attn_k"][seg_i],
                cache["attn_v"][seg_i], pos, n_heads=cfg.n_heads,
                kv_heads=cfg.kv_heads, head_dim=cfg.hd,
                theta=cfg.rope_theta, window=cfg.attn_window)
            x = x + a
            h = cm.rmsnorm(sp["mlp_norm"], x, cfg.rms_eps)
            x = x + cm.mlp_apply(sp["mlp"], h, cfg.mlp_activation)
            new_k.append(k2)
            new_v.append(v2)
            idx += period
        if rem:
            seg = jax.tree.map(lambda a: a[idx:], params["blocks"])

            def mstep(x, inp):
                bp, s, conv = inp
                bp = _gather_weights(bp, cs["blocks"] if cs else None)
                h = cm.rmsnorm(bp["norm"], x, cfg.rms_eps)
                y, st = ssm_mod.ssm_decode(bp["ssm"], h, ssm_mod.SSMState(
                    s, conv), n_state=cfg.ssm_state, n_heads=heads)
                return x + y, (st.s, st.conv)
            x, (s2, c2) = jax.lax.scan(
                mstep, x, (seg, cache["ssm_s"][idx:], cache["conv"][idx:]))
            new_s.append(s2)
            new_conv.append(c2)
        new_cache = {
            "ssm_s": jnp.concatenate(new_s, axis=0),
            "conv": jnp.concatenate(new_conv, axis=0),
            "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
            "pos": pos + 1,
        }

    else:
        def step(x, inp):
            bp, k_l, v_l = inp
            bp = _gather_weights(bp, cs["blocks"] if cs else None)
            h = cm.rmsnorm(bp["attn_norm"], x, cfg.rms_eps)
            a, k2, v2 = attn.attn_decode(
                bp["attn"], h, k_l, v_l, pos, n_heads=cfg.n_heads,
                kv_heads=cfg.kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
                window=cfg.attn_window)
            x = x + a
            h = cm.rmsnorm(bp["mlp_norm"], x, cfg.rms_eps)
            if cfg.is_moe:
                y, _aux = moe_mod.moe_apply(
                    bp["moe"], h, mesh=mesh, batch_axes=plan.batch_axes,
                    model_axis=plan.model_axis, n_experts=cfg.n_experts,
                    top_k=cfg.top_k, strategy=plan.moe_strategy)
            else:
                y = cm.mlp_apply(bp["mlp"], h, cfg.mlp_activation)
            return x + y, (k2, v2)
        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}

    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = emb.lm_head_logits(_head_params(params, cfg), x[:, 0:1],
                                mesh=mesh, batch_axes=plan.batch_axes,
                                model_axis=plan.model_axis,
                                strategy=plan.head_strategy)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, plan: ShardingPlan, mesh, tokens,
            cond_emb=None):
    """Full-sequence prefill returning last-position logits. (The dry-run
    lowers this for prefill_* shapes; cache assembly for generation reuses
    forward's per-layer KV which the serving driver manages.)"""
    hidden, _aux = forward(params, cfg, plan, mesh, tokens, cond_emb)
    logits = emb.lm_head_logits(_head_params(params, cfg), hidden[:, -1:],
                                mesh=mesh, batch_axes=plan.batch_axes,
                                model_axis=plan.model_axis,
                                strategy=plan.head_strategy)
    return logits[:, 0]
