"""Model configuration covering all assigned architecture families.

One dataclass spans dense / MoE / hybrid-SSM / pure-SSM (RWKV) / VLM / audio
backbones; family-specific fields are ignored elsewhere. Exact assigned
configs live in ``repro.configs.<arch>``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    HYBRID = "hybrid"   # Mamba2 blocks + shared attention (zamba2)
    SSM = "ssm"         # attention-free (rwkv6)
    VLM = "vlm"         # vision-stub frontend + dense decoder
    AUDIO = "audio"     # audio-token decoder (musicgen)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0             # 0 -> = n_heads (MHA)
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0              # 0 -> d_model // 64
    attn_every: int = 0             # hybrid: shared attn block period
    # --- RWKV ---
    rwkv_head_dim: int = 64
    # --- frontends (stubs) ---
    n_cond_tokens: int = 0          # VLM patches / audio conditioning prefix
    # --- common ---
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_activation: str = "swiglu"  # "swiglu" | "gelu" | "geglu"
    optimizer: str = "adamw"        # "adamw" | "adafactor"
    remat_policy: str = "full"      # "full" | "dots" | "none"
    # long-context: attention window for hybrid shared-attn at huge S (0=full)
    attn_window: int = 0

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family is Family.SSM

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear attention)."""
        return self.family in (Family.SSM, Family.HYBRID)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family is Family.SSM:  # rwkv6
            per = _rwkv_params(self)
            return emb + self.n_layers * per
        att = d * self.n_heads * self.hd + d * self.hd * self.kv_heads * 2 \
            + self.n_heads * self.hd * d
        if self.mlp_activation in ("swiglu", "geglu"):
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.is_moe:
            mlp = self.n_experts * mlp + d * self.n_experts  # + router
        if self.family is Family.HYBRID:
            mamba = _mamba_params(self)
            n_attn = self.n_layers // max(self.attn_every, 1)
            mlp_h = 3 * d * ff
            return emb + self.n_layers * mamba + 1 * (att + mlp_h) * min(
                n_attn, 1) + 0 * n_attn
        return emb + self.n_layers * (att + mlp)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_all = self.n_experts * 3 * d * ff
        mlp_act = self.top_k * 3 * d * ff
        return self.param_count() - self.n_layers * (mlp_all - mlp_act)


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner = 2 * d
    heads = cfg.ssm_heads or d_inner // 64
    return (d * (2 * d_inner + 2 * cfg.ssm_state * heads + heads)  # in_proj
            + d_inner * d                                          # out_proj
            + heads * (2 + cfg.ssm_state))                         # A, D, dt


def _rwkv_params(cfg: ModelConfig) -> int:
    d, ff = cfg.d_model, cfg.d_ff
    # time-mix: r,k,v,g,o projections + decay MLP; channel-mix: 2 mats
    return 5 * d * d + 2 * d * 64 + d * ff + ff * d


# ---------------------------------------------------------------------------
# Input shape sets (assigned): every LM arch pairs with these four.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP(full-attention): long_500k requires sub-quadratic "
                "attention (assignment instruction); noted in DESIGN.md")
    return None
