"""Model composition: config dataclasses, the decoder-LM composer, and
modality-frontend stubs."""

from .config import (Family, ModelConfig, SHAPES, SHAPE_BY_NAME, ShapeConfig,
                     shape_applicable)

__all__ = ["Family", "ModelConfig", "SHAPES", "SHAPE_BY_NAME", "ShapeConfig",
           "shape_applicable"]
