"""Optimizers: AdamW (default) and Adafactor (factored second moment, for
the 100B+ MoE configs where full Adam state would not fit per-chip HBM).

States are pytrees congruent with params and inherit the params' sharding
(FSDP over the data axis), so optimizer memory scales down with the mesh.
Gradient "compression": grads can be cast to bf16 before the update
(halves the reduce-scatter bytes the backward pass emits under FSDP).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    grad_dtype: str = "float32"    # "bfloat16" -> compressed reduction


def lr_at(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def init_opt_state(cfg: OptConfig, params) -> Dict[str, Any]:
    if cfg.name == "adamw":
        return {
            "mu": jax.tree.map(jnp.zeros_like, params),
            "nu": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "adafactor":
        def row_col(p):
            if p.ndim < 2:
                return {"v": jnp.zeros_like(p)}
            return {"vr": jnp.zeros(p.shape[:-1], p.dtype),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], p.dtype)}
        return {"fact": jax.tree.map(row_col, params,
                                     is_leaf=lambda x: isinstance(
                                         x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}
    raise ValueError(f"unknown optimizer {cfg.name}")


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, opt_state, grads
                  ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """One optimizer step. Returns (params, opt_state, metrics)."""
    if cfg.grad_dtype == "bfloat16":
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)

    if cfg.name == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          opt_state["mu"], grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          opt_state["nu"], grads)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * p)
        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {
            "grad_norm": gnorm, "lr": lr}

    # adafactor (beta1=0 variant)
    d2 = 1 - 0.999 ** step.astype(jnp.float32)

    def upd(p, g, f):
        g2 = g * g + 1e-30
        if p.ndim < 2:
            v = 0.999 * f["v"] + 0.001 * g2
            update = g / (jnp.sqrt(v / d2) + cfg.eps)
            newf = {"v": v}
        else:
            vr = 0.999 * f["vr"] + 0.001 * jnp.mean(g2, axis=-1)
            vc = 0.999 * f["vc"] + 0.001 * jnp.mean(g2, axis=-2)
            rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
            vhat = rfac * vc[..., None, :]
            update = g / (jnp.sqrt(vhat / d2) + cfg.eps)
            newf = {"vr": vr, "vc": vc}
        return p - lr * (update + cfg.weight_decay * p), newf

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_f = [f for f in _iter_fact(opt_state["fact"], params)]
    new_p, new_f = [], []
    for p, g, f in zip(leaves_p, leaves_g, leaves_f):
        np_, nf = upd(p, g, f)
        new_p.append(np_)
        new_f.append(nf)
    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_fact = jax.tree_util.tree_unflatten(treedef, new_f)
    return new_params, {"fact": new_fact, "step": step}, {
        "grad_norm": gnorm, "lr": lr}


def _iter_fact(fact, params):
    """Yield the factored-state dict for every param leaf, in tree order."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    for kp, _ in leaves_with_path:
        node = fact
        for k in kp:
            node = node[k.key]
        yield node


def opt_state_specs(cfg: OptConfig, param_specs_tree):
    """Sharding specs for the optimizer state (mirror the params)."""
    from jax.sharding import PartitionSpec as P
    if cfg.name == "adamw":
        return {"mu": param_specs_tree, "nu": param_specs_tree,
                "step": P()}

    def row_col_spec(spec):
        parts = tuple(spec)
        if len(parts) < 2:
            return {"v": spec}
        return {"vr": P(*parts[:-1]), "vc": P(*parts[:-2], parts[-1])}
    return {"fact": jax.tree.map(row_col_spec, param_specs_tree,
                                 is_leaf=lambda s: isinstance(
                                     s, type(P()))),
            "step": P()}
