"""Sharded checkpointing with atomic manifests — the fault-tolerance
substrate (checkpoint/restart, elastic re-sharding).

Layout:  <dir>/step_<N>/arr_<i>.npy  + manifest.json (tree structure,
shapes, dtypes, step, config digest). Writes go to a temp dir renamed into
place, so a killed writer never leaves a half-checkpoint that ``latest``
would pick up (restart safety). Loading re-shards to whatever mesh the new
job runs on (elastic scaling): arrays are stored unsharded per-leaf and
device_put with the target sharding.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None
         ) -> str:
    """Atomically persist a pytree. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"),
                    np.asarray(jax.device_get(leaf)))
        manifest = {
            "step": step,
            "n_arrays": len(leaves),
            "treedef": str(treedef),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``; re-shard to ``shardings``
    (pytree of NamedSharding / None) for the *current* mesh — a checkpoint
    written on one mesh loads onto any other (elastic scaling)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    if manifest["n_arrays"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_arrays']} arrays, model needs "
            f"{len(leaves)} — architecture mismatch")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"arr_{i}: shape {arr.shape} != {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
