"""Training step factory + host loop with checkpoint/restart.

``make_train_step`` builds the jit'd (params, opt_state, batch) -> (params,
opt_state, metrics) step with explicit in/out shardings from the RelShard
plan — the same callable the multi-pod dry-run lowers with
ShapeDtypeStructs. The host loop adds fault tolerance: periodic atomic
checkpoints, resume-from-latest, and deterministic data replay.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.relshard import ShardingPlan
from ..models import lm
from ..models.config import ModelConfig
from . import checkpoint as ckpt_mod
from .data import DataConfig, batch_for_step
from .optimizer import OptConfig, apply_updates, init_opt_state, \
    opt_state_specs


def batch_specs(plan: ShardingPlan, has_cond: bool):
    spec = {"tokens": P(plan.batch_axes)}
    if has_cond:
        spec["cond_emb"] = P(plan.batch_axes)
    return spec


def make_train_step(cfg: ModelConfig, plan: ShardingPlan, mesh,
                    opt_cfg: OptConfig, lt_schedule: bool = False):
    """Returns the pure train_step function (to be jit'd by the caller with
    the sharding trees from ``sharding_trees``)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = lm.train_loss(p, cfg, plan, mesh, batch,
                                          lt_schedule=lt_schedule)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn,
                                                    has_aux=True)(params)
        params2, opt2, opt_metrics = apply_updates(opt_cfg, params,
                                                   opt_state, grads)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def sharding_trees(cfg: ModelConfig, plan: ShardingPlan, mesh,
                   opt_cfg: OptConfig, params_shape):
    """NamedSharding pytrees for params / opt state (jit in_shardings)."""
    specs = lm.param_specs(cfg, params_shape, plan)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
    o_specs = opt_state_specs(opt_cfg, specs)
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                        is_leaf=lambda s: isinstance(s, P))
    return p_sh, o_sh, specs


def train(cfg: ModelConfig, plan: ShardingPlan, mesh, *,
          steps: int, global_batch: int, seq_len: int,
          opt_cfg: Optional[OptConfig] = None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 100, resume: bool = True, log_every: int = 10,
          seed: int = 0) -> Dict[str, Any]:
    """Host training loop (used by examples + launch/train.py)."""
    opt_cfg = opt_cfg or OptConfig(name=cfg.optimizer)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(cfg, key)
    opt_state = init_opt_state(opt_cfg, params)
    data_cfg = DataConfig(cfg.vocab, seq_len, global_batch, seed,
                          cfg.n_cond_tokens, cfg.d_model)

    start = 0
    if ckpt_dir and resume:
        last = ckpt_mod.latest_step(ckpt_dir)
        if last is not None:
            state, _ = ckpt_mod.restore(ckpt_dir, last,
                                        {"params": params,
                                         "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    step_fn = make_train_step(cfg, plan, mesh, opt_cfg)
    if mesh is not None:
        p_sh, o_sh, _ = sharding_trees(cfg, plan, mesh, opt_cfg, params)
        b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            batch_specs(plan, cfg.n_cond_tokens > 0),
                            is_leaf=lambda s: isinstance(s, P))
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    history = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch = batch_for_step(data_cfg, step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            dt = time.perf_counter() - t0
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt:.1f}s)", flush=True)
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt_mod.save(ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          extra={"arch": cfg.name})
    return {"params": params, "opt_state": opt_state, "history": history}
