"""Training substrate: optimizers, deterministic data pipeline, atomic
sharded checkpoints, and the train-step factory."""

from .checkpoint import latest_step, restore, save
from .data import DataConfig, batch_for_step
from .optimizer import OptConfig, apply_updates, init_opt_state
from .train_loop import make_train_step, sharding_trees, train

__all__ = ["latest_step", "restore", "save", "DataConfig", "batch_for_step",
           "OptConfig", "apply_updates", "init_opt_state", "make_train_step",
           "sharding_trees", "train"]
