"""Deterministic synthetic token pipeline.

Stateless step->batch mapping: ``batch_for_step(step)`` is a pure function
of (seed, step), so a restarted job replays the exact token stream — the
property checkpoint/restart correctness depends on (DESIGN.md scale-out).
Tokens follow a Zipfian unigram draw with a shifted-window structure so the
loss actually decreases (next-token has mutual information with context).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_cond_tokens: int = 0
    d_model: int = 0  # for cond_emb stubs


def batch_for_step(cfg: DataConfig, step: int):
    """Pure (seed, step) -> batch. jit-able; host calls it per step."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    # Zipf-ish unigram via exponential transform of uniforms.
    u = jax.random.uniform(k1, (cfg.global_batch, cfg.seq_len),
                           minval=1e-6, maxval=1.0)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(cfg.vocab)))) - 1
    base = ranks.astype(jnp.int32) % cfg.vocab
    # structure: every other token repeats its predecessor + 1 (learnable)
    shifted = jnp.roll(base, 1, axis=1)
    alt = (jnp.arange(cfg.seq_len) % 2).astype(jnp.int32)[None, :]
    tokens = jnp.where(alt == 1, (shifted + 1) % cfg.vocab, base)
    batch = {"tokens": tokens}
    if cfg.n_cond_tokens:
        batch["cond_emb"] = 0.02 * jax.random.normal(
            k2, (cfg.global_batch, cfg.n_cond_tokens, cfg.d_model),
            jnp.bfloat16)
    return batch
