"""Synthetic TPC-DS-like star schema (DESIGN.md §7: same *shape* of the
decision problem as TPC-DS — fact tables vastly larger than dimensions,
FK->PK equi-joins, multi-join chains, skewable keys).

Scale factor 1.0 ~= 100k fact rows; tables keep TPC-DS-style names so the
query suite reads like the original workload.
"""

from __future__ import annotations

import dataclasses
import itertools
import uuid
from typing import Dict, Tuple

import numpy as np

from ..core.stats import (ColumnStats, ColumnSummary,
                          column_stats_from_summary)
from ..joins.table import Table, from_numpy, partition_round_robin


@dataclasses.dataclass
class Catalog:
    """Named stacked tables + their (exact) base statistics.

    ``key_domains`` maps key columns (FKs and PKs alike) to the cardinality
    of the domain they draw from — the denominator of the runtime-filter
    planner's selectivity estimate sigma = surviving build keys / domain.
    It is header metadata (like the PK contract), not a measurement.

    ``version`` identifies the catalog *contents*: every constructed
    Catalog (each ``generate`` call included) gets a fresh monotonically
    increasing value. ``uid`` is the catalog's *identity fingerprint* — a
    generation UUID minted per constructed Catalog. The cross-query caches
    (``FilterCache``, ``PlanCache``) key their validity on
    :func:`catalog_fingerprint`, i.e. on ``(version, uid)``: the version
    alone is only process-unique by convention, and two Catalogs built
    with an explicitly-passed (or persisted-and-reloaded) version number
    would otherwise falsely reuse each other's payloads — wrong rows, not
    just a stale-cost miss. Data changes must go through a new Catalog
    object, never by mutating ``tables`` in place.
    """

    tables: Dict[str, Table]
    p: int
    key_domains: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: Per-column NDV / MCV / equi-depth-histogram statistics
    #: (``core.stats.ColumnStats``), computed by ``generate`` from the
    #: unpartitioned data. Column names are globally unique across the
    #: star schema, so one flat map covers every table. Empty on
    #: hand-built catalogs — every estimator treats a missing entry as
    #: "no histogram" and falls back to the declared/domain fractions.
    column_stats: Dict[str, ColumnStats] = dataclasses.field(
        default_factory=dict)
    version: int = dataclasses.field(
        default_factory=lambda: next(_CATALOG_VERSIONS))
    uid: str = dataclasses.field(
        default_factory=lambda: uuid.uuid4().hex)

    def table(self, name: str) -> Table:
        return self.tables[name]


#: Source of ``Catalog.version`` values (process-unique, monotonic).
_CATALOG_VERSIONS = itertools.count()


def catalog_fingerprint(catalog) -> Tuple[object, object]:
    """Cache-validity identity of a catalog: ``(version, uid)``.

    Both components must match for a cached payload to be reusable —
    ``version`` tracks declared content generations, ``uid`` pins the
    concrete Catalog instance lineage so version-number collisions across
    independently built catalogs can never alias cache entries. Tolerates
    catalog-like objects without the fields (None components) so caches
    degrade to always-invalidate rather than crash."""
    return (getattr(catalog, "version", None), getattr(catalog, "uid", None))


#: (rows per unit scale, payload float columns) per table. Dimensions are
#: sized to place joins on BOTH sides of k0: fact/item k~25d, fact/store
#: k~2000, fact/customer k~4 etc. (with w=1,p=8 -> k0=15).
SCHEMA = {
    "store_sales": 100_000,     # fact
    "catalog_sales": 60_000,    # second fact
    "inventory": 30_000,        # medium fact
    "customer": 12_000,         # large dim (k < k0 vs fact)
    "item": 2_000,              # mid dim
    "date_dim": 360,            # small dim (explicit 360-day year: 12x30)
    "store": 60,                # tiny dim
    "promotion": 40,            # tiny dim
    "warehouse": 12,            # tiny dim
    "household": 3_000,         # mid dim
}


def _zipf_fks(rng, n, n_dim, skew: float):
    """FK draws; skew=0 -> uniform, else Zipf-tilted (hot keys)."""
    if skew <= 0:
        return rng.integers(0, n_dim, n).astype(np.int32)
    ranks = np.arange(1, n_dim + 1, dtype=np.float64)
    probs = ranks ** (-skew)
    probs /= probs.sum()
    return rng.choice(n_dim, size=n, p=probs).astype(np.int32)


#: Only facts scale; dimensions are fixed (TPC-DS dims grow sub-linearly,
#: and e.g. date_dim must always cover whole years).
FACTS = ("store_sales", "catalog_sales", "inventory")


def generate(scale: float = 1.0, p: int = 8, seed: int = 0,
             skew: float = 0.0,
             skew_overrides: Dict[str, float] | None = None) -> Catalog:
    """Build the catalog. ``skew`` is the global Zipf exponent of every fact
    FK column; ``skew_overrides`` overrides it per column (e.g.
    ``{"ss_customer_sk": 1.4}`` makes only the customer key hot), letting
    the skewed queries (q16-q18) tilt exactly the join they target.
    """
    rng = np.random.default_rng(seed)
    n = {t: max(8, int(r * scale)) if t in FACTS else r
         for t, r in SCHEMA.items()}
    overrides = skew_overrides or {}

    def fks(col: str, nrows: int, dim: str):
        return _zipf_fks(rng, nrows, n[dim], overrides.get(col, skew))

    def dim(name, pk, extra):
        cols = {pk: np.arange(n[name], dtype=np.int32)}
        cols.update(extra)
        return from_numpy(cols)

    tables = {}
    tables["customer"] = dim("customer", "c_customer_sk", {
        "c_region": rng.integers(0, 8, n["customer"]).astype(np.int32),
        "c_hdemo_sk": rng.integers(0, n["household"],
                                   n["customer"]).astype(np.int32),
        "c_income": rng.uniform(2e4, 2e5, n["customer"]).astype(np.float32),
    })
    tables["item"] = dim("item", "i_item_sk", {
        "i_category": rng.integers(0, 10, n["item"]).astype(np.int32),
        "i_brand": rng.integers(0, 100, n["item"]).astype(np.int32),
        "i_price": rng.uniform(1, 300, n["item"]).astype(np.float32),
    })
    # Explicit 360-day calendar (12 months x 30 days): every month holds
    # exactly 1/12 of the domain and every day-of-month exactly 1/30, so
    # the suite's declared date selectivities are *exact*, not off-by-one
    # (a 365-day year would wrap days 360-364 back into month 0).
    tables["date_dim"] = dim("date_dim", "d_date_sk", {
        "d_month": (np.arange(n["date_dim"]) // 30 % 12).astype(np.int32),
        "d_year": (2000 + np.arange(n["date_dim"]) // 360).astype(np.int32),
        "d_moy": (np.arange(n["date_dim"]) % 30).astype(np.int32),
    })
    tables["store"] = dim("store", "s_store_sk", {
        "s_state": rng.integers(0, 12, n["store"]).astype(np.int32),
        "s_floor": rng.uniform(1e3, 1e5, n["store"]).astype(np.float32),
    })
    tables["promotion"] = dim("promotion", "p_promo_sk", {
        "p_channel": rng.integers(0, 4, n["promotion"]).astype(np.int32),
    })
    tables["warehouse"] = dim("warehouse", "w_warehouse_sk", {
        "w_state": rng.integers(0, 12, n["warehouse"]).astype(np.int32),
    })
    tables["household"] = dim("household", "hd_demo_sk", {
        "hd_buy_potential": rng.integers(0, 6,
                                         n["household"]).astype(np.int32),
    })

    nf = n["store_sales"]
    tables["store_sales"] = from_numpy({
        "ss_item_sk": fks("ss_item_sk", nf, "item"),
        "ss_store_sk": fks("ss_store_sk", nf, "store"),
        "ss_customer_sk": fks("ss_customer_sk", nf, "customer"),
        "ss_sold_date_sk": fks("ss_sold_date_sk", nf, "date_dim"),
        "ss_promo_sk": fks("ss_promo_sk", nf, "promotion"),
        "ss_quantity": rng.integers(1, 100, nf).astype(np.int32),
        "ss_sales_price": rng.uniform(1, 300, nf).astype(np.float32),
        "ss_net_profit": rng.uniform(-50, 150, nf).astype(np.float32),
    })
    nc = n["catalog_sales"]
    tables["catalog_sales"] = from_numpy({
        "cs_item_sk": fks("cs_item_sk", nc, "item"),
        "cs_ship_date_sk": fks("cs_ship_date_sk", nc, "date_dim"),
        "cs_bill_customer_sk": fks("cs_bill_customer_sk", nc, "customer"),
        "cs_warehouse_sk": fks("cs_warehouse_sk", nc, "warehouse"),
        "cs_quantity": rng.integers(1, 100, nc).astype(np.int32),
        "cs_sales_price": rng.uniform(1, 300, nc).astype(np.float32),
    })
    ni = n["inventory"]
    tables["inventory"] = from_numpy({
        "inv_item_sk": fks("inv_item_sk", ni, "item"),
        "inv_date_sk": fks("inv_date_sk", ni, "date_dim"),
        "inv_warehouse_sk": fks("inv_warehouse_sk", ni, "warehouse"),
        "inv_quantity_on_hand": rng.integers(0, 1000, ni).astype(np.int32),
    })

    domains = {col: float(n[dim]) for col, dim in FK_DIMENSIONS.items()}
    domains.update({pk: float(n[t]) for t, pk in PRIMARY_KEYS.items()})
    return Catalog({k: partition_round_robin(t, p)
                    for k, t in tables.items()}, p, key_domains=domains,
                   column_stats=compute_column_stats(tables))


def compute_column_stats(tables: Dict[str, Table]) -> Dict[str, ColumnStats]:
    """Exact per-column statistics from unpartitioned tables: one
    ``np.unique`` pass per column feeds the compressed-multiset summary,
    finalized into NDV / MCV / equi-depth buckets."""
    stats: Dict[str, ColumnStats] = {}
    for t in tables.values():
        for col, arr in t.to_numpy().items():
            a = np.asarray(arr)
            vals, counts = np.unique(a, return_counts=True)
            summary = ColumnSummary(tuple(float(v) for v in vals),
                                    tuple(float(c) for c in counts))
            stats[col] = column_stats_from_summary(
                summary, integral=bool(np.issubdtype(a.dtype, np.integer)))
    return stats


#: fact FK column -> the dimension whose PK domain it draws from. Feeds
#: ``Catalog.key_domains`` (runtime-filter selectivity estimation).
FK_DIMENSIONS = {
    "ss_item_sk": "item", "ss_store_sk": "store",
    "ss_customer_sk": "customer", "ss_sold_date_sk": "date_dim",
    "ss_promo_sk": "promotion",
    "cs_item_sk": "item", "cs_ship_date_sk": "date_dim",
    "cs_bill_customer_sk": "customer", "cs_warehouse_sk": "warehouse",
    "inv_item_sk": "item", "inv_date_sk": "date_dim",
    "inv_warehouse_sk": "warehouse",
    "c_hdemo_sk": "household",
}

#: primary key of each dimension (build-side uniqueness contract).
PRIMARY_KEYS = {
    "customer": "c_customer_sk", "item": "i_item_sk",
    "date_dim": "d_date_sk", "store": "s_store_sk",
    "promotion": "p_promo_sk", "warehouse": "w_warehouse_sk",
    "household": "hd_demo_sk",
}

#: Static schema: ordered column names per table, exactly as ``generate``
#: builds them (pinned by a test). The SQL binder resolves unqualified
#: columns against this without needing a materialized catalog — column
#: names are globally unique across the star schema by TPC-DS convention.
TABLE_COLUMNS: Dict[str, tuple] = {
    "customer": ("c_customer_sk", "c_region", "c_hdemo_sk", "c_income"),
    "item": ("i_item_sk", "i_category", "i_brand", "i_price"),
    "date_dim": ("d_date_sk", "d_month", "d_year", "d_moy"),
    "store": ("s_store_sk", "s_state", "s_floor"),
    "promotion": ("p_promo_sk", "p_channel"),
    "warehouse": ("w_warehouse_sk", "w_state"),
    "household": ("hd_demo_sk", "hd_buy_potential"),
    "store_sales": ("ss_item_sk", "ss_store_sk", "ss_customer_sk",
                    "ss_sold_date_sk", "ss_promo_sk", "ss_quantity",
                    "ss_sales_price", "ss_net_profit"),
    "catalog_sales": ("cs_item_sk", "cs_ship_date_sk",
                      "cs_bill_customer_sk", "cs_warehouse_sk",
                      "cs_quantity", "cs_sales_price"),
    "inventory": ("inv_item_sk", "inv_date_sk", "inv_warehouse_sk",
                  "inv_quantity_on_hand"),
}

#: Non-key column value domains as ``(lo, hi, integral)`` with half-open
#: ``[lo, hi)`` bounds matching the ``generate`` draws (integers/uniform),
#: plus the computed date columns' exact ranges under the 360-day
#: calendar. ``derive_selectivity`` turns these into op-specific filter
#: fractions; because only facts scale and every distribution is uniform,
#: the derived fraction equals the measured one at any scale.
COLUMN_DOMAINS: Dict[str, tuple] = {
    "c_region": (0, 8, True), "c_income": (2e4, 2e5, False),
    "i_category": (0, 10, True), "i_brand": (0, 100, True),
    "i_price": (1, 300, False),
    "d_month": (0, 12, True), "d_year": (2000, 2001, True),
    "d_moy": (0, 30, True),
    "s_state": (0, 12, True), "s_floor": (1e3, 1e5, False),
    "p_channel": (0, 4, True), "w_state": (0, 12, True),
    "hd_buy_potential": (0, 6, True),
    "ss_quantity": (1, 100, True), "ss_sales_price": (1, 300, False),
    "ss_net_profit": (-50, 150, False),
    "cs_quantity": (1, 100, True), "cs_sales_price": (1, 300, False),
    "inv_quantity_on_hand": (0, 1000, True),
}

#: Static key domains: FK and PK columns -> domain cardinality. Dimensions
#: never scale (only FACTS do), so this is knowable without a catalog —
#: it is exactly what ``generate`` stores in ``Catalog.key_domains``.
STATIC_KEY_DOMAINS: Dict[str, float] = {
    **{col: float(SCHEMA[dim]) for col, dim in FK_DIMENSIONS.items()},
    **{pk: float(SCHEMA[t]) for t, pk in PRIMARY_KEYS.items()},
}
