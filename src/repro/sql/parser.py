"""SQL text front end, part 1: hand-written tokenizer + recursive-descent
parser producing a small AST (``SelectStmt``). The binder (``sql.binder``)
lowers the AST to the ``logical.py`` plan algebra.

Supported surface (see docs/sql_frontend.md for the full grammar):

  * ``SELECT`` list: ``*``, plain columns, aggregate calls
    (``SUM/COUNT/MIN/MAX/AVG``),
  * ``FROM``: tables, derived tables ``(SELECT ...) [AS alias]``, explicit
    ``JOIN ... ON a = b`` / ``LEFT JOIN ... ON`` chains, and implicit
    comma joins,
  * ``WHERE``: conjunctions (``AND``) of single-column comparisons
    (``= <> < <= > >=``), ``BETWEEN x AND y``, ``IN (literal list)``,
    ``[NOT] IN (subquery)`` (semi/anti joins), and column = column
    equality (implicit join predicates),
  * ``GROUP BY`` a single column.

The dialect is deliberately small — exactly the plan algebra's expressive
range — and everything outside it raises ``SqlSyntaxError`` with the
offending position rather than mis-parsing.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple, Union

__all__ = ["AGG_FUNCS", "AggCall", "ColRef", "ColumnEquals", "Comparison",
           "DerivedRef", "FromTree", "InList", "InSubquery", "JoinClause",
           "KEYWORDS", "SelectStmt", "SqlSyntaxError", "TableRef", "Token",
           "parse", "tokenize"]


class SqlSyntaxError(ValueError):
    """Raised on any text the dialect does not cover."""


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColRef:
    """A (possibly qualified) column reference: ``col`` or ``tab.col``."""

    name: str
    qualifier: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class AggCall:
    """One aggregate select item. ``func`` is the SQL name (upper-cased)."""

    func: str
    column: str


@dataclasses.dataclass(frozen=True)
class Comparison:
    """``col op literal`` (op in eq/ne/lt/le/gt/ge/between)."""

    col: ColRef
    op: str
    value: float
    value2: float = 0.0


@dataclasses.dataclass(frozen=True)
class InList:
    """``col IN (v1, v2, ...)`` over literals."""

    col: ColRef
    values: Tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class InSubquery:
    """``col [NOT] IN (SELECT ...)`` — lowers to a semi/anti join."""

    col: ColRef
    query: "SelectStmt"
    negated: bool


@dataclasses.dataclass(frozen=True)
class ColumnEquals:
    """``col1 = col2`` — an implicit equi-join predicate."""

    left: ColRef
    right: ColRef


@dataclasses.dataclass(frozen=True)
class TableRef:
    table: str
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class DerivedRef:
    query: "SelectStmt"
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class JoinClause:
    """One ``[LEFT] JOIN ref ON left = right`` link in a FROM chain."""

    kind: str  # "inner" | "left"
    ref: Union[TableRef, DerivedRef]
    left_col: ColRef
    right_col: ColRef


@dataclasses.dataclass(frozen=True)
class FromTree:
    """One comma-separated FROM item: a primary plus its JOIN chain."""

    primary: Union[TableRef, DerivedRef]
    joins: Tuple[JoinClause, ...] = ()


Predicate = Union[Comparison, InList, InSubquery, ColumnEquals]
SelectItem = Union[ColRef, AggCall]


@dataclasses.dataclass(frozen=True)
class SelectStmt:
    """One parsed SELECT statement (the AST root)."""

    items: Tuple[SelectItem, ...]   # empty iff star
    star: bool
    froms: Tuple[FromTree, ...]
    where: Tuple[Predicate, ...] = ()
    group_by: Optional[str] = None


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Token:
    kind: str   # "ident" | "number" | "symbol" | "eof"
    text: str
    pos: int


_TOKEN_RE = re.compile(r"""
    \s+
  | (?P<number>-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)
  | (?P<ident>[A-Za-z_][A-Za-z0-9_]*)
  | (?P<symbol><>|<=|>=|[(),.*=<>])
""", re.VERBOSE)

KEYWORDS = frozenset({
    "SELECT", "FROM", "WHERE", "GROUP", "BY", "JOIN", "LEFT", "OUTER",
    "ON", "AND", "BETWEEN", "IN", "NOT", "AS",
})

#: SQL aggregate function names the select list accepts.
AGG_FUNCS = ("SUM", "COUNT", "MIN", "MAX", "AVG")

_COMPARISON_OPS = {"=": "eq", "<>": "ne", "<": "lt", "<=": "le",
                   ">": "gt", ">=": "ge"}


def tokenize(text: str) -> list:
    """Scan ``text`` into tokens; raises on any unrecognized character."""
    out = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise SqlSyntaxError(
                f"unrecognized character {text[pos]!r} at position {pos}")
        if m.lastgroup == "number":
            out.append(Token("number", m.group("number"), pos))
        elif m.lastgroup == "ident":
            out.append(Token("ident", m.group("ident"), pos))
        elif m.lastgroup == "symbol":
            out.append(Token("symbol", m.group("symbol"), pos))
        pos = m.end()
    out.append(Token("eof", "", len(text)))
    return out


# ---------------------------------------------------------------------------
# Recursive-descent parser
# ---------------------------------------------------------------------------

class _Parser:
    def __init__(self, text: str):
        self.text = text
        self.tokens = tokenize(text)
        self.i = 0

    # -- token plumbing -----------------------------------------------------

    def peek(self, ahead: int = 0) -> Token:
        return self.tokens[min(self.i + ahead, len(self.tokens) - 1)]

    def advance(self) -> Token:
        tok = self.tokens[self.i]
        if tok.kind != "eof":
            self.i += 1
        return tok

    def error(self, message: str) -> SqlSyntaxError:
        tok = self.peek()
        at = f"{tok.text!r}" if tok.kind != "eof" else "end of input"
        return SqlSyntaxError(f"{message} (at {at}, position {tok.pos})")

    def at_keyword(self, word: str) -> bool:
        tok = self.peek()
        return tok.kind == "ident" and tok.text.upper() == word

    def accept_keyword(self, word: str) -> bool:
        if self.at_keyword(word):
            self.advance()
            return True
        return False

    def expect_keyword(self, word: str) -> None:
        if not self.accept_keyword(word):
            raise self.error(f"expected {word}")

    def accept_symbol(self, sym: str) -> bool:
        tok = self.peek()
        if tok.kind == "symbol" and tok.text == sym:
            self.advance()
            return True
        return False

    def expect_symbol(self, sym: str) -> None:
        if not self.accept_symbol(sym):
            raise self.error(f"expected {sym!r}")

    def expect_ident(self, what: str) -> str:
        tok = self.peek()
        if tok.kind != "ident" or tok.text.upper() in KEYWORDS:
            raise self.error(f"expected {what}")
        return self.advance().text

    def expect_number(self) -> float:
        tok = self.peek()
        if tok.kind != "number":
            raise self.error("expected a numeric literal")
        return float(self.advance().text)

    # -- grammar ------------------------------------------------------------

    def parse(self) -> SelectStmt:
        stmt = self.select_stmt()
        if self.peek().kind != "eof":
            raise self.error("trailing input after statement")
        return stmt

    def select_stmt(self) -> SelectStmt:
        self.expect_keyword("SELECT")
        star, items = False, []
        if self.accept_symbol("*"):
            star = True
        else:
            items.append(self.select_item())
            while self.accept_symbol(","):
                items.append(self.select_item())
        self.expect_keyword("FROM")
        froms = [self.from_tree()]
        while self.accept_symbol(","):
            froms.append(self.from_tree())
        where: list = []
        if self.accept_keyword("WHERE"):
            where.append(self.predicate())
            while self.accept_keyword("AND"):
                where.append(self.predicate())
        group_by = None
        if self.accept_keyword("GROUP"):
            self.expect_keyword("BY")
            group_by = self.expect_ident("a group-by column")
        return SelectStmt(tuple(items), star, tuple(froms), tuple(where),
                          group_by)

    def select_item(self) -> SelectItem:
        tok = self.peek()
        if (tok.kind == "ident" and tok.text.upper() in AGG_FUNCS
                and self.peek(1).kind == "symbol"
                and self.peek(1).text == "("):
            func = self.advance().text.upper()
            self.expect_symbol("(")
            col = self.expect_ident("an aggregate argument column")
            self.expect_symbol(")")
            return AggCall(func, col)
        return self.col_ref()

    def col_ref(self) -> ColRef:
        first = self.expect_ident("a column name")
        if self.accept_symbol("."):
            return ColRef(self.expect_ident("a column name"), first)
        return ColRef(first)

    def from_tree(self) -> FromTree:
        primary = self.primary()
        joins = []
        while True:
            if self.accept_keyword("JOIN"):
                kind = "inner"
            elif self.at_keyword("LEFT"):
                self.advance()
                self.accept_keyword("OUTER")
                self.expect_keyword("JOIN")
                kind = "left"
            else:
                break
            ref = self.primary()
            self.expect_keyword("ON")
            left = self.col_ref()
            self.expect_symbol("=")
            right = self.col_ref()
            joins.append(JoinClause(kind, ref, left, right))
        return FromTree(primary, tuple(joins))

    def primary(self) -> Union[TableRef, DerivedRef]:
        if self.accept_symbol("("):
            stmt = self.select_stmt()
            self.expect_symbol(")")
            return DerivedRef(stmt, self.maybe_alias())
        table = self.expect_ident("a table name")
        return TableRef(table, self.maybe_alias())

    def maybe_alias(self) -> Optional[str]:
        if self.accept_keyword("AS"):
            return self.expect_ident("an alias")
        tok = self.peek()
        if tok.kind == "ident" and tok.text.upper() not in KEYWORDS:
            return self.advance().text
        return None

    def predicate(self) -> Predicate:
        col = self.col_ref()
        negated = self.accept_keyword("NOT")
        if self.accept_keyword("IN"):
            return self.in_predicate(col, negated)
        if negated:
            raise self.error("NOT is only supported as NOT IN")
        if self.accept_keyword("BETWEEN"):
            lo = self.expect_number()
            self.expect_keyword("AND")
            hi = self.expect_number()
            return Comparison(col, "between", lo, hi)
        tok = self.peek()
        if tok.kind == "symbol" and tok.text in _COMPARISON_OPS:
            op = _COMPARISON_OPS[self.advance().text]
            if self.peek().kind == "number":
                return Comparison(col, op, self.expect_number())
            if op == "eq":
                return ColumnEquals(col, self.col_ref())
            raise self.error("column-to-column predicates support only =")
        raise self.error("expected a comparison operator, BETWEEN or IN")

    def in_predicate(self, col: ColRef, negated: bool) -> Predicate:
        self.expect_symbol("(")
        if self.at_keyword("SELECT"):
            stmt = self.select_stmt()
            self.expect_symbol(")")
            return InSubquery(col, stmt, negated)
        if negated:
            raise self.error("NOT IN is only supported with a subquery")
        values = [self.expect_number()]
        while self.accept_symbol(","):
            values.append(self.expect_number())
        self.expect_symbol(")")
        return InList(col, tuple(values))


def parse(text: str) -> SelectStmt:
    """Parse one SELECT statement into its AST."""
    return _Parser(text).parse()
