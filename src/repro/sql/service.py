"""Concurrent query service: N queries, one catalog, shared work.

The production regime the ROADMAP targets is many simultaneous queries,
not one fast query. ``QueryService`` admits a set of queries (SQL text or
logical plans) against one catalog/mesh and executes them as a batch,
amortizing work three ways:

  1. **Plan cache** (``planner.PlanCache``) — compiled plans keyed on
     ``logical.signature()`` + every optimize() knob, bound to the catalog
     identity fingerprint exactly like ``FilterCache``. A warm submission
     skips the whole rewrite + System-R DP pass.
  2. **Cross-query CSE** — identical exchange-rooted subtrees (Join /
     Aggregate, enumerated by ``logical.shared_subtree_candidates``) are
     deduped by subtree signature: each shared subtree executes **once**
     per batch and its materialized table fans out to every consumer via
     the Executor's ``intermediates`` injection. Tables are immutable, so
     fan-out is aliasing, not copying.
  3. **Shared FilterCache** — one cross-query ``FilterCache`` spans the
     batch, so a filter payload built for one query's edge is reused by
     every later query with the same build leaf (PR 5's warm-run result,
     now intra-batch).
  4. **Admission control** — submissions queue through a deque (the
     ``ServeEngine`` admission structure) and batches form under a cost
     budget quoted by ``planner.modeled_plan_cost`` — the RelJoin cost
     model's static workload estimate, comparable across queries on the
     same catalog.

Correctness contract: per-query results are identical to solo execution
(``execute_solo``). CSE only dedupes occurrences that solo execution
evaluates as a self-contained exchange boundary (the region-atomicity
rule in ``shared_subtree_candidates``), runtime filters never change
result rows, and the service optimizes with ``prune=False`` — projection
pruning narrows scans per *whole-plan* column sets, which would make
structurally-shared subtrees signature-distinct (the classic CSE /
column-pruning tension; a shared subtree must carry every column any
consumer needs).

Run ``python -m repro.sql.service`` for the standalone CI pass: the
service suite (q19-q23 + the deliberately-overlapping q33/q34) executes
batched with ``verify=True`` plan-analysis gates armed on every plan, and
every query's rows are checked against its solo run.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Tuple, Union

from ..core.cost_model import CostParams
from ..joins.table import Table
from .binder import parse_sql
from .datagen import Catalog
from .executor import ExecutionResult, Executor
from .logical import Node, shared_subtree_candidates, subtree_size
from .planner import (OptimizedPlan, PlanCache, catalog_base_stats,
                      catalog_schema, modeled_plan_cost, optimize)
from .runtime_filters import FilterCache
from .strategies import FilteredStrategy, RelJoinStrategy, Strategy

#: Admission policies: ``fifo`` preserves submission order; ``cost``
#: stably reorders each batch cheapest-quote-first (small interactive
#: queries are not stuck behind a scan-heavy report).
ADMISSION_POLICIES = ("fifo", "cost")


@dataclasses.dataclass
class Submission:
    """One admitted query: its compiled plan + admission metadata."""

    qid: int
    name: str
    plan: Node                 # logical plan as submitted (pre-rewrite)
    optimized: OptimizedPlan   # compiled plan (possibly from the PlanCache)
    quoted_cost: float         # modeled_plan_cost — the admission quote
    plan_cached: bool          # True when optimize() was skipped entirely


@dataclasses.dataclass
class SharedSubtree:
    """One deduped subtree: executed once, fanned out to its consumers."""

    sig: str
    node: Node
    consumers: Tuple[str, ...]  # query names containing the subtree
    occurrences: int            # total occurrences across the batch (>= 2)
    result: ExecutionResult     # the single producer execution


@dataclasses.dataclass
class BatchReport:
    """Everything one batch did: per-query results + shared-work audit."""

    results: Dict[str, ExecutionResult]
    shared: List[SharedSubtree]
    wall_time_s: float

    @property
    def total_network_bytes(self) -> float:
        """Suite wire traffic: every shared producer once + every consumer
        (whose injected subtrees moved zero bytes)."""
        return (sum(s.result.network_bytes for s in self.shared)
                + sum(r.network_bytes for r in self.results.values()))

    @property
    def queries_per_second(self) -> float:
        if self.wall_time_s <= 0:
            return float("inf")
        return len(self.results) / self.wall_time_s


class AdmissionController:
    """Cost-budgeted batch former over a deque admission queue.

    ``next_batch`` pops submissions while the batch's summed quotes stay
    within ``budget`` (None = unbounded: one batch takes everything). A
    single over-budget query is still admitted *alone* — a budget below
    every quote must not live-lock the queue. ``policy="cost"`` stably
    sorts the queue cheapest-first before popping; ``"fifo"`` preserves
    submission order (the ``ServeEngine.submit`` discipline).
    """

    def __init__(self, budget: Optional[float] = None,
                 policy: str = "fifo") -> None:
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {ADMISSION_POLICIES}")
        self.budget = budget
        self.policy = policy
        self.queue: Deque[Submission] = collections.deque()

    def __len__(self) -> int:
        return len(self.queue)

    def submit(self, sub: Submission) -> None:
        self.queue.append(sub)

    def next_batch(self) -> List[Submission]:
        if self.policy == "cost" and len(self.queue) > 1:
            # Stable: equal quotes keep submission order.
            self.queue = collections.deque(
                sorted(self.queue, key=lambda s: s.quoted_cost))
        batch: List[Submission] = []
        spent = 0.0
        while self.queue:
            head = self.queue[0]
            if (batch and self.budget is not None
                    and spent + head.quoted_cost > self.budget):
                break
            batch.append(self.queue.popleft())
            spent += head.quoted_cost
        return batch


class QueryService:
    """Multi-tenant batched query execution against one catalog/mesh.

    ``submit()`` compiles (or plan-cache-fetches) each query and quotes
    its admission cost; ``run()`` drains the admission queue in budgeted
    batches, deduping shared subtrees per batch. ``execute_solo()`` is
    the reference path — one query, cold caches, same optimizer settings
    — that batched results are checked against.
    """

    def __init__(self, catalog: Catalog, *,
                 strategy: Optional[Strategy] = None,
                 cost_budget: Optional[float] = None,
                 policy: str = "fifo",
                 cse: bool = True,
                 verify: bool = False,
                 adaptive: bool = True) -> None:
        self.catalog = catalog
        # One FilterCache spans the batch: respect a cache the caller's
        # strategy already carries, otherwise own a fresh one.
        cache = getattr(strategy, "filter_cache", None)
        self.filter_cache: FilterCache = (cache if cache is not None
                                          else FilterCache())
        if strategy is None:
            strategy = FilteredStrategy(RelJoinStrategy(),
                                        cache=self.filter_cache)
        self.strategy = strategy
        self.plan_cache = PlanCache()
        self.cse = cse
        self.verify = verify
        self.adaptive = adaptive
        self.admission = AdmissionController(cost_budget, policy)
        self._schema = catalog_schema(catalog)
        self._base_stats = catalog_base_stats(catalog)
        self._params = CostParams(p=catalog.p,
                                  w=getattr(strategy, "w", 1.0))
        self._qid = 0

    # -- admission -------------------------------------------------------------

    def submit(self, query: Union[str, Node],
               name: Optional[str] = None) -> Submission:
        """Admit one query (SQL text or logical plan): lower, compile (or
        hit the plan cache), quote, enqueue."""
        plan = parse_sql(query) if isinstance(query, str) else query
        hits_before = self.plan_cache.hits
        optimized = self._optimize(plan, plan_cache=self.plan_cache)
        sub = Submission(
            qid=self._qid,
            name=name if name is not None else f"q{self._qid}",
            plan=plan,
            optimized=optimized,
            quoted_cost=modeled_plan_cost(optimized.plan, self._base_stats,
                                          self._schema, self._params,
                                          self.catalog.key_domains),
            plan_cached=self.plan_cache.hits > hits_before)
        self._qid += 1
        self.admission.submit(sub)
        return sub

    def _optimize(self, plan: Node,
                  plan_cache: Optional[PlanCache] = None) -> OptimizedPlan:
        # prune=False: projection pruning would specialize shared subtrees
        # per consumer column set and defeat CSE (module docstring).
        return optimize(plan, self.catalog, params=self._params,
                        prune=False, verify=self.verify,
                        plan_cache=plan_cache)

    def _executor(self, intermediates: Optional[Dict[str, Table]] = None
                  ) -> Executor:
        return Executor(self.catalog, self.strategy, adaptive=self.adaptive,
                        verify=True if self.verify else None,
                        intermediates=intermediates)

    # -- execution -------------------------------------------------------------

    def run(self) -> List[BatchReport]:
        """Drain the admission queue: one ``BatchReport`` per cost-budgeted
        batch, in admission order."""
        reports = []
        while len(self.admission):
            reports.append(self._execute_batch(self.admission.next_batch()))
        return reports

    def _execute_batch(self, batch: List[Submission]) -> BatchReport:
        t0 = time.perf_counter()
        intermediates: Dict[str, Table] = {}
        shared: List[SharedSubtree] = []
        if self.cse:
            # Count every candidate occurrence across the batch (intra-query
            # duplicates count too — two occurrences in one plan still share).
            info: Dict[str, list] = {}
            for sub in batch:
                for sig, node in shared_subtree_candidates(
                        sub.optimized.plan):
                    entry = info.setdefault(sig, [node, 0, []])
                    entry[1] += 1
                    if sub.name not in entry[2]:
                        entry[2].append(sub.name)
            shared_sigs = [s for s, e in info.items() if e[1] >= 2]
            # Producers run smallest-first so a shared subtree nested inside
            # a larger shared subtree is already injectable when the larger
            # one executes.
            for sig in sorted(shared_sigs,
                              key=lambda s: subtree_size(info[s][0])):
                node, count, consumers = info[sig]
                res = self._executor(intermediates).execute(node)
                intermediates[sig] = res.table
                shared.append(SharedSubtree(sig, node, tuple(consumers),
                                            count, res))
        results: Dict[str, ExecutionResult] = {}
        for sub in batch:
            results[sub.name] = self._executor(intermediates).execute(
                sub.optimized.plan)
        return BatchReport(results, shared, time.perf_counter() - t0)

    def execute_solo(self, query: Union[str, Node]) -> ExecutionResult:
        """Reference single-query execution: same optimizer settings, but
        no plan cache, no injected intermediates, and a *fresh* FilterCache
        — the result batched execution must reproduce."""
        plan = parse_sql(query) if isinstance(query, str) else query
        optimized = self._optimize(plan)
        strategy = self.strategy
        if isinstance(strategy, FilteredStrategy):
            strategy = dataclasses.replace(strategy, cache=FilterCache())
        ex = Executor(self.catalog, strategy, adaptive=self.adaptive,
                      verify=True if self.verify else None)
        return ex.execute(optimized.plan)

    # -- stats publish ---------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        """Service-lifetime cache counters (the lifecycle's publish step)."""
        return {
            "plan_cache_hits": self.plan_cache.hits,
            "plan_cache_misses": self.plan_cache.misses,
            "plan_cache_size": len(self.plan_cache),
            "filter_cache_hits": self.filter_cache.hits,
            "filter_cache_misses": self.filter_cache.misses,
            "queries_submitted": self._qid,
        }


def main() -> int:
    """Standalone CI pass: the service suite batched with verify gates
    armed on every executed plan, rows checked against solo runs."""
    from ..joins.ref import rows_as_set, rows_close
    from .datagen import generate
    from .queries import service_queries

    catalog = generate(scale=0.05, p=4, seed=11)
    service = QueryService(catalog, verify=True)
    queries = service_queries()
    for qname, plan in queries.items():
        service.submit(plan, name=qname)
    reports = service.run()
    assert len(reports) == 1, "unbudgeted run should form one batch"
    report = reports[0]

    failures = []
    if not report.shared:
        failures.append("no shared subtrees deduped across the suite")
    serial_bytes = 0.0
    serial_joins = 0
    for qname in queries:
        solo = service.execute_solo(queries[qname])
        serial_bytes += solo.network_bytes
        serial_joins += len(solo.decisions)
        batched = report.results[qname]
        a = rows_as_set(solo.table.to_numpy())
        b = rows_as_set(batched.table.to_numpy())
        if not rows_close(a, b):
            failures.append(f"{qname}: batched rows differ from solo")
    batch_joins = (sum(len(s.result.decisions) for s in report.shared)
                   + sum(len(r.decisions) for r in report.results.values()))
    if batch_joins >= serial_joins:
        failures.append(f"dedup ran no fewer joins than serial "
                        f"({batch_joins} >= {serial_joins})")
    if report.total_network_bytes >= serial_bytes:
        failures.append(f"batched bytes not below serial "
                        f"({report.total_network_bytes:.0f} >= "
                        f"{serial_bytes:.0f})")
    print(f"service CI pass: {len(queries)} queries, "
          f"{len(report.shared)} shared subtrees, "
          f"{batch_joins}/{serial_joins} joins, "
          f"{report.total_network_bytes:.0f}/{serial_bytes:.0f} bytes, "
          f"stats={service.stats()}")
    for f in failures:
        print(f"FAIL: {f}")
    return 1 if failures else 0


__all__ = ["ADMISSION_POLICIES", "AdmissionController", "BatchReport",
           "QueryService", "SharedSubtree", "Submission", "main"]


if __name__ == "__main__":
    raise SystemExit(main())
