"""Logical query plans (paper §2.2): operator trees that determine the
result but not the physical methods. Joins and aggregations are the
exchange boundaries that split the plan into query stages (§2.3)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..core.cost_model import JoinMethod
from ..core.selection import JoinType


@dataclasses.dataclass(frozen=True)
class Node:
    """Base logical operator."""

    def children(self) -> tuple:
        return ()


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    table: str


@dataclasses.dataclass(frozen=True)
class Filter(Node):
    """Single-column predicate. ``op`` is one of ``eq | ne | lt | le | gt |
    ge | between | in | eqcol``; ``value2`` is BETWEEN's upper bound and
    ``values`` IN's literal list (both ignored by the other ops).
    ``eqcol`` is the one column-to-column op: it keeps rows where
    ``column == column2`` — the closing edge of a cyclic join core, which
    the binary engine can only evaluate as a post-join residual predicate.
    ``selectivity`` is the declared static estimate — ``None`` means
    *underived*, and every consumer goes through
    :func:`effective_selectivity`, which falls back to the schema-derived
    estimate (``sql.selectivity.derive_selectivity``).
    """

    child: Node
    column: str
    op: str
    value: float = 0.0
    value2: float = 0.0
    values: Tuple[float, ...] = ()
    selectivity: Optional[float] = None
    column2: Optional[str] = None  # eqcol's right-hand column

    def children(self):
        return (self.child,)


def effective_selectivity(f: Filter) -> float:
    """The selectivity estimate a plan consumer should use: the declared
    value when present, else the op/domain-derived one (declared wins)."""
    if f.selectivity is not None:
        return f.selectivity
    from .selectivity import derive_selectivity
    return derive_selectivity(f)


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    columns: Tuple[str, ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Join(Node):
    """Logical equi-join; left is the plan-order probe side."""

    left: Node
    right: Node
    left_key: str
    right_key: str
    join_type: JoinType = JoinType.INNER
    hint: Optional[JoinMethod] = None

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class Aggregate(Node):
    """Group-by aggregation (an exchange boundary, like Join)."""

    child: Node
    key: str                              # group key column
    aggs: Tuple[Tuple[str, str], ...]     # (column, op) pairs

    def children(self):
        return (self.child,)


def _fmt_literal(v: float) -> str:
    """Compact literal rendering for signatures (``6`` not ``6.0``)."""
    return f"{v:g}"


def filter_literal(f: Filter) -> str:
    """The literal part of a Filter's signature tag: BETWEEN's two bounds,
    IN's value list, eqcol's right-hand column, or the single comparison
    constant."""
    if f.op == "between":
        return f"{_fmt_literal(f.value)}:{_fmt_literal(f.value2)}"
    if f.op == "in":
        return ",".join(_fmt_literal(v) for v in f.values)
    if f.op == "eqcol":
        return str(f.column2)
    return _fmt_literal(f.value)


def signature(plan: Node) -> str:
    """Canonical one-line structural signature of a logical plan. Captures
    join order, join keys/types, filter predicates *including their
    literals*, aggregate specs, and operator nesting — what the golden-plan
    snapshots pin so optimizer edits can't silently reorder a plan.
    (Literals and agg specs matter: two plans differing only in a constant
    or in which column they sum are different plans, and signature-keyed
    consumers — the plan cache, cross-query CSE — must never collide
    them.)"""
    if isinstance(plan, Scan):
        return plan.table
    if isinstance(plan, Filter):
        return (f"filter[{plan.column} {plan.op} {filter_literal(plan)}]"
                f"({signature(plan.child)})")
    if isinstance(plan, Project):
        return f"project[{','.join(plan.columns)}]({signature(plan.child)})"
    if isinstance(plan, Aggregate):
        specs = ",".join(f"{op}:{col}" for col, op in plan.aggs)
        return f"agg[{plan.key};{specs}]({signature(plan.child)})"
    if isinstance(plan, Join):
        tag = f"{plan.left_key}={plan.right_key}"
        if plan.join_type is not JoinType.INNER:
            tag += f",{plan.join_type.value}"
        return f"join[{tag}]({signature(plan.left)},{signature(plan.right)})"
    raise TypeError(f"unknown plan node {type(plan)}")


def count_joins(plan: Node) -> int:
    n = 1 if isinstance(plan, Join) else 0
    return n + sum(count_joins(c) for c in plan.children())


def walk(plan: Node):
    yield plan
    for c in plan.children():
        yield from walk(c)


def walk_paths(plan: Node, path: str = "root"):
    """Pre-order walk yielding ``(path, node)`` pairs, where ``path`` is a
    dotted locator like ``root.left.child`` — the plan path the static
    analyzer attaches to every violation so a failing rule names the exact
    operator, not just the plan."""
    yield path, plan
    if isinstance(plan, Join):
        yield from walk_paths(plan.left, path + ".left")
        yield from walk_paths(plan.right, path + ".right")
    else:
        for c in plan.children():
            yield from walk_paths(c, path + ".child")


# ---------------------------------------------------------------------------
# Distribution property lattice (plan analysis support): how an operator's
# output is laid out across the engine's p partitions — Spark
# EnsureRequirements-style physical properties, used by the plan analyzer
# to prove every exchange of a chosen join method necessary (no missing
# shuffle) and sufficient (no redundant re-shuffle of a side already
# hash-partitioned on its join key).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Distribution:
    """Physical data-distribution property of an operator's output.

    ``kind`` is one of:

      * ``"hash"`` — rows are hash-partitioned by column ``key`` (the
        output-partitioning property a shuffle on ``key`` establishes;
        ``Table.partitioned_by`` is its runtime shadow),
      * ``"broadcast"`` — every partition holds a full replica,
      * ``"singleton"`` — all rows live in one partition,
      * ``"cube"`` — hypercube layout: hash-partitioned by ``key`` along
        one cube axis and *replicated* along the others (the state a
        ``hypercube_shuffle`` establishes). Replication means a plain
        shuffle on ``key`` can NOT be elided — rows exist on several
        partitions — so the property never satisfies ``partitioned_on``,
      * ``"arbitrary"`` — no guarantee (round-robin placement, salted
        shuffles, or any layout the analyzer cannot prove stronger).

    The lattice order is arbitrary < {hash(key), broadcast, singleton}:
    ``arbitrary`` is the sound fallback whenever inference loses track.
    """

    kind: str
    key: Optional[str] = None

    def partitioned_on(self, key: str) -> bool:
        """True iff rows are provably hash-partitioned by ``key`` — the
        condition under which a shuffle on ``key`` may be elided."""
        return self.kind == "hash" and self.key == key


#: The bottom of the lattice: no layout guarantee.
ARBITRARY = Distribution("arbitrary")
BROADCAST = Distribution("broadcast")
SINGLETON = Distribution("singleton")


def hash_dist(key: str) -> Distribution:
    """Hash-partitioned-on-``key`` distribution."""
    return Distribution("hash", key)


def cube_dist(key: str) -> Distribution:
    """Cube-partitioned distribution: hashed on ``key`` along one hypercube
    axis, replicated along the rest. Strictly weaker than ``hash(key)`` for
    exchange elision (see the class docstring)."""
    return Distribution("cube", key)


def infer_distribution(node: Node) -> Distribution:
    """Static bottom-up distribution inference over a logical plan.

    Mirrors the engine's output-partitioning rules where the logical plan
    determines them (scans land round-robin; filters preserve placement;
    a projection keeps the hash property only while the key survives;
    a group-by shuffles by its group key) and falls back to ARBITRARY for
    joins, whose output distribution depends on the physical method —
    :func:`join_output_distribution` resolves those once a method is known.
    """
    if isinstance(node, Scan):
        return ARBITRARY
    if isinstance(node, Filter):
        return infer_distribution(node.child)
    if isinstance(node, Project):
        d = infer_distribution(node.child)
        if d.kind == "hash" and d.key not in node.columns:
            return ARBITRARY
        return d
    if isinstance(node, Aggregate):
        return hash_dist(node.key)
    if isinstance(node, Join):
        return ARBITRARY
    raise TypeError(f"unknown plan node {type(node)}")


def join_output_distribution(method: JoinMethod, probe: Distribution,
                             probe_key: str) -> Distribution:
    """Output distribution of one physical join, given the probe (plan
    left) side's input distribution — the engine's rules in
    ``joins/methods.py``: broadcast-family joins leave the probe side in
    place (its distribution survives), shuffle hash/sort co-partition both
    sides by the probe key, and salted or cartesian placement is
    key-independent."""
    if method in (JoinMethod.BROADCAST_HASH, JoinMethod.BROADCAST_NL):
        return probe
    if method in (JoinMethod.SHUFFLE_HASH, JoinMethod.SHUFFLE_SORT):
        return hash_dist(probe_key)
    return ARBITRARY


# ---------------------------------------------------------------------------
# Join-graph extraction (planner support): a *join region* is a maximal
# subtree of hint-free INNER joins. Its leaves are the region's base
# relations (scans, filter chains, projections, aggregates, or non-inner
# join subtrees); its edges carry the equi-join keys, oriented probe ->
# build (the build side's key is unique by the engine contract).
# ---------------------------------------------------------------------------

#: table name -> ordered column names; the planner derives it from a Catalog.
Schema = dict


def leaf_columns(node: Node, schema: Schema) -> Tuple[str, ...]:
    """Output column names of a subtree (mirrors executor semantics,
    including the ``_r`` rename of colliding build columns and the
    ``_matched`` flag of left-outer joins)."""
    if isinstance(node, Scan):
        return tuple(schema[node.table])
    if isinstance(node, Filter):
        return leaf_columns(node.child, schema)
    if isinstance(node, Project):
        return tuple(node.columns)
    if isinstance(node, Aggregate):
        return (node.key,) + tuple(f"{op}_{col}" for col, op in node.aggs)
    if isinstance(node, Join):
        left = leaf_columns(node.left, schema)
        if node.join_type in (JoinType.LEFT_SEMI, JoinType.LEFT_ANTI):
            return left
        out = list(left)
        for c in leaf_columns(node.right, schema):
            out.append(c if c not in out else f"{c}_r")
        if node.join_type is JoinType.LEFT_OUTER:
            out.append(f"{node.right_key}_matched")
        return tuple(out)
    raise TypeError(f"unknown plan node {type(node)}")


def filter_chain(node: Node):
    """Split the conjunctive filter list off the top of a subtree.

    Returns ``(base, filters)`` where ``filters`` is the outermost-first
    list of Filter specs and ``base`` is the first non-Filter descendant.
    """
    filters = []
    while isinstance(node, Filter):
        filters.append(node)
        node = node.child
    return node, filters


def leaf_retain_fraction(node: Node) -> float:
    """Fraction of the leaf's key domain surviving its filter chain —
    the fk_selectivity a probe side experiences when joining this leaf
    (key-uniformity assumption; 1.0 for unfiltered leaves)."""
    base, filters = filter_chain(node)
    frac = 1.0
    for f in filters:
        frac *= min(max(effective_selectivity(f), 0.0), 1.0)
    if isinstance(base, Project):
        frac *= leaf_retain_fraction(base.child)
    return frac


def key_retain_fraction(node: Node, key: str) -> float:
    """Fraction of ``key``'s domain surviving the leaf's filters, looking
    *through* aggregates: a group key survives grouping, so a filter on it
    below the Aggregate still thins the key set the leaf exposes.
    Below an Aggregate only filters on the group key itself count — a
    predicate on any other input column thins groups sub-proportionally
    (a group survives if any of its rows does), so assuming full retention
    there is the conservative choice."""
    base, filters = filter_chain(node)
    frac = 1.0
    for f in filters:
        frac *= min(max(effective_selectivity(f), 0.0), 1.0)
    if isinstance(base, Project):
        frac *= key_retain_fraction(base.child, key)
    elif isinstance(base, Aggregate) and base.key == key:
        frac *= _key_filter_fraction(base.child, key)
    return frac


def _key_filter_fraction(node: Node, key: str) -> float:
    """Product of selectivities of filters *on ``key`` itself* in a
    subtree, descending through projections and same-key aggregates."""
    base, filters = filter_chain(node)
    frac = 1.0
    for f in filters:
        if f.column == key:
            frac *= min(max(effective_selectivity(f), 0.0), 1.0)
    if isinstance(base, Project):
        frac *= _key_filter_fraction(base.child, key)
    elif isinstance(base, Aggregate) and base.key == key:
        frac *= _key_filter_fraction(base.child, key)
    return frac


#: Filter ops whose survivors form one contiguous interval of the column.
_BAND_OPS = ("eq", "lt", "le", "gt", "ge", "between")


def key_band_fraction(node: Node, key: str) -> Optional[float]:
    """Zone-map applicability test: the estimated width of the interval
    the leaf's surviving ``key`` values span, as a fraction of the domain.

    A leaf is *band-shaped* in its key iff its filter chain constrains the
    key **itself** with range predicates (TPC-DS date windows filter
    ``d_date_sk`` between two dates): the surviving key set is then one
    contiguous interval whose width is the product of those predicates'
    selectivities — the zone map's kept fraction, exactly. Filters on
    other columns thin the key set *within* the band but cannot shrink
    its min/max span, so they do not tighten the estimate. Returns None
    when no range predicate on the key exists (min/max would span ~the
    whole domain — a zone map has nothing to cut)."""
    base, filters = filter_chain(node)
    frac = None
    for f in filters:
        if f.column == key and f.op in _BAND_OPS:
            s = min(max(effective_selectivity(f), 0.0), 1.0)
            frac = s if frac is None else frac * s
    child = None
    if isinstance(base, Project):
        child = key_band_fraction(base.child, key)
    elif isinstance(base, Aggregate) and base.key == key:
        child = key_band_fraction(base.child, key)
    if child is not None:
        frac = child if frac is None else frac * child
    return frac


@dataclasses.dataclass(frozen=True)
class JoinEdge:
    """One equi-join predicate, oriented probe -> build (unique-key side)."""

    probe: int       # leaf index of the probe-side relation
    build: int       # leaf index of the build-side (unique key) relation
    probe_key: str
    build_key: str
    derived: bool = False  # inferred through a key equivalence class


@dataclasses.dataclass
class JoinGraph:
    """A join region: leaves + oriented equi-join edges + the plan's tree.

    ``tree`` is the written join order: either a leaf index or a tuple
    ``(left_tree, right_tree, edge_index)``.
    """

    leaves: list
    edges: list
    tree: object

    @property
    def n(self) -> int:
        return len(self.leaves)


class _ExtractionBailout(Exception):
    """Region not safely reorderable (ambiguous or missing key ownership)."""


def _is_region_join(node: Node) -> bool:
    return (isinstance(node, Join) and node.join_type is JoinType.INNER
            and node.hint is None)


def extract_join_graph(root: Node, schema: Schema) -> Optional[JoinGraph]:
    """Extract the join region rooted at ``root``.

    Returns None when ``root`` is not a reorderable join, when key ownership
    is ambiguous (a join key appearing in several leaves), or when leaves
    share column names (the executor's collision renames would be
    order-dependent).
    """
    if not _is_region_join(root):
        return None
    leaves: list = []
    cols: list = []
    edges: list = []

    def owner(leaf_set, key):
        found = [i for i in leaf_set if key in cols[i]]
        if len(found) != 1:
            raise _ExtractionBailout(key)
        return found[0]

    def leaf_set(tree):
        if isinstance(tree, int):
            return (tree,)
        return leaf_set(tree[0]) + leaf_set(tree[1])

    def go(n):
        if _is_region_join(n):
            lt = go(n.left)
            rt = go(n.right)
            e = JoinEdge(owner(leaf_set(lt), n.left_key),
                         owner(leaf_set(rt), n.right_key),
                         n.left_key, n.right_key)
            edges.append(e)
            return (lt, rt, len(edges) - 1)
        i = len(leaves)
        leaves.append(n)
        cols.append(frozenset(leaf_columns(n, schema)))
        return i

    try:
        tree = go(root)
    except (_ExtractionBailout, KeyError, TypeError):
        return None
    total = sum(len(c) for c in cols)
    if len(frozenset().union(*cols)) != total:  # cross-leaf name collision
        return None
    return JoinGraph(leaves, edges, tree)


def subtree_size(plan: Node) -> int:
    """Operator count of a subtree — the size order cross-query CSE uses
    to execute nested shared subtrees before the subtrees containing them."""
    return 1 + sum(subtree_size(c) for c in plan.children())


def shared_subtree_candidates(plan: Node):
    """Enumerate the subtree occurrences cross-query CSE may dedupe, as
    ``(signature, node)`` pairs (one pair per occurrence — a signature
    appearing twice in one plan yields two pairs).

    A candidate must be *worth sharing* and *safe to share*:

      * **Exchange-rooted** (Join or Aggregate): only subtrees containing
        at least one exchange save network bytes when deduped; scans and
        filter chains are free to re-evaluate.
      * **Region-atomic**: solo execution must evaluate the occurrence as
        a unit for an injected result to be byte-identical. The executor
        flattens maximal hint-free INNER-join regions for reordering and
        leaf-level filter placement (``extract_join_graph``), so an inner
        hint-free join nested *directly under* another inner hint-free
        join is not a unit — it dissolves into its parent's region and
        would be re-ordered/filtered across its own boundary. Every other
        position (under a Filter/Project/Aggregate, under a hinted or
        non-inner join, or at the root) is a region leaf or a region root,
        which the executor evaluates via a single ``_eval`` call.

    Exclusion is conservative: a non-atomic occurrence is merely not
    shared, never shared wrongly.
    """

    def go(node: Node, parent: Optional[Node]):
        if isinstance(node, (Join, Aggregate)):
            dissolves = (_is_region_join(node) and parent is not None
                         and _is_region_join(parent))
            if not dissolves:
                yield signature(node), node
        for child in node.children():
            yield from go(child, node)

    yield from go(plan, None)


def cyclic_core(n: int, pairs) -> frozenset:
    """Cycle detection over a join region: the 2-core of the undirected
    simple graph on ``n`` leaves with the given ``(u, v)`` edge pairs —
    join-graph edges plus the closing column-equality (eqcol) edges.

    Iteratively strips degree-<=1 vertices; whatever survives lies on at
    least one cycle. Returns the surviving leaf set (empty for acyclic
    regions). A triangle or clique query's relations all survive; a star
    or chain strips to nothing — exactly the shapes where the hypercube
    multi-way plan is (resp. is not) worth quoting."""
    adj: dict = {i: set() for i in range(n)}
    for u, v in pairs:
        if u != v:
            adj[u].add(v)
            adj[v].add(u)
    alive = set(range(n))
    changed = True
    while changed:
        changed = False
        for v in list(alive):
            deg = sum(1 for u in adj[v] if u in alive)
            if deg <= 1:
                alive.remove(v)
                changed = True
    return frozenset(alive)


def key_equivalence_classes(graph: JoinGraph):
    """Union-find over (leaf, column) pairs: keys equated by the region's
    equi-join predicates, transitively (paper §2.2's equivalence of join
    attributes across a multi-join query)."""
    parent = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        parent[find(a)] = find(b)

    for e in graph.edges:
        union((e.probe, e.probe_key), (e.build, e.build_key))
    classes = {}
    for x in list(parent):
        classes.setdefault(find(x), set()).add(x)
    return [c for c in classes.values() if len(c) > 1]


def unique_key_sides(graph: JoinGraph):
    """(leaf, column) pairs whose values are unique within the leaf: build
    sides of the written joins (engine contract) plus aggregate group keys."""
    unique = {(e.build, e.build_key) for e in graph.edges}
    for i, leaf in enumerate(graph.leaves):
        base, _ = filter_chain(leaf)
        if isinstance(base, Aggregate):
            unique.add((i, base.key))
    return unique


@dataclasses.dataclass(frozen=True)
class RuntimeFilter:
    """A planned runtime-filter pushdown on one join-graph edge.

    The filter is built over the build leaf's join-key column and applied
    to the probe leaf's key column *at the leaf* — below every exchange the
    probe side subsequently goes through, which is what makes it sideways
    information passing rather than an ordinary join predicate. Edges
    derived through key equivalence classes (``derived=True``) push a
    dimension's filter onto relations it is never directly joined with.

    ``kind`` names the physical filter the planner priced cheapest for the
    edge — ``"bloom"`` (m-bit array, k hashes), ``"zone_map"`` (min/max
    interval) or ``"semi_join"`` (exact sorted key list). ``m_bits`` is the
    *serialized wire size in bits* for every kind (the quantity the cost
    model broadcasts); ``k`` is bloom's hash count, 0 for the others.
    """

    probe: int          # leaf index whose rows are filtered
    build: int          # leaf index whose keys define membership
    probe_key: str
    build_key: str
    m_bits: int         # serialized filter size in bits
    k: int              # hash count (bloom) — 0 for other kinds
    sigma_est: float    # estimated true match fraction of probe rows
    keep_est: float     # planned kept fraction (kind-specific floor)
    benefit: float      # modeled workload saved on the filtered join
    cost: float         # modeled workload of building + shipping the filter
    derived: bool = False
    kind: str = "bloom"
    #: True when the planner found the payload in the cross-query
    #: ``FilterCache`` and quoted the edge at ``cached_filter_cost``
    #: (broadcast only — no build, no reduce tree).
    cached: bool = False


def augment_edges(graph: JoinGraph):
    """Original edges + edges derived through key equivalence classes.

    Any leaf pair (u, v) whose columns fall in one equivalence class may be
    joined directly, provided v's column is unique in v (valid build side).
    This is what lets the DP join e.g. a dimension to an aggregated fact
    before the probe fact arrives.
    """
    seen = {(e.probe, e.build, e.probe_key, e.build_key)
            for e in graph.edges}
    unique = unique_key_sides(graph)
    out = list(graph.edges)
    for cls in key_equivalence_classes(graph):
        members = sorted(cls)
        for u, cu in members:
            for v, cv in members:
                if u == v or (v, cv) not in unique:
                    continue
                if (u, v, cu, cv) not in seen:
                    seen.add((u, v, cu, cv))
                    out.append(JoinEdge(u, v, cu, cv, derived=True))
    return out
