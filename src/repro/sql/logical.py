"""Logical query plans (paper §2.2): operator trees that determine the
result but not the physical methods. Joins and aggregations are the
exchange boundaries that split the plan into query stages (§2.3)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

from ..core.cost_model import JoinMethod
from ..core.selection import JoinType


@dataclasses.dataclass(frozen=True)
class Node:
    """Base logical operator."""

    def children(self) -> tuple:
        return ()


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    table: str


@dataclasses.dataclass(frozen=True)
class Filter(Node):
    child: Node
    column: str
    op: str            # "eq" | "lt" | "le" | "gt" | "ge" | "between"
    value: float
    value2: float = 0.0
    selectivity: float = 0.5  # static estimate used when stats are projected

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    columns: Tuple[str, ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Join(Node):
    """Logical equi-join; left is the plan-order probe side."""

    left: Node
    right: Node
    left_key: str
    right_key: str
    join_type: JoinType = JoinType.INNER
    hint: Optional[JoinMethod] = None

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class Aggregate(Node):
    """Group-by aggregation (an exchange boundary, like Join)."""

    child: Node
    key: str                              # group key column
    aggs: Tuple[Tuple[str, str], ...]     # (column, op) pairs

    def children(self):
        return (self.child,)


def count_joins(plan: Node) -> int:
    n = 1 if isinstance(plan, Join) else 0
    return n + sum(count_joins(c) for c in plan.children())


def walk(plan: Node):
    yield plan
    for c in plan.children():
        yield from walk(c)
