"""Plan-lint: static plan verifier + property-inference pass.

RelJoin's win comes from aggressively rewriting plans — predicate pushdown,
System-R reordering, skew salting, runtime-filter placement, cache-aware
re-costing, adaptive mid-pipeline re-planning — and every rewrite is an
opportunity to silently corrupt a plan in ways the cost model can't see.
This module gates all of them with *named, testable rules* over three
passes, none of which executes the plan:

1. **Property inference** (:func:`infer_properties`) — bottom-up
   schema/dtype flow and distribution properties (hash-partitioned-on-key /
   broadcast / singleton / arbitrary, the lattice in ``logical.py``), in
   the style of Spark's EnsureRequirements. Feeds the P-rules, and lets
   the exchange audit prove each exchange of a chosen join method
   *necessary* (an elided shuffle needs a proven hash distribution — E1)
   and *sufficient* (a side already partitioned on its join key must not
   be re-shuffled — E2).

2. **Rewrite-safety rules** — runtime filters only on filter-safe edges
   (F1: a LEFT_OUTER probe-side placement is rejected unless the
   unmatched-row padding path is used; LEFT_ANTI never), filters only
   when strictly cheaper (F2), cached-filter reuse only when the stored
   predicate chain is a subset of the edge's (F3: the payload must be a
   key-set superset of the edge's surviving build keys), salting only
   when the build side is replicable (S1), adaptive re-plan steps only
   along real join-graph edges (R1), and optimizer rewrites must preserve
   the output schema (P2).

3. **Cost-model audit** — every ``JoinDecision`` / ``FilterDecision`` the
   planner emits is checked for non-negative byte terms (C1) and for the
   selected method's quoted cost being minimal among the quoted
   alternatives, by reproducing Algorithm 1 on the recorded statistics
   (C2).

Violations carry ``(rule, path, detail)``; the executor/planner debug
gates (``verify=True``) raise :class:`PlanVerificationError` listing
them. ``python -m repro.sql.plan_analysis`` runs every golden query under
every strategy with the gates armed — the standalone CI pass.
"""

from __future__ import annotations

import dataclasses
import math
import sys
from typing import Dict, List, Optional, Tuple

from ..core.cost_model import CostParams, JoinMethod
from ..core.selection import (JoinProperties, JoinType, Selection,
                              select_join_method)
from ..core.stats import TableStats, q_error as _q_error
from ..joins.aggregate import AGG_OPS as _AGG_OPS
from .logical import (ARBITRARY as _ARBITRARY, Aggregate, Distribution,
                      Filter, Join, Node, Project, RuntimeFilter, Scan,
                      Schema, hash_dist, leaf_columns)

__all__ = [
    "RULES", "Rule", "Violation", "PlanVerificationError", "NodeProperties",
    "analyze_plan", "audit_exchanges", "audit_filter_decision",
    "audit_join_decision", "audit_selection", "catalog_dtypes",
    "check_cache_reuse", "check_cache_store", "check_filter_placement",
    "check_filter_quote", "check_reopt_decision", "check_replan_step",
    "check_schema_preserved", "infer_properties", "main",
    "verify_execution",
]


# ---------------------------------------------------------------------------
# Rule registry. docs/plan_analysis.md documents every rule listed here
# (pinned by tests/test_docs.py — extend both together).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    """One named plan invariant. ``severity`` is ``"error"`` (violating
    plans can return wrong results) or ``"perf"`` (violating plans return
    correct results but pay for work the engine could avoid)."""

    rule_id: str
    severity: str
    invariant: str


RULES: Dict[str, Rule] = {r.rule_id: r for r in (
    Rule("P1_UNKNOWN_COLUMN", "error",
         "Every column an operator references exists in its input schema "
         "(tables in the catalog, filter/project/aggregate/join columns in "
         "the child's inferred output)."),
    Rule("P2_OUTPUT_SCHEMA_CHANGED", "error",
         "Optimizer rewrites (pushdown, pruning, reordering) preserve the "
         "plan's output column set."),
    Rule("P3_KEY_DTYPE_MISMATCH", "error",
         "The two key columns of an equi-join have the same dtype — hash "
         "and sort comparisons across dtypes are not value-faithful."),
    Rule("P4_BAD_AGG_OP", "error",
         "Every aggregation op is one the engine implements (AGG_OPS)."),
    Rule("E1_MISSING_EXCHANGE", "error",
         "An exchange may be elided only when the analyzer can prove the "
         "side's distribution already satisfies the method's requirement "
         "(hash-partitioned on the join key for shuffles; salted and "
         "broadcast exchanges are never elidable)."),
    Rule("E2_REDUNDANT_EXCHANGE", "perf",
         "A side proven hash-partitioned on its join key must not be "
         "re-shuffled — the exchange must be elided, and the cost model "
         "must not re-pay it."),
    Rule("F1_FILTER_UNSAFE_JOIN_TYPE", "error",
         "A probe-side runtime filter is placed only on join types whose "
         "result survives dropping non-matching probe rows: INNER and "
         "LEFT_SEMI always; LEFT_OUTER only via the padding path that "
         "re-injects dropped rows with null-padded build columns and "
         "_matched=False; LEFT_ANTI never (the filter would drop exactly "
         "the rows the query keeps)."),
    Rule("F2_FILTER_NOT_CHEAPER", "perf",
         "A planned runtime filter keeps strictly less than the full probe "
         "side and its modeled benefit strictly exceeds its build + "
         "broadcast cost (the planner's strictly-cheaper gate)."),
    Rule("F3_CACHE_CHAIN_MISMATCH", "error",
         "A cached filter payload serves an edge only when the stored "
         "predicate chain is a subset of the edge's build chain (payload "
         "keys are a superset — false positives only), and a payload "
         "built from a build side masked by another runtime filter is "
         "never stored under its chain-only key."),
    Rule("S1_SALT_UNREPLICABLE_BUILD", "error",
         "SALTED_SHUFFLE_HASH is selected only when the model's A role "
         "sits on the plan's probe (left) side — the engine salts the "
         "left side and replicates the right, so a swapped-sides salted "
         "selection prices a plan the engine cannot run."),
    Rule("C1_NEGATIVE_COST_TERM", "error",
         "Every byte term a decision records — input sizes, cardinalities, "
         "quoted costs, filter wire bytes, row counts — is non-negative "
         "and non-NaN."),
    Rule("C2_NONMINIMAL_METHOD", "perf",
         "A cost-quoting selection picks the method Algorithm 1 picks on "
         "the recorded statistics and properties, at that method's quoted "
         "cost — minimal among the quoted alternatives under the "
         "algorithm's feasibility/preference order."),
    Rule("R1_REPLAN_BROKEN_EDGE", "error",
         "Every adaptive re-plan step joins the current intermediate to a "
         "remaining leaf along a real join-graph edge (probe endpoint "
         "already joined, matching keys) — the BuildRight contract "
         "survives re-planning."),
    Rule("R2_REOPT_DISCIPLINE", "error",
         "Every checkpoint re-optimization decision is disciplined: it "
         "triggers iff the recomputed estimated-vs-measured q-error "
         "exceeds the recorded threshold, and a non-triggered checkpoint "
         "leaves the planned continuation untouched (new_next == "
         "old_next) — re-planning may only be bought with evidence."),
)}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule violation at one plan location."""

    rule: str
    path: str
    detail: str

    def __str__(self) -> str:
        return f"{self.rule} at {self.path}: {self.detail}"


class PlanVerificationError(RuntimeError):
    """Raised by the debug-mode gates when a plan violates any rule."""

    def __init__(self, violations: List[Violation]):
        self.violations = list(violations)
        msg = "; ".join(str(v) for v in self.violations)
        super().__init__(f"plan verification failed: {msg}")


def _v(rule_id: str, path: str, detail: str) -> Violation:
    assert rule_id in RULES, rule_id
    return Violation(rule_id, path, detail)


# ---------------------------------------------------------------------------
# Pass 1: property inference (schema / dtype / distribution flow).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeProperties:
    """Inferred output properties of one plan node: column names in output
    order, a column -> dtype-name map ("" when unknown), and the
    distribution property from ``logical``'s lattice."""

    columns: Tuple[str, ...]
    dtypes: Dict[str, str]
    distribution: Distribution


def catalog_dtypes(catalog) -> Dict[str, Dict[str, str]]:
    """table -> column -> dtype-name map from a generated Catalog — the
    dtype ground truth the inference pass flows through the plan."""
    return {name: {col: str(arr.dtype) for col, arr in t.columns.items()}
            for name, t in catalog.tables.items()}


def infer_properties(plan: Node, schema: Schema,
                     dtypes: Optional[Dict[str, Dict[str, str]]] = None
                     ) -> Tuple[Dict[str, NodeProperties], List[Violation]]:
    """Bottom-up property inference over a logical plan.

    Returns ``(props, violations)``: per-path :class:`NodeProperties`
    (mirroring executor semantics, including the ``_r`` collision rename
    and the left-outer ``_matched`` flag) plus all P-rule violations.
    A subtree whose schema cannot be resolved stops inference upward —
    its own violation is the root cause; no cascading noise is emitted.
    """
    props: Dict[str, NodeProperties] = {}
    violations: List[Violation] = []

    def done(path: str, p: NodeProperties) -> NodeProperties:
        props[path] = p
        return p

    def go(node: Node, path: str) -> Optional[NodeProperties]:
        if isinstance(node, Scan):
            if node.table not in schema:
                violations.append(_v("P1_UNKNOWN_COLUMN", path,
                                     f"scan of unknown table {node.table!r}"))
                return None
            cols = tuple(schema[node.table])
            dt = dict((dtypes or {}).get(node.table, {}))
            return done(path, NodeProperties(
                cols, {c: dt.get(c, "") for c in cols}, _ARBITRARY))

        if isinstance(node, Filter):
            child = go(node.child, path + ".child")
            if child is None:
                return None
            if node.column not in child.columns:
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"filter references {node.column!r}, not in input "
                    f"columns {sorted(child.columns)}"))
            if (node.op == "eqcol"
                    and node.column2 not in child.columns):
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"eqcol filter references {node.column2!r}, not in "
                    f"input columns {sorted(child.columns)}"))
            return done(path, child)

        if isinstance(node, Project):
            child = go(node.child, path + ".child")
            if child is None:
                return None
            missing = [c for c in node.columns if c not in child.columns]
            if missing:
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"projection references {missing}, not in input "
                    f"columns {sorted(child.columns)}"))
            dist = child.distribution
            if dist.kind == "hash" and dist.key not in node.columns:
                dist = _ARBITRARY  # the hash key was projected away
            return done(path, NodeProperties(
                tuple(node.columns),
                {c: child.dtypes.get(c, "") for c in node.columns}, dist))

        if isinstance(node, Aggregate):
            child = go(node.child, path + ".child")
            if child is None:
                return None
            if node.key not in child.columns:
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"group key {node.key!r} not in input columns "
                    f"{sorted(child.columns)}"))
            out_dtypes = {node.key: child.dtypes.get(node.key, "")}
            cols = [node.key]
            for col, op in node.aggs:
                if col not in child.columns:
                    violations.append(_v(
                        "P1_UNKNOWN_COLUMN", path,
                        f"aggregation over {col!r}, not in input columns "
                        f"{sorted(child.columns)}"))
                if op not in _AGG_OPS:
                    violations.append(_v(
                        "P4_BAD_AGG_OP", path,
                        f"op {op!r} not implemented (AGG_OPS={_AGG_OPS})"))
                name = f"{op}_{col}"
                cols.append(name)
                src = child.dtypes.get(col, "")
                out_dtypes[name] = ("int32" if op == "count"
                                    else "float32" if op == "mean" else src)
            return done(path, NodeProperties(tuple(cols), out_dtypes,
                                             hash_dist(node.key)))

        if isinstance(node, Join):
            left = go(node.left, path + ".left")
            right = go(node.right, path + ".right")
            if left is None or right is None:
                return None
            if node.left_key not in left.columns:
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"left join key {node.left_key!r} not in probe columns "
                    f"{sorted(left.columns)}"))
            if node.right_key not in right.columns:
                violations.append(_v(
                    "P1_UNKNOWN_COLUMN", path,
                    f"right join key {node.right_key!r} not in build "
                    f"columns {sorted(right.columns)}"))
            lt = left.dtypes.get(node.left_key, "")
            rt = right.dtypes.get(node.right_key, "")
            if lt and rt and lt != rt:
                violations.append(_v(
                    "P3_KEY_DTYPE_MISMATCH", path,
                    f"{node.left_key!r} is {lt} but {node.right_key!r} is "
                    f"{rt}"))
            if node.join_type in (JoinType.LEFT_SEMI, JoinType.LEFT_ANTI):
                # Probe columns only survive; distribution is the probe's
                # at best, unknown method-wise -> arbitrary is sound.
                return done(path, NodeProperties(left.columns, left.dtypes,
                                                 _ARBITRARY))
            cols = list(left.columns)
            out_dtypes = dict(left.dtypes)
            for c in right.columns:
                name = c if c not in cols else f"{c}_r"
                cols.append(name)
                out_dtypes[name] = right.dtypes.get(c, "")
            if node.join_type is JoinType.LEFT_OUTER:
                name = f"{node.right_key}_matched"
                cols.append(name)
                out_dtypes[name] = "bool"
            # Output distribution depends on the physical method
            # (logical.join_output_distribution); statically arbitrary.
            return done(path, NodeProperties(tuple(cols), out_dtypes,
                                             _ARBITRARY))

        violations.append(_v("P1_UNKNOWN_COLUMN", path,
                             f"unknown plan node {type(node).__name__}"))
        return None

    go(plan, "root")
    return props, violations


def analyze_plan(plan: Node, schema: Schema,
                 dtypes: Optional[Dict[str, Dict[str, str]]] = None
                 ) -> List[Violation]:
    """The static pass: property inference + P-rules over one plan."""
    return infer_properties(plan, schema, dtypes)[1]


def check_schema_preserved(before: Node, after: Node, schema: Schema,
                           path: str = "root") -> List[Violation]:
    """P2: an optimizer rewrite preserves the plan's output column set."""
    try:
        want = set(leaf_columns(before, schema))
        got = set(leaf_columns(after, schema))
    except (KeyError, TypeError):
        return []  # unresolvable schema is P1 territory, reported there
    if want == got:
        return []
    lost, gained = sorted(want - got), sorted(got - want)
    return [_v("P2_OUTPUT_SCHEMA_CHANGED", path,
               f"rewrite changed output columns (lost {lost}, "
               f"gained {gained})")]


# ---------------------------------------------------------------------------
# Pass 2: rewrite-safety rules (runtime filters, cache reuse, salting,
# adaptive re-plan steps).
# ---------------------------------------------------------------------------

#: Join types whose result survives dropping non-matching probe rows
#: outright (no compensation needed).
_FILTER_SAFE_TYPES = (JoinType.INNER, JoinType.LEFT_SEMI)


def check_filter_placement(rf: RuntimeFilter, join_type: JoinType,
                           padded: bool = False,
                           path: str = "filter") -> List[Violation]:
    """F1: probe-side runtime filters only on filter-safe edges.

    ``padded`` asserts the executor compensates a LEFT_OUTER placement by
    re-injecting filtered-out probe rows with null-padded build columns
    and ``_matched=False`` (the padding path) — without it the filter
    would silently delete unmatched output rows.
    """
    if join_type in _FILTER_SAFE_TYPES:
        return []
    if join_type is JoinType.LEFT_OUTER and padded:
        return []
    why = ("LEFT_OUTER probe-side filter without the unmatched-row "
           "padding path" if join_type is JoinType.LEFT_OUTER else
           f"probe-side filter on {join_type.value} join (dropped probe "
           f"rows are part of the result)")
    return [_v("F1_FILTER_UNSAFE_JOIN_TYPE", path,
               f"{rf.kind} filter {rf.probe_key}<-{rf.build_key}: {why}")]


def check_filter_quote(rf: RuntimeFilter,
                       path: str = "filter") -> List[Violation]:
    """F2: a planned filter must be strictly worth it — it keeps < 100%
    of the probe side and its modeled benefit strictly exceeds its cost."""
    out: List[Violation] = []
    if not rf.keep_est < 1.0:
        out.append(_v("F2_FILTER_NOT_CHEAPER", path,
                      f"{rf.kind} filter {rf.probe_key}<-{rf.build_key} "
                      f"keeps {rf.keep_est:.3f} >= 1 of the probe side"))
    if not rf.benefit > rf.cost:
        out.append(_v("F2_FILTER_NOT_CHEAPER", path,
                      f"{rf.kind} filter {rf.probe_key}<-{rf.build_key}: "
                      f"benefit {rf.benefit:.1f} <= cost {rf.cost:.1f}"))
    return out


def check_cache_store(chain: Optional[tuple], build_masked: bool,
                      path: str = "cache") -> List[Violation]:
    """F3 (store side): a payload built from a build side that another
    runtime filter of this query already masked no longer matches its
    static predicate chain and must not enter the cross-query cache."""
    if not build_masked:
        return []
    return [_v("F3_CACHE_CHAIN_MISMATCH", path,
               f"storing payload for masked build side under chain-only "
               f"key {chain!r} (payload is narrower than the chain)")]


def check_cache_reuse(stored_chain: Optional[tuple],
                      edge_chain: Optional[tuple],
                      path: str = "cache") -> List[Violation]:
    """F3 (reuse side): a stored payload may serve an edge only when the
    stored predicate chain is a *subset* of the edge's build chain — then
    the payload's key set is a superset of the edge's surviving build
    keys and filtering stays false-positive-only."""
    if stored_chain is None or edge_chain is None:
        return [_v("F3_CACHE_CHAIN_MISMATCH", path,
                   "cache traffic for a leaf with no chain identity "
                   "(not Scan-rooted)")]
    s_table, s_preds = stored_chain
    e_table, e_preds = edge_chain
    if s_table != e_table:
        return [_v("F3_CACHE_CHAIN_MISMATCH", path,
                   f"stored chain scans {s_table!r}, edge scans "
                   f"{e_table!r}")]
    if not set(s_preds) <= set(e_preds):
        extra = sorted(set(s_preds) - set(e_preds))
        return [_v("F3_CACHE_CHAIN_MISMATCH", path,
                   f"stored chain has predicates {extra} the edge chain "
                   f"lacks — the payload may miss keys the edge's build "
                   f"side retains")]
    return []


def check_replan_step(step, joined, edges,
                      path: str = "region") -> List[Violation]:
    """R1: an adaptive re-plan step must follow a real join-graph edge —
    build endpoint outside the joined set, probe endpoint inside, keys
    matching — so the BuildRight contract survives re-planning."""
    for e in edges:
        if (e.build == step.build and e.probe in joined
                and e.probe_key == step.probe_key
                and e.build_key == step.build_key):
            return []
    return [_v("R1_REPLAN_BROKEN_EDGE", path,
               f"re-plan step joins leaf {step.build} via "
               f"{step.probe_key}={step.build_key} but no join-graph edge "
               f"oriented into the joined set {sorted(joined)} matches")]


def check_reopt_decision(dec, path: str = "reopt") -> List[Violation]:
    """R2: checkpoint re-optimization discipline over one decision.

    The trigger is recomputed from the recorded estimated/measured
    cardinalities (``core.stats.q_error``) and must match both the
    recorded ``q_error`` and the ``triggered`` flag against the recorded
    threshold; a non-triggered checkpoint must not have changed the
    continuation — the re-planned subtree must stay consistent with the
    live join graph's next step."""
    out: List[Violation] = []
    q = _q_error(dec.estimated.cardinality, dec.measured.cardinality)
    if not math.isclose(q, dec.q_error, rel_tol=_REL_TOL, abs_tol=1e-9):
        out.append(_v("R2_REOPT_DISCIPLINE", path,
                      f"recorded q-error {dec.q_error:.3f} != recomputed "
                      f"{q:.3f} (est={dec.estimated.cardinality:.0f}, "
                      f"meas={dec.measured.cardinality:.0f})"))
    elif dec.triggered != (q > dec.threshold):
        out.append(_v("R2_REOPT_DISCIPLINE", path,
                      f"triggered={dec.triggered} but q-error {q:.3f} vs "
                      f"threshold {dec.threshold:g} says "
                      f"{q > dec.threshold}"))
    if not dec.triggered and dec.new_next != dec.old_next:
        out.append(_v("R2_REOPT_DISCIPLINE", path,
                      f"checkpoint did not trigger yet changed the "
                      f"continuation (next build {dec.old_next!r} -> "
                      f"{dec.new_next!r})"))
    return out


# ---------------------------------------------------------------------------
# Pass 3: cost-model audit over emitted decisions.
# ---------------------------------------------------------------------------

_REL_TOL = 1e-6

#: Shuffle-family methods whose per-side exchanges are elidable.
_ELIDABLE = (JoinMethod.SHUFFLE_HASH, JoinMethod.SHUFFLE_SORT)


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=1e-9)


def audit_selection(sel: Selection, left: TableStats, right: TableStats,
                    props: JoinProperties, params: CostParams,
                    path: str = "join") -> List[Violation]:
    """C1 + C2 + S1 over one selection, *before* it runs.

    C2 reproduces Algorithm 1 on the recorded statistics and properties
    and demands the same method at the same quoted cost. Hinted,
    fallback, and quote-free (absolute-size / forced) selections have no
    quotes to audit — C1 still applies to their statistics.
    """
    out: List[Violation] = []
    for label, st in (("left", left), ("right", right)):
        if (st.size_bytes < 0 or st.cardinality < 0
                or math.isnan(st.size_bytes) or math.isnan(st.cardinality)):
            out.append(_v("C1_NEGATIVE_COST_TERM", path,
                          f"{label} statistics have negative/NaN terms "
                          f"(size={st.size_bytes}, "
                          f"card={st.cardinality})"))
    for m, c in sel.costs.items():
        if math.isnan(c) or c < 0:
            out.append(_v("C1_NEGATIVE_COST_TERM", path,
                          f"quoted cost of {m.value} is {c}"))
    if sel.method is JoinMethod.SALTED_SHUFFLE_HASH and sel.swapped_sides:
        out.append(_v("S1_SALT_UNREPLICABLE_BUILD", path,
                      "salted shuffle selected with swapped sides — the "
                      "build (replicated) side is the larger one"))
    if props.hint is not None or sel.used_fallback or not sel.costs:
        return out
    if sel.method is JoinMethod.HYPERCUBE_SHUFFLE:
        # Multi-way selections are quoted by the hypercube planner against
        # the best binary tree's cost, not by the binary Algorithm 1 on a
        # (left, right) pair — there is no two-sided reference to replay.
        # C1/S1 above still apply.
        return out
    if out:
        return out  # corrupted inputs make the reference run meaningless
    ref = select_join_method(left, right,
                             dataclasses.replace(props, hint=None), params)
    if ref.used_fallback or not ref.costs:
        return out
    expect_method, expect_cost = ref.method, ref.cost
    if "engine:" in sel.reason:
        # §4.4-style engine degrade: broadcast premise void, shuffle runs.
        expect_method = JoinMethod.SHUFFLE_HASH
        expect_cost = ref.costs.get(expect_method, ref.cost)
    if sel.method is not expect_method:
        out.append(_v(
            "C2_NONMINIMAL_METHOD", path,
            f"selected {sel.method.value} "
            f"(quoted {sel.costs.get(sel.method, float('nan')):.1f}) but "
            f"Algorithm 1 picks {expect_method.value} "
            f"(quoted {expect_cost:.1f}) on the recorded statistics"))
    elif not _close(sel.cost, expect_cost):
        out.append(_v(
            "C2_NONMINIMAL_METHOD", path,
            f"{sel.method.value} quoted at {sel.cost:.1f}, but its "
            f"minimal quote on the recorded statistics is "
            f"{expect_cost:.1f}"))
    return out


def audit_exchanges(sel: Selection, props: JoinProperties, report,
                    path: str = "join") -> List[Violation]:
    """E1 + E2 over one executed join's exchange reports.

    The necessity proof: an elided exchange is legal only where the
    distribution property says the side is already hash-partitioned on
    its join key (shuffle family, per-side flags) — anything else is a
    missing exchange. The sufficiency proof: a side with a proven hash
    distribution must have had its shuffle elided, not re-paid.
    """
    out: List[Violation] = []
    exchanges = list(report.exchanges)
    if sel.method is JoinMethod.HYPERCUBE_SHUFFLE:
        # Multi-way: every relation pays its hypercube exchange — the cube
        # distribution (hash on owned axes x replication along free axes)
        # is never provable from any input property, so an elision is
        # always a missing exchange.
        for ex in exchanges:
            if getattr(ex, "elided", False):
                out.append(_v(
                    "E1_MISSING_EXCHANGE", path,
                    f"{ex.kind} exchange of the multi-way join elided — "
                    f"cube distributions are never provably redundant"))
        return out
    if sel.method in _ELIDABLE and len(exchanges) == 2:
        sides = (("probe", props.left_partitioned, exchanges[0]),
                 ("build", props.right_partitioned, exchanges[1]))
        for label, proven, ex in sides:
            elided = bool(getattr(ex, "elided", False))
            if elided and not proven:
                out.append(_v(
                    "E1_MISSING_EXCHANGE", path,
                    f"{label}-side shuffle elided without a proven "
                    f"hash-on-key distribution"))
            if proven and not elided:
                out.append(_v(
                    "E2_REDUNDANT_EXCHANGE", path,
                    f"{label} side is hash-partitioned on its join key "
                    f"but re-shuffled {ex.network_bytes:.0f} bytes"))
        return out
    # Broadcast-family and salted exchanges establish distributions that
    # depend on more than the join key (full replication; key+salt
    # partitioning) — no input property can prove them skippable.
    for ex in exchanges:
        if getattr(ex, "elided", False):
            out.append(_v(
                "E1_MISSING_EXCHANGE", path,
                f"{ex.kind} exchange of {sel.method.value} elided — this "
                f"exchange kind is never provably redundant"))
    return out


def audit_join_decision(decision, params: CostParams,
                        path: str = "join") -> List[Violation]:
    """Full audit of one ``JoinDecision``: selection (C1/C2/S1) plus
    exchanges (E1/E2). E-rules need the decision's recorded
    ``JoinProperties`` (partition flags) — decisions without them get the
    selection audit only."""
    props = getattr(decision, "props", None)
    out = audit_selection(decision.selection, decision.left_stats,
                          decision.right_stats, props or JoinProperties(),
                          params, path)
    if props is not None:
        out += audit_exchanges(decision.selection, props, decision.report,
                               path)
    return out


def audit_filter_decision(decision, path: str = "filter") -> List[Violation]:
    """C1 + F2 over one executed ``FilterDecision``."""
    out: List[Violation] = []
    if decision.rows_before < 0 or decision.rows_after < 0:
        out.append(_v("C1_NEGATIVE_COST_TERM", path,
                      f"negative row counts ({decision.rows_before} -> "
                      f"{decision.rows_after})"))
    if decision.rows_after > decision.rows_before:
        out.append(_v("C1_NEGATIVE_COST_TERM", path,
                      f"filter grew the probe side ({decision.rows_before} "
                      f"-> {decision.rows_after} rows)"))
    if decision.broadcast_bytes < 0 or decision.reduce_bytes < 0:
        out.append(_v("C1_NEGATIVE_COST_TERM", path,
                      f"negative filter wire bytes "
                      f"(broadcast={decision.broadcast_bytes}, "
                      f"reduce={decision.reduce_bytes})"))
    out += check_filter_quote(decision.plan, path)
    return out


def verify_execution(result, params: CostParams) -> List[Violation]:
    """Post-hoc audit of a full ``ExecutionResult``: every join and filter
    decision through the pass-3 rules. The executor's ``verify=True``
    gates run the same audits inline — this entry point serves the CLI
    and tests."""
    out: List[Violation] = []
    for i, d in enumerate(result.decisions):
        out += audit_join_decision(d, params, path=f"join#{i}")
    for i, f in enumerate(result.filters):
        out += audit_filter_decision(f, path=f"filter#{i}[{f.plan.kind}]")
    for i, r in enumerate(getattr(result, "reopts", ()) or ()):
        out += check_reopt_decision(r, path=f"reopt#{i}")
    return out


# ---------------------------------------------------------------------------
# Standalone CI pass: every golden query x every strategy, gates armed.
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    """``python -m repro.sql.plan_analysis``: run all golden queries
    (q1-q37, including the text-only SQL suite and the cyclic hypercube
    targets) under every strategy with the debug gates armed, plus the
    static pass and the optimizer's P2 gate per query. Exits non-zero on
    any violation."""
    import argparse

    from .datagen import generate
    from .executor import Executor
    from .planner import catalog_schema, optimize
    from .queries import (cyclic_queries, every_query, filtered_queries,
                          skewed_queries, text_queries)
    from .strategies import (FilteredStrategy, RelJoinStrategy,
                             ReorderingStrategy, SkewAwareStrategy,
                             default_strategies)

    ap = argparse.ArgumentParser(
        description="static plan verification over the golden query suite")
    ap.add_argument("--scale", type=float, default=0.05,
                    help="catalog scale factor (default 0.05)")
    ap.add_argument("--p", type=int, default=4,
                    help="partition count (default 4)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--queries", default="",
                    help="comma-separated subset of query names")
    args = ap.parse_args(argv)

    catalog = generate(scale=args.scale, p=args.p, seed=args.seed)
    schema = catalog_schema(catalog)
    dtypes = catalog_dtypes(catalog)
    queries = {**every_query(), **skewed_queries(), **filtered_queries(),
               **text_queries(), **cyclic_queries()}
    if args.queries:
        names = args.queries.split(",")
        unknown = [n for n in names if n not in queries]
        if unknown:
            ap.error(f"unknown queries {unknown}; "
                     f"known: {sorted(queries)}")
        queries = {n: queries[n] for n in names}
    strategies = default_strategies() + [
        ReorderingStrategy(RelJoinStrategy()),
        # Checkpoint re-optimization arm: every boundary's ReoptDecision
        # runs through the R2 gate inline (verify=True below).
        ReorderingStrategy(RelJoinStrategy(), reopt=True),
        FilteredStrategy(RelJoinStrategy()),
        FilteredStrategy(ReorderingStrategy(RelJoinStrategy())),
        SkewAwareStrategy(),
    ]

    failures: List[str] = []
    checked = 0
    for qname in sorted(queries):
        plan = queries[qname]
        for violation in analyze_plan(plan, schema, dtypes):
            failures.append(f"{qname} [static]: {violation}")
        try:
            optimize(plan, catalog, verify=True)
        except PlanVerificationError as e:
            failures.extend(f"{qname} [optimize]: {v}" for v in e.violations)
        for strat in strategies:
            checked += 1
            try:
                Executor(catalog, strat, verify=True).execute(plan)
            except PlanVerificationError as e:
                failures.extend(f"{qname} [{strat.name}]: {v}"
                                for v in e.violations)
        status = "FAIL" if any(f.startswith(qname) for f in failures) else "ok"
        print(f"{qname}: {status}")
    for f in failures:
        print(f"VIOLATION {f}", file=sys.stderr)
    print(f"checked {len(queries)} plans x {len(strategies)} strategies "
          f"({checked} gated executions): "
          f"{len(failures)} violation(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
