"""SQL text front end, part 2: the binder.

Lowers a parsed :class:`~repro.sql.parser.SelectStmt` onto the plan algebra
(``sql.logical``), resolving every column reference against the catalog
schema (``datagen.TABLE_COLUMNS`` by default, or any table -> ordered
column tuple mapping):

  * FROM items lower first: tables become :class:`Scan`, derived tables
    bind recursively (a ``SELECT *`` derived table adds no node), explicit
    ``JOIN ... ON`` chains become :class:`Join` nodes with the ON keys
    oriented by column ownership (written order is kept; sides swap only
    when the text lists them build-first),
  * WHERE conjuncts apply in textual order: single-column predicates wrap
    the owning tree in :class:`Filter` (so the last conjunct is outermost),
    column = column equalities merge two FROM trees into an inner
    :class:`Join` (the tree owning the left column probes), and
    ``[NOT] IN (subquery)`` replaces the owning tree with a LEFT_SEMI /
    LEFT_ANTI join against the bound subquery, keyed on the subquery's
    first select item,
  * ``GROUP BY k`` requires the select list ``k, AGG(...), ...`` and
    becomes :class:`Aggregate`; without it a plain column list becomes
    :class:`Project` and ``*`` adds nothing.

Every Filter the binder creates gets its selectivity *baked in* from
:func:`~repro.sql.selectivity.derive_selectivity`, so parsed plans carry
the same static estimates a hand-built plan would declare.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Set, Tuple, Union

from ..core.selection import JoinType
from . import datagen
from .logical import (Aggregate, Filter, Join, Node, Project, Scan,
                      leaf_columns)
from .parser import (AggCall, ColRef, ColumnEquals, Comparison, DerivedRef,
                     FromTree, InList, InSubquery, SelectStmt, TableRef,
                     parse)
from .selectivity import derive_selectivity

__all__ = ["SqlBindError", "bind", "parse_sql"]


class SqlBindError(ValueError):
    """Raised when a parsed statement cannot be resolved against the
    schema: unknown tables/columns, ambiguous references, aggregates
    outside GROUP BY, or FROM items left unjoined."""


#: SQL aggregate name -> Aggregate op name (AVG is the algebra's "mean").
_AGG_MAP = {"SUM": "sum", "COUNT": "count", "MIN": "min", "MAX": "max",
            "AVG": "mean"}


@dataclasses.dataclass
class _Tree:
    """One bound FROM item: its plan subtree, output columns, and the
    relation names (tables / aliases) that qualify into it."""

    node: Node
    columns: Tuple[str, ...]
    names: Set[str]

    def resolves(self, ref: ColRef) -> bool:
        if ref.qualifier is not None and ref.qualifier not in self.names:
            return False
        return ref.name in self.columns


def _stmt_names(stmt: SelectStmt) -> Set[str]:
    """Relation names a statement exposes for qualified references."""
    names: Set[str] = set()
    for tree in stmt.froms:
        for ref in (tree.primary,) + tuple(j.ref for j in tree.joins):
            if isinstance(ref, TableRef):
                names.add(ref.alias or ref.table)
            elif ref.alias is not None:
                names.add(ref.alias)
            else:
                names |= _stmt_names(ref.query)
    return names


class _Binder:
    def __init__(self, schema: Mapping[str, Tuple[str, ...]],
                 key_domains: Optional[Mapping[str, float]]):
        self.schema: Dict[str, Tuple[str, ...]] = {
            t: tuple(cols) for t, cols in schema.items()}
        self.key_domains = key_domains

    # -- helpers ------------------------------------------------------------

    def columns_of(self, node: Node) -> Tuple[str, ...]:
        return tuple(leaf_columns(node, self.schema))

    def make_filter(self, child: Node, column: str, op: str,
                    value: float = 0.0, value2: float = 0.0,
                    values: Tuple[float, ...] = ()) -> Filter:
        f = Filter(child, column, op, value, value2, values)
        return dataclasses.replace(
            f, selectivity=derive_selectivity(f, self.key_domains))

    def lower_ref(self, ref: Union[TableRef, DerivedRef]) -> _Tree:
        if isinstance(ref, TableRef):
            if ref.table not in self.schema:
                raise SqlBindError(f"unknown table {ref.table!r}")
            node: Node = Scan(ref.table)
            names = {ref.alias or ref.table}
        else:
            node = self.bind_stmt(ref.query)
            names = ({ref.alias} if ref.alias is not None
                     else _stmt_names(ref.query))
        return _Tree(node, self.columns_of(node), names)

    def owner(self, trees: List[_Tree], ref: ColRef) -> int:
        found = [i for i, t in enumerate(trees) if t.resolves(ref)]
        if not found:
            raise SqlBindError(f"unknown column {_ref_str(ref)}")
        if len(found) > 1:
            raise SqlBindError(f"ambiguous column {_ref_str(ref)}")
        return found[0]

    # -- FROM ---------------------------------------------------------------

    def lower_from_tree(self, tree: FromTree) -> _Tree:
        acc = self.lower_ref(tree.primary)
        for jc in tree.joins:
            right = self.lower_ref(jc.ref)
            if acc.resolves(jc.left_col) and right.resolves(jc.right_col):
                probe_key, build_key = jc.left_col.name, jc.right_col.name
            elif acc.resolves(jc.right_col) and right.resolves(jc.left_col):
                probe_key, build_key = jc.right_col.name, jc.left_col.name
            else:
                raise SqlBindError(
                    f"ON {_ref_str(jc.left_col)} = {_ref_str(jc.right_col)}"
                    " does not link the joined relations")
            jt = (JoinType.LEFT_OUTER if jc.kind == "left"
                  else JoinType.INNER)
            node = Join(acc.node, right.node, probe_key, build_key,
                        join_type=jt)
            acc = _Tree(node, self.columns_of(node), acc.names | right.names)
        return acc

    # -- WHERE --------------------------------------------------------------

    def apply_where(self, trees: List[_Tree], preds) -> None:
        for pred in preds:
            if isinstance(pred, Comparison):
                i = self.owner(trees, pred.col)
                node = self.make_filter(trees[i].node, pred.col.name,
                                        pred.op, pred.value, pred.value2)
                trees[i] = _Tree(node, trees[i].columns, trees[i].names)
            elif isinstance(pred, InList):
                i = self.owner(trees, pred.col)
                node = self.make_filter(trees[i].node, pred.col.name, "in",
                                        values=pred.values)
                trees[i] = _Tree(node, trees[i].columns, trees[i].names)
            elif isinstance(pred, InSubquery):
                i = self.owner(trees, pred.col)
                sub = self.bind_stmt(pred.query, as_subquery=True)
                key = _subquery_key(pred.query)
                jt = (JoinType.LEFT_ANTI if pred.negated
                      else JoinType.LEFT_SEMI)
                node = Join(trees[i].node, sub, pred.col.name, key,
                            join_type=jt)
                # Semi/anti output keeps only the probe side's columns.
                trees[i] = _Tree(node, trees[i].columns, trees[i].names)
            elif isinstance(pred, ColumnEquals):
                li = self.owner(trees, pred.left)
                ri = self.owner(trees, pred.right)
                if li == ri:
                    raise SqlBindError(
                        f"{_ref_str(pred.left)} = {_ref_str(pred.right)}"
                        " relates columns of one relation; only"
                        " cross-relation join predicates are supported")
                node = Join(trees[li].node, trees[ri].node, pred.left.name,
                            pred.right.name)
                merged = _Tree(node, self.columns_of(node),
                               trees[li].names | trees[ri].names)
                trees[li] = merged
                del trees[ri]
            else:  # pragma: no cover - parser emits no other predicate
                raise SqlBindError(f"unsupported predicate {pred!r}")

    # -- SELECT / GROUP BY --------------------------------------------------

    def bind_stmt(self, stmt: SelectStmt, as_subquery: bool = False) -> Node:
        trees = [self.lower_from_tree(t) for t in stmt.froms]
        self.apply_where(trees, stmt.where)
        if len(trees) != 1:
            raise SqlBindError(
                f"{len(trees)} FROM items remain unjoined — comma-listed"
                " relations must be linked by WHERE equality predicates")
        tree = trees[0]

        if stmt.group_by is not None:
            return self.bind_group_by(stmt, tree)

        if stmt.star:
            return tree.node
        cols = []
        for item in stmt.items:
            if isinstance(item, AggCall):
                raise SqlBindError(
                    f"{item.func}({item.column}) requires GROUP BY")
            if not tree.resolves(item):
                raise SqlBindError(f"unknown column {_ref_str(item)}")
            cols.append(item.name)
        if as_subquery:
            # Dialect rule: an IN-subquery's select list only names its
            # key; no Project is planted around the subquery tree.
            return tree.node
        return Project(tree.node, tuple(cols))

    def bind_group_by(self, stmt: SelectStmt, tree: _Tree) -> Node:
        key = stmt.group_by
        assert key is not None
        if key not in tree.columns:
            raise SqlBindError(f"unknown group-by column {key!r}")
        if stmt.star or not stmt.items:
            raise SqlBindError("GROUP BY requires an explicit select list")
        first = stmt.items[0]
        if not isinstance(first, ColRef) or first.name != key:
            raise SqlBindError(
                f"the first select item must be the group key {key!r}")
        aggs = []
        for item in stmt.items[1:]:
            if not isinstance(item, AggCall):
                raise SqlBindError(
                    "select items after the group key must be aggregates")
            if item.column not in tree.columns:
                raise SqlBindError(
                    f"unknown aggregate column {item.column!r}")
            aggs.append((item.column, _AGG_MAP[item.func]))
        if not aggs:
            raise SqlBindError("GROUP BY requires at least one aggregate")
        return Aggregate(tree.node, key, tuple(aggs))


def _ref_str(ref: ColRef) -> str:
    return f"{ref.qualifier}.{ref.name}" if ref.qualifier else ref.name


def _subquery_key(stmt: SelectStmt) -> str:
    """The join key an IN-subquery exposes: its first select item."""
    if stmt.star or not stmt.items:
        raise SqlBindError(
            "an IN subquery must name its key as the first select item")
    first = stmt.items[0]
    if not isinstance(first, ColRef):
        raise SqlBindError(
            "an IN subquery's first select item must be a plain column")
    return first.name


def bind(stmt: SelectStmt,
         schema: Optional[Mapping[str, Tuple[str, ...]]] = None,
         key_domains: Optional[Mapping[str, float]] = None) -> Node:
    """Lower a parsed statement to a logical plan.

    ``schema`` maps table name -> ordered output columns (defaults to the
    synthetic catalog's ``datagen.TABLE_COLUMNS``); ``key_domains``
    optionally overrides the FK/PK domain sizes used when baking filter
    selectivities (e.g. a live ``Catalog.key_domains``).
    """
    return _Binder(schema or datagen.TABLE_COLUMNS,
                   key_domains).bind_stmt(stmt)


def parse_sql(text: str,
              schema: Optional[Mapping[str, Tuple[str, ...]]] = None,
              key_domains: Optional[Mapping[str, float]] = None) -> Node:
    """Parse SQL text and bind it to a logical plan in one step."""
    return bind(parse(text), schema, key_domains)
