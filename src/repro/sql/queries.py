"""TPC-DS-shaped query suite (DESIGN.md §7).

Every query is **SQL text** (``SQL_TEXTS``), lowered through the text front
end (``sql.parser`` -> ``sql.binder``) into a logical plan over the
synthetic star schema. q1-q23 additionally keep their original hand-built
plan constructors (``HAND_BUILT``) as a structural reference: the round-trip
test pins ``signature(parse_sql(text)) == signature(hand_built())`` for each,
so the front end can never silently drift from the plans the rest of the
suite was engineered around. q24+ exist only as text — the front end is
their sole producer.

The suite covers the decision space the paper evaluates:

  * deep dimension chains (q72's 10-join shape) with tiny build sides,
  * joins whose build side is < Spark's 10MB absolute threshold but NOT
    relatively small (k < k0) — where AQE over-broadcasts (paper §5.4),
  * joins of aggregated intermediates (q39's shape, a ~ p),
  * fact-to-large-dim joins (shuffle territory), semi/anti joins, outer
    joins, and a non-equi NL join.

Engine contract: probe side on the LEFT, unique-key build side on the RIGHT
(Spark's BuildRight).
"""

from __future__ import annotations

from typing import Callable, Dict

from ..core.selection import JoinType
from .binder import parse_sql
from .logical import Aggregate, Filter, Join, Node, Project, Scan


def _ss() -> Node:
    return Scan("store_sales")


def _cs() -> Node:
    return Scan("catalog_sales")


def q1_star3() -> Node:
    """Fact x 3 small dims with filters (classic reporting star)."""
    j = Join(_ss(), Filter(Scan("item"), "i_category", "lt", 3,
                           selectivity=0.3), "ss_item_sk", "i_item_sk")
    j = Join(j, Scan("store"), "ss_store_sk", "s_store_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_month", "eq", 6,
                       selectivity=1 / 12), "ss_sold_date_sk", "d_date_sk")
    return Aggregate(j, "i_brand", (("ss_sales_price", "sum"),
                                    ("ss_quantity", "sum")))


def q2_chain7() -> Node:
    """q72-shaped chain: fact joined to 6 dimensions in sequence."""
    j = Join(_ss(), Scan("date_dim"), "ss_sold_date_sk", "d_date_sk")
    j = Join(j, Scan("item"), "ss_item_sk", "i_item_sk")
    j = Join(j, Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("household"), "c_hdemo_sk", "hd_demo_sk")
    j = Join(j, Scan("promotion"), "ss_promo_sk", "p_promo_sk")
    j = Join(j, Scan("store"), "ss_store_sk", "s_store_sk")
    return Aggregate(j, "i_category", (("ss_net_profit", "sum"),))


def q3_cross_channel() -> Node:
    """Fact joined to the aggregate of another fact (q14 shape)."""
    cs_by_item = Aggregate(_cs(), "cs_item_sk",
                           (("cs_sales_price", "sum"),
                            ("cs_quantity", "count")))
    j = Join(_ss(), cs_by_item, "ss_item_sk", "cs_item_sk")
    return Aggregate(j, "ss_store_sk", (("ss_sales_price", "sum"),))


def q4_agg_agg() -> Node:
    """q39 shape: join of two aggregated subqueries (a ~ p territory)."""
    inv1 = Aggregate(Filter(Scan("inventory"), "inv_date_sk", "lt", 180,
                            selectivity=0.5),
                     "inv_item_sk", (("inv_quantity_on_hand", "mean"),))
    inv2 = Aggregate(Filter(Scan("inventory"), "inv_date_sk", "ge", 180,
                            selectivity=0.5),
                     "inv_item_sk", (("inv_quantity_on_hand", "mean"),))
    return Join(inv1, inv2, "inv_item_sk", "inv_item_sk")


def q5_dim_chain_first() -> Node:
    """Dim-dim join feeding a fact join (bushy shape)."""
    cust = Join(Scan("customer"), Scan("household"), "c_hdemo_sk",
                "hd_demo_sk")
    j = Join(_ss(), cust, "ss_customer_sk", "c_customer_sk")
    return Aggregate(j, "hd_buy_potential", (("ss_net_profit", "sum"),))


def q6_catalog_star() -> Node:
    j = Join(_cs(), Scan("warehouse"), "cs_warehouse_sk", "w_warehouse_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_year", "eq", 2000,
                       selectivity=1.0), "cs_ship_date_sk", "d_date_sk")
    j = Join(j, Scan("item"), "cs_item_sk", "i_item_sk")
    return Aggregate(j, "w_state", (("cs_sales_price", "sum"),))


def q7_filtered_fact() -> Node:
    """Hard-filtered fact x large dim: small absolute sizes but k ~ 1 —
    AQE broadcasts (under 10MB), RelJoin correctly shuffles (k < k0)."""
    f = Filter(_ss(), "ss_quantity", "lt", 10, selectivity=9 / 99)
    j = Join(f, Scan("customer"), "ss_customer_sk", "c_customer_sk")
    return Aggregate(j, "c_region", (("ss_sales_price", "sum"),))


def q8_semi() -> Node:
    """Semi join: customers with at least one purchase."""
    buyers = Aggregate(_ss(), "ss_customer_sk", (("ss_quantity", "count"),))
    return Join(Scan("customer"), buyers, "c_customer_sk", "ss_customer_sk",
                join_type=JoinType.LEFT_SEMI)


def q9_inventory_star() -> Node:
    j = Join(Scan("inventory"), Scan("item"), "inv_item_sk", "i_item_sk")
    j = Join(j, Scan("warehouse"), "inv_warehouse_sk", "w_warehouse_sk")
    return Aggregate(j, "i_category", (("inv_quantity_on_hand", "sum"),))


def q10_promo_window() -> Node:
    j = Join(_ss(), Filter(Scan("date_dim"), "d_moy", "between", 10,
                           value2=20, selectivity=11 / 30),
             "ss_sold_date_sk", "d_date_sk")
    j = Join(j, Scan("promotion"), "ss_promo_sk", "p_promo_sk")
    return Aggregate(j, "p_channel", (("ss_net_profit", "sum"),))


def q11_projected() -> Node:
    """Column pruning ahead of the exchange (smaller row bytes -> lower k)."""
    slim = Project(_ss(), ("ss_item_sk", "ss_customer_sk",
                           "ss_sales_price"))
    j = Join(slim, Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("item"), "ss_item_sk", "i_item_sk")
    return Aggregate(j, "i_brand", (("ss_sales_price", "sum"),))


def q12_anti() -> Node:
    """Anti join: items never sold through the catalog channel."""
    sold = Aggregate(_cs(), "cs_item_sk", (("cs_quantity", "count"),))
    return Join(Scan("item"), sold, "i_item_sk", "cs_item_sk",
                join_type=JoinType.LEFT_ANTI)


# ---------------------------------------------------------------------------
# Deliberately mis-ordered queries (planner targets): the written join order
# is provably suboptimal under the cost model — the System-R DP must find a
# strictly cheaper order. Kept out of all_queries() so the baseline suite's
# shape is unchanged; use misordered_queries() / every_query().
# ---------------------------------------------------------------------------


def q13_fact_fact_first() -> Node:
    """Fact x aggregated-fact runs BEFORE the selective dim filters.

    Optimal order joins the 10%-filtered item (then the 1/12 date window)
    first, shrinking the probe side ~120x before the expensive
    fact-aggregate join."""
    cs_by_item = Aggregate(_cs(), "cs_item_sk", (("cs_sales_price", "sum"),))
    j = Join(_ss(), cs_by_item, "ss_item_sk", "cs_item_sk")
    j = Join(j, Filter(Scan("item"), "i_category", "lt", 1, selectivity=0.1),
             "ss_item_sk", "i_item_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_month", "eq", 3,
                       selectivity=1 / 12), "ss_sold_date_sk", "d_date_sk")
    return Aggregate(j, "i_brand", (("ss_sales_price", "sum"),))


def q14_big_dim_first() -> Node:
    """The shuffle-heavy customer join (k < k0) runs BEFORE the 1/12
    date filter that would shrink the fact side it shuffles."""
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("store"), "ss_store_sk", "s_store_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_month", "eq", 6,
                       selectivity=1 / 12), "ss_sold_date_sk", "d_date_sk")
    return Aggregate(j, "c_region", (("ss_net_profit", "sum"),))


def q15_late_filter() -> Node:
    """Mis-placed AND mis-ordered: the selective item predicate is written
    above both joins. Pushdown sinks it to the item scan; reordering then
    joins the slimmed item ahead of the expensive customer join."""
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("item"), "ss_item_sk", "i_item_sk")
    f = Filter(j, "i_category", "lt", 1, selectivity=0.1)
    return Aggregate(f, "c_region", (("ss_sales_price", "sum"),))


# ---------------------------------------------------------------------------
# Skewed queries (skew-aware selection targets): each centers on a
# fact x large-dim join in shuffle territory (k < k0) whose fact-side FK is
# Zipf-hot when the catalog is generated with skew > 0. Under uniform keys
# these are ordinary shuffle-hash joins; under Zipf >= ~1.2 the straggler
# cost makes SkewAwareStrategy switch them to SALTED_SHUFFLE_HASH. Run them
# against ``generate(..., skew=z)`` catalogs (bench_skew sweeps z).
# ---------------------------------------------------------------------------


def q16_hot_customer() -> Node:
    """The canonical skew target: fact x customer (k ~ 1.7 << k0) with a
    Zipf-hot ss_customer_sk — one hot customer draws ~20% of the fact."""
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    return Aggregate(j, "c_region", (("ss_net_profit", "sum"),))


def q17_hot_customer_star() -> Node:
    """Skewed shuffle join feeding a reporting star: the hot customer join
    runs first (maximum straggler exposure), then two broadcast dims whose
    skew-invariant costs must NOT change under skew."""
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Scan("store"), "ss_store_sk", "s_store_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_month", "eq", 6,
                       selectivity=1 / 12), "ss_sold_date_sk", "d_date_sk")
    return Aggregate(j, "c_region", (("ss_sales_price", "sum"),))


def q18_hot_catalog_customer() -> Node:
    """Catalog-channel variant: the date join first widens the fact rows
    (so the probe side is the larger one at every scale), then the
    Zipf-hot cs_bill_customer_sk shuffle join hits the straggler."""
    j = Join(_cs(), Scan("date_dim"), "cs_ship_date_sk", "d_date_sk")
    j = Join(j, Scan("customer"), "cs_bill_customer_sk", "c_customer_sk")
    return Aggregate(j, "c_region", (("cs_sales_price", "sum"),))


# ---------------------------------------------------------------------------
# Filter-friendly queries (runtime bloom-filter targets): star shapes whose
# selective dimension predicate makes the probe side mostly dead weight at
# its shuffle — a bloom filter over the surviving dimension keys, applied
# to the fact below its exchanges, cuts the shipped bytes by ~1/sigma.
# Selectivities are tuned so the big fact x customer joins stay in shuffle
# territory (k < k0) with filters on AND off, so the saving shows up as
# probe-side shuffle bytes rather than a method flip.
# ---------------------------------------------------------------------------


def q19_filtered_customer() -> Node:
    """Fact x 30%-filtered customer (k ~ 3 << k0, shuffle both ways): the
    canonical single-edge filter — ~70% of the fact never ships."""
    f = Filter(Scan("customer"), "c_income", "lt", 74_000,
               selectivity=0.3)
    j = Join(_ss(), f, "ss_customer_sk", "c_customer_sk")
    return Aggregate(j, "c_region", (("ss_net_profit", "sum"),))


def q20_filter_below_earlier_exchange() -> Node:
    """The *unfiltered* customer shuffle runs first in plan order; the
    selective item predicate joins later. Leaf-level placement pushes the
    item filter below the customer exchange, so the first shuffle already
    ships only the ~10% of fact rows with surviving items."""
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, Filter(Scan("item"), "i_category", "lt", 1, selectivity=0.1),
             "ss_item_sk", "i_item_sk")
    return Aggregate(j, "c_region", (("ss_sales_price", "sum"),))


def q21_catalog_filtered_dates() -> Node:
    """Catalog channel: the date predicate (1 quarter ~ 25%) sits on a tiny
    broadcast dimension, yet its filter — pushed onto the fact leaf —
    quarters the later customer join's shuffled bytes."""
    j = Join(_cs(), Scan("customer"), "cs_bill_customer_sk", "c_customer_sk")
    j = Join(j, Filter(Scan("date_dim"), "d_month", "between", 0, value2=2,
                       selectivity=0.25), "cs_ship_date_sk", "d_date_sk")
    return Aggregate(j, "c_region", (("cs_sales_price", "sum"),))


# ---------------------------------------------------------------------------
# Filter-kind targets (runtime-filter *framework*): queries whose cheapest
# reducer is provably not a bloom filter, exercising the per-edge kind
# selection. q22's dimension predicate is a range on the join key itself
# (a TPC-DS date window filters d_date_sk between two dates), so the
# surviving keys are one contiguous band — the 8-byte min/max zone map
# keeps the same fraction as a bloom filter at a fraction of its broadcast
# cost. q23's build side survives as a handful of stores, so the exact
# sorted key list (32n bits, n ~ 5) undercuts even the minimum-size bloom
# array (256 bits) with zero false positives — the semi-join reducer wins.
# ---------------------------------------------------------------------------


def q22_zone_map_window() -> Node:
    """Date-window star: range predicate on the join key itself -> the
    dimension's surviving keys form one band and the zone map is the
    cheapest reducer. The unfiltered customer shuffle runs *first* in plan
    order, so only the leaf-level zone map — pushed below that exchange —
    can thin it to 25% of the fact (a 90-day window of the 360-day year)."""
    f = Filter(Scan("date_dim"), "d_date_sk", "lt", 90,
               selectivity=90 / 360)
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, f, "ss_sold_date_sk", "d_date_sk")
    return Aggregate(j, "c_region", (("ss_net_profit", "sum"),))


def q23_semi_join_stores() -> Node:
    """Tiny exact key set: ~5 of 60 stores survive the state predicate, so
    the semi-join reducer's key list is smaller than the minimum bloom
    array. Like q22, the customer shuffle runs first: the semi-join filter
    on the store key, applied at the fact leaf, ships only ~8% of it."""
    f = Filter(Scan("store"), "s_state", "eq", 0, selectivity=1 / 12)
    j = Join(_ss(), Scan("customer"), "ss_customer_sk", "c_customer_sk")
    j = Join(j, f, "ss_store_sk", "s_store_sk")
    return Aggregate(j, "c_region", (("ss_sales_price", "sum"),))


# ---------------------------------------------------------------------------
# Cyclic join cores (hypercube multi-way targets): the closing edge of each
# cycle is a column-to-column equality between two *build-side* columns —
# inexpressible in the suite's SQL dialect (single-equality ON, literal-only
# WHERE), so q35-q37 exist only as hand-built plans. The binary engine
# evaluates the closing edge as a post-join eqcol residual; the hypercube
# planner recognizes the cycle and quotes one multi-way shuffle against the
# DP's best binary tree. Build sides are aggregates (unique group keys — the
# engine's build contract) sized *relatively large* (> probe/k0), so the
# binary plan pays real shuffles and re-ships its wide intermediate, which
# is exactly the traffic the cube partitioning never creates.
# ---------------------------------------------------------------------------


def q35_triangle() -> Node:
    """Triangle on fact tables: store_sales x (catalog_sales by customer) x
    (inventory by item), closed on the item variable (the customer's
    max catalog item must be this sale's item). The item axis spans all
    three relations, so the best cube pure-hashes every relation —
    replication-free — while the binary plan re-ships its wide
    fact-sized intermediate at the second join."""
    s = Aggregate(_cs(), "cs_bill_customer_sk", (("cs_item_sk", "max"),))
    t = Aggregate(Scan("inventory"), "inv_item_sk",
                  (("inv_warehouse_sk", "max"),
                   ("inv_quantity_on_hand", "sum")))
    j = Join(_ss(), s, "ss_customer_sk", "cs_bill_customer_sk")
    j = Join(j, t, "ss_item_sk", "inv_item_sk")
    f = Filter(j, "max_cs_item_sk", "eqcol", column2="inv_item_sk")
    return Aggregate(f, "ss_store_sk", (("ss_sales_price", "sum"),))


def q36_triangle_shared_axis() -> Node:
    """The q35 rotation: catalog_sales probes (store_sales by customer) and
    (inventory by item), closed on the item variable via store_sales'
    max-item aggregate column. Same replication-free two-axis cube, with
    the probe and both builds drawn from the other fact pairing."""
    s = Aggregate(_ss(), "ss_customer_sk",
                  (("ss_item_sk", "max"), ("ss_sales_price", "sum")))
    t = Aggregate(Scan("inventory"), "inv_item_sk",
                  (("inv_quantity_on_hand", "sum"),
                   ("inv_warehouse_sk", "max")))
    j = Join(_cs(), s, "cs_bill_customer_sk", "ss_customer_sk")
    j = Join(j, t, "cs_item_sk", "inv_item_sk")
    f = Filter(j, "max_ss_item_sk", "eqcol", column2="inv_item_sk")
    return Aggregate(f, "cs_warehouse_sk", (("cs_sales_price", "sum"),))


def q37_four_clique() -> Node:
    """4-clique: every pair of relations shares a variable (customer, item,
    date, warehouse). Three closing eqcol edges ride above the join tree;
    the date variable spans all four relations, so the best cube
    concentrates the whole budget on the date axis."""
    r = _ss()
    s = Aggregate(_cs(), "cs_bill_customer_sk",
                  (("cs_warehouse_sk", "max"), ("cs_ship_date_sk", "max")))
    t = Aggregate(Scan("inventory"), "inv_item_sk",
                  (("inv_warehouse_sk", "max"), ("inv_date_sk", "max"),
                   ("inv_quantity_on_hand", "sum")))
    u = Aggregate(_cs(), "cs_ship_date_sk",
                  (("cs_quantity", "count"), ("cs_sales_price", "sum")))
    j = Join(r, s, "ss_customer_sk", "cs_bill_customer_sk")
    j = Join(j, t, "ss_item_sk", "inv_item_sk")
    j = Join(j, u, "ss_sold_date_sk", "cs_ship_date_sk")
    f = Filter(j, "max_cs_warehouse_sk", "eqcol",
               column2="max_inv_warehouse_sk")
    f = Filter(f, "max_cs_ship_date_sk", "eqcol", column2="cs_ship_date_sk")
    f = Filter(f, "max_inv_date_sk", "eqcol", column2="cs_ship_date_sk")
    return Aggregate(f, "ss_store_sk", (("ss_net_profit", "sum"),))


#: q1-q23's hand-built constructors — the structural reference the SQL
#: round-trip test pins against SQL_TEXTS.
HAND_BUILT: Dict[str, Callable[[], Node]] = {
    "q1_star3": q1_star3,
    "q2_chain7": q2_chain7,
    "q3_cross_channel": q3_cross_channel,
    "q4_agg_agg": q4_agg_agg,
    "q5_dim_chain_first": q5_dim_chain_first,
    "q6_catalog_star": q6_catalog_star,
    "q7_filtered_fact": q7_filtered_fact,
    "q8_semi": q8_semi,
    "q9_inventory_star": q9_inventory_star,
    "q10_promo_window": q10_promo_window,
    "q11_projected": q11_projected,
    "q12_anti": q12_anti,
    "q13_fact_fact_first": q13_fact_fact_first,
    "q14_big_dim_first": q14_big_dim_first,
    "q15_late_filter": q15_late_filter,
    "q16_hot_customer": q16_hot_customer,
    "q17_hot_customer_star": q17_hot_customer_star,
    "q18_hot_catalog_customer": q18_hot_catalog_customer,
    "q19_filtered_customer": q19_filtered_customer,
    "q20_filter_below_earlier_exchange": q20_filter_below_earlier_exchange,
    "q21_catalog_filtered_dates": q21_catalog_filtered_dates,
    "q22_zone_map_window": q22_zone_map_window,
    "q23_semi_join_stores": q23_semi_join_stores,
}


# ---------------------------------------------------------------------------
# The SQL texts. These are the queries: every registry below lowers its
# plans from this dict through parse_sql(). Filters written inside derived
# tables sit on the leaf scans (the hand-built shapes); q15/q29 deliberately
# leave predicates above the joins for the optimizer's pushdown to sink.
# ---------------------------------------------------------------------------

SQL_TEXTS: Dict[str, str] = {
    "q1_star3": """
        SELECT i_brand, SUM(ss_sales_price), SUM(ss_quantity)
        FROM store_sales
        JOIN (SELECT * FROM item WHERE i_category < 3)
          ON ss_item_sk = i_item_sk
        JOIN store ON ss_store_sk = s_store_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 6)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY i_brand
    """,
    "q2_chain7": """
        SELECT i_category, SUM(ss_net_profit)
        FROM store_sales
        JOIN date_dim ON ss_sold_date_sk = d_date_sk
        JOIN item ON ss_item_sk = i_item_sk
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN household ON c_hdemo_sk = hd_demo_sk
        JOIN promotion ON ss_promo_sk = p_promo_sk
        JOIN store ON ss_store_sk = s_store_sk
        GROUP BY i_category
    """,
    "q3_cross_channel": """
        SELECT ss_store_sk, SUM(ss_sales_price)
        FROM store_sales
        JOIN (SELECT cs_item_sk, SUM(cs_sales_price), COUNT(cs_quantity)
              FROM catalog_sales GROUP BY cs_item_sk)
          ON ss_item_sk = cs_item_sk
        GROUP BY ss_store_sk
    """,
    "q4_agg_agg": """
        SELECT *
        FROM (SELECT inv_item_sk, AVG(inv_quantity_on_hand) FROM inventory
              WHERE inv_date_sk < 180 GROUP BY inv_item_sk)
        JOIN (SELECT inv_item_sk, AVG(inv_quantity_on_hand) FROM inventory
              WHERE inv_date_sk >= 180 GROUP BY inv_item_sk)
          ON inv_item_sk = inv_item_sk
    """,
    "q5_dim_chain_first": """
        SELECT hd_buy_potential, SUM(ss_net_profit)
        FROM store_sales
        JOIN (SELECT * FROM customer
              JOIN household ON c_hdemo_sk = hd_demo_sk)
          ON ss_customer_sk = c_customer_sk
        GROUP BY hd_buy_potential
    """,
    "q6_catalog_star": """
        SELECT w_state, SUM(cs_sales_price)
        FROM catalog_sales
        JOIN warehouse ON cs_warehouse_sk = w_warehouse_sk
        JOIN (SELECT * FROM date_dim WHERE d_year = 2000)
          ON cs_ship_date_sk = d_date_sk
        JOIN item ON cs_item_sk = i_item_sk
        GROUP BY w_state
    """,
    "q7_filtered_fact": """
        SELECT c_region, SUM(ss_sales_price)
        FROM (SELECT * FROM store_sales WHERE ss_quantity < 10)
        JOIN customer ON ss_customer_sk = c_customer_sk
        GROUP BY c_region
    """,
    "q8_semi": """
        SELECT * FROM customer
        WHERE c_customer_sk IN (SELECT ss_customer_sk, COUNT(ss_quantity)
                                FROM store_sales GROUP BY ss_customer_sk)
    """,
    "q9_inventory_star": """
        SELECT i_category, SUM(inv_quantity_on_hand)
        FROM inventory
        JOIN item ON inv_item_sk = i_item_sk
        JOIN warehouse ON inv_warehouse_sk = w_warehouse_sk
        GROUP BY i_category
    """,
    "q10_promo_window": """
        SELECT p_channel, SUM(ss_net_profit)
        FROM store_sales
        JOIN (SELECT * FROM date_dim WHERE d_moy BETWEEN 10 AND 20)
          ON ss_sold_date_sk = d_date_sk
        JOIN promotion ON ss_promo_sk = p_promo_sk
        GROUP BY p_channel
    """,
    "q11_projected": """
        SELECT i_brand, SUM(ss_sales_price)
        FROM (SELECT ss_item_sk, ss_customer_sk, ss_sales_price
              FROM store_sales)
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN item ON ss_item_sk = i_item_sk
        GROUP BY i_brand
    """,
    "q12_anti": """
        SELECT * FROM item
        WHERE i_item_sk NOT IN (SELECT cs_item_sk, COUNT(cs_quantity)
                                FROM catalog_sales GROUP BY cs_item_sk)
    """,
    "q13_fact_fact_first": """
        SELECT i_brand, SUM(ss_sales_price)
        FROM store_sales
        JOIN (SELECT cs_item_sk, SUM(cs_sales_price) FROM catalog_sales
              GROUP BY cs_item_sk)
          ON ss_item_sk = cs_item_sk
        JOIN (SELECT * FROM item WHERE i_category < 1)
          ON ss_item_sk = i_item_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 3)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY i_brand
    """,
    "q14_big_dim_first": """
        SELECT c_region, SUM(ss_net_profit)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN store ON ss_store_sk = s_store_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 6)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY c_region
    """,
    "q15_late_filter": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN item ON ss_item_sk = i_item_sk
        WHERE i_category < 1
        GROUP BY c_region
    """,
    "q16_hot_customer": """
        SELECT c_region, SUM(ss_net_profit)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        GROUP BY c_region
    """,
    "q17_hot_customer_star": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN store ON ss_store_sk = s_store_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 6)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY c_region
    """,
    "q18_hot_catalog_customer": """
        SELECT c_region, SUM(cs_sales_price)
        FROM catalog_sales
        JOIN date_dim ON cs_ship_date_sk = d_date_sk
        JOIN customer ON cs_bill_customer_sk = c_customer_sk
        GROUP BY c_region
    """,
    "q19_filtered_customer": """
        SELECT c_region, SUM(ss_net_profit)
        FROM store_sales
        JOIN (SELECT * FROM customer WHERE c_income < 74000)
          ON ss_customer_sk = c_customer_sk
        GROUP BY c_region
    """,
    "q20_filter_below_earlier_exchange": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN (SELECT * FROM item WHERE i_category < 1)
          ON ss_item_sk = i_item_sk
        GROUP BY c_region
    """,
    "q21_catalog_filtered_dates": """
        SELECT c_region, SUM(cs_sales_price)
        FROM catalog_sales
        JOIN customer ON cs_bill_customer_sk = c_customer_sk
        JOIN (SELECT * FROM date_dim WHERE d_month BETWEEN 0 AND 2)
          ON cs_ship_date_sk = d_date_sk
        GROUP BY c_region
    """,
    "q22_zone_map_window": """
        SELECT c_region, SUM(ss_net_profit)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN (SELECT * FROM date_dim WHERE d_date_sk < 90)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY c_region
    """,
    "q23_semi_join_stores": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN (SELECT * FROM store WHERE s_state = 0)
          ON ss_store_sk = s_store_sk
        GROUP BY c_region
    """,
    # -- text-only queries (q24+): no hand-built twin, the front end is
    # -- their sole producer. Each widens the parsed surface: multi-
    # -- conjunct WHEREs, IN lists, LEFT JOIN, semi/anti under aggregates,
    # -- implicit comma joins, ne predicates, nested derived aggregates.
    "q24_multi_predicate": """
        SELECT s_state, SUM(ss_net_profit)
        FROM (SELECT * FROM store_sales
              WHERE ss_quantity < 50 AND ss_sales_price > 100)
        JOIN store ON ss_store_sk = s_store_sk
        GROUP BY s_state
    """,
    "q25_in_dims": """
        SELECT i_brand, SUM(ss_sales_price)
        FROM store_sales
        JOIN (SELECT * FROM item WHERE i_category IN (1, 3, 5))
          ON ss_item_sk = i_item_sk
        JOIN (SELECT * FROM date_dim WHERE d_month = 6)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY i_brand
    """,
    "q26_outer_agg": """
        SELECT c_region, SUM(sum_ss_net_profit)
        FROM customer
        LEFT JOIN (SELECT ss_customer_sk, SUM(ss_net_profit)
                   FROM store_sales GROUP BY ss_customer_sk)
          ON c_customer_sk = ss_customer_sk
        GROUP BY c_region
    """,
    "q27_semi_rich": """
        SELECT c_region, COUNT(c_income)
        FROM customer
        WHERE c_income > 150000
          AND c_customer_sk IN (SELECT cs_bill_customer_sk,
                                       COUNT(cs_quantity)
                                FROM catalog_sales
                                GROUP BY cs_bill_customer_sk)
        GROUP BY c_region
    """,
    "q28_anti_catalog": """
        SELECT i_category, COUNT(i_price)
        FROM item
        WHERE i_item_sk NOT IN (SELECT cs_item_sk, COUNT(cs_quantity)
                                FROM catalog_sales GROUP BY cs_item_sk)
        GROUP BY i_category
    """,
    "q29_implicit_star": """
        SELECT s_state, SUM(ss_sales_price)
        FROM store_sales, store, date_dim
        WHERE ss_store_sk = s_store_sk
          AND ss_sold_date_sk = d_date_sk
          AND d_month = 11
        GROUP BY s_state
    """,
    "q30_zone_window": """
        SELECT p_channel, SUM(ss_net_profit)
        FROM store_sales
        JOIN (SELECT * FROM date_dim WHERE d_date_sk BETWEEN 30 AND 59)
          ON ss_sold_date_sk = d_date_sk
        JOIN promotion ON ss_promo_sk = p_promo_sk
        GROUP BY p_channel
    """,
    "q31_ne_store": """
        SELECT s_state, COUNT(ss_quantity)
        FROM store_sales
        JOIN (SELECT * FROM store WHERE s_state <> 0)
          ON ss_store_sk = s_store_sk
        GROUP BY s_state
    """,
    "q32_inventory_turns": """
        SELECT w_state, SUM(mean_inv_quantity_on_hand)
        FROM (SELECT inv_warehouse_sk, AVG(inv_quantity_on_hand)
              FROM inventory WHERE inv_date_sk BETWEEN 90 AND 179
              GROUP BY inv_warehouse_sk)
        JOIN warehouse ON inv_warehouse_sk = w_warehouse_sk
        GROUP BY w_state
    """,
    # -- service queries (q33/q34): deliberately overlapping with q19/q22 —
    # -- identical FROM/JOIN subtrees under a *different* aggregate, the
    # -- cross-query CSE targets (the shared join executes once per batch).
    "q33_shared_customer_join": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN (SELECT * FROM customer WHERE c_income < 74000)
          ON ss_customer_sk = c_customer_sk
        GROUP BY c_region
    """,
    "q34_shared_window_join": """
        SELECT c_region, SUM(ss_sales_price)
        FROM store_sales
        JOIN customer ON ss_customer_sk = c_customer_sk
        JOIN (SELECT * FROM date_dim WHERE d_date_sk < 90)
          ON ss_sold_date_sk = d_date_sk
        GROUP BY c_region
    """,
}


def _from_sql(names) -> Dict[str, Node]:
    return {name: parse_sql(SQL_TEXTS[name]) for name in names}


def misordered_queries() -> Dict[str, Node]:
    return _from_sql(["q13_fact_fact_first", "q14_big_dim_first",
                      "q15_late_filter"])


def skewed_queries() -> Dict[str, Node]:
    return _from_sql(["q16_hot_customer", "q17_hot_customer_star",
                      "q18_hot_catalog_customer"])


def filtered_queries() -> Dict[str, Node]:
    return _from_sql(["q19_filtered_customer",
                      "q20_filter_below_earlier_exchange",
                      "q21_catalog_filtered_dates",
                      "q22_zone_map_window",
                      "q23_semi_join_stores"])


def cyclic_queries() -> Dict[str, Node]:
    """The cyclic-core queries (q35-q37): hand-built only — their closing
    eqcol edges are inexpressible in the suite's SQL dialect."""
    return {"q35_triangle": q35_triangle(),
            "q36_triangle_shared_axis": q36_triangle_shared_axis(),
            "q37_four_clique": q37_four_clique()}


def text_queries() -> Dict[str, Node]:
    """The text-only queries (q24+) — plans that exist solely as SQL."""
    return _from_sql([n for n in SQL_TEXTS if n not in HAND_BUILT])


def service_queries() -> Dict[str, Node]:
    """The concurrent-service batch: the filter-friendly q19-q23 plus the
    deliberately-overlapping q33/q34, whose join subtrees duplicate q19's
    and q22's — the cross-query CSE demonstration suite."""
    out = filtered_queries()
    out.update(_from_sql(["q33_shared_customer_join",
                          "q34_shared_window_join"]))
    return out


def every_query() -> Dict[str, Node]:
    """The 12 baseline plans plus the 3 mis-ordered planner targets.
    (The skewed q16-q18, filter-friendly q19-q23 and text-only q24+ are
    separate: they target specific catalogs/strategies — see
    ``skewed_queries()`` / ``filtered_queries()`` / ``text_queries()`` and
    bench_skew / bench_filters.)"""
    out = all_queries()
    out.update(misordered_queries())
    return out


def all_queries() -> Dict[str, Node]:
    return _from_sql(["q1_star3", "q2_chain7", "q3_cross_channel",
                      "q4_agg_agg", "q5_dim_chain_first", "q6_catalog_star",
                      "q7_filtered_fact", "q8_semi", "q9_inventory_star",
                      "q10_promo_window", "q11_projected", "q12_anti"])
