"""Mini SQL layer: a SQL text front end (tokenizer, recursive-descent
parser, binder, and pretty-printer), logical plans, synthetic TPC-DS-like
workload, selection strategies, the logical plan optimizer (pushdown /
pruning / System-R join reordering), and the adaptive stage-wise
executor."""

from .binder import SqlBindError, bind, parse_sql
from .datagen import Catalog, catalog_fingerprint, generate
from .executor import ExecutionResult, Executor, FilterDecision, JoinDecision
from .logical import (Aggregate, Distribution, Filter, Join, JoinEdge,
                      JoinGraph, Node, Project, RuntimeFilter, Scan,
                      effective_selectivity, extract_join_graph,
                      infer_distribution, shared_subtree_candidates,
                      signature, subtree_size, walk_paths)
from .parser import SqlSyntaxError, parse, tokenize
from .plan_analysis import (RULES, PlanVerificationError, Rule, Violation,
                            analyze_plan, audit_join_decision,
                            verify_execution)
from .planner import (OptimizedPlan, PlanCache, enumerate_join_order,
                      modeled_plan_cost, modeled_tree_cost, optimize,
                      plan_runtime_filters, prune_projections,
                      push_down_filters)
from .printer import to_sql
from .queries import (all_queries, cyclic_queries, every_query,
                      filtered_queries, misordered_queries, service_queries,
                      skewed_queries, text_queries)
from .selectivity import derive_selectivity
from .runtime_filters import (DEFAULT_FILTER_KINDS, FILTER_KINDS,
                              FilterCache, FilterQuote, RuntimeFilterKind,
                              build_filter_payload, filter_cache_key,
                              probe_filter_mask)
from .service import (ADMISSION_POLICIES, AdmissionController, BatchReport,
                      QueryService, SharedSubtree, Submission)
from .strategies import (AQEStrategy, FilteredStrategy, ForcedStrategy,
                         RelJoinStrategy, ReorderingStrategy,
                         SkewAwareStrategy, Strategy, default_strategies)

__all__ = ["SqlBindError", "bind", "parse_sql", "SqlSyntaxError", "parse",
           "tokenize", "to_sql", "derive_selectivity",
           "effective_selectivity", "text_queries",
           "Catalog", "catalog_fingerprint", "generate", "ExecutionResult",
           "Executor",
           "FilterDecision", "JoinDecision", "Aggregate", "Distribution",
           "Filter", "Join",
           "JoinEdge", "JoinGraph", "Node", "Project", "RuntimeFilter",
           "Scan", "extract_join_graph", "infer_distribution",
           "shared_subtree_candidates", "signature", "subtree_size",
           "walk_paths",
           "RULES", "PlanVerificationError", "Rule", "Violation",
           "analyze_plan", "audit_join_decision", "verify_execution",
           "OptimizedPlan", "PlanCache",
           "enumerate_join_order", "modeled_plan_cost", "modeled_tree_cost",
           "optimize",
           "plan_runtime_filters", "prune_projections", "push_down_filters",
           "all_queries", "cyclic_queries", "every_query",
           "filtered_queries",
           "misordered_queries", "service_queries", "skewed_queries",
           "DEFAULT_FILTER_KINDS",
           "FILTER_KINDS", "FilterCache", "FilterQuote", "RuntimeFilterKind",
           "build_filter_payload", "filter_cache_key", "probe_filter_mask",
           "ADMISSION_POLICIES", "AdmissionController", "BatchReport",
           "QueryService", "SharedSubtree", "Submission",
           "AQEStrategy",
           "FilteredStrategy", "ForcedStrategy", "RelJoinStrategy",
           "ReorderingStrategy", "SkewAwareStrategy", "Strategy",
           "default_strategies"]
