"""Mini SQL layer: logical plans, synthetic TPC-DS-like workload, selection
strategies, and the adaptive stage-wise executor."""

from .datagen import Catalog, generate
from .executor import ExecutionResult, Executor, JoinDecision
from .logical import Aggregate, Filter, Join, Node, Project, Scan
from .queries import all_queries
from .strategies import (AQEStrategy, ForcedStrategy, RelJoinStrategy,
                         Strategy, default_strategies)

__all__ = ["Catalog", "generate", "ExecutionResult", "Executor",
           "JoinDecision", "Aggregate", "Filter", "Join", "Node", "Project",
           "Scan", "all_queries", "AQEStrategy", "ForcedStrategy",
           "RelJoinStrategy", "Strategy", "default_strategies"]
