"""Join-method selection strategies evaluated in the paper (Table 3)."""

from __future__ import annotations

import dataclasses

from ..core.cost_model import (BLOOM_DEFAULT_BITS_PER_KEY,
                               DEFAULT_REOPT_QERROR, CostParams, JoinMethod)
from ..core.selection import (JoinProperties, Selection, select_absolute_size,
                              select_forced, select_join_method)
from ..core.stats import DEFAULT_WATERMARK_BYTES, TableStats
from .runtime_filters import DEFAULT_FILTER_KINDS, FilterCache


class Strategy:
    name: str = "base"
    #: When True the Executor runs the planner: pushdown + pruning rewrites
    #: and adaptive cost-based join reordering (System-R DP per region).
    reorder: bool = False
    #: When True the Executor measures the join-key partition skew of both
    #: inputs at every exchange boundary (partition_hist histograms) and
    #: attaches it to the runtime statistics, enabling the straggler-aware
    #: costs and the salted shuffle method.
    skew_aware: bool = False
    #: When True the Executor plans runtime-filter pushdown: build a filter
    #: (cheapest applicable kind — bloom / zone map / semi-join) over the
    #: build side's join keys at its exchange boundary and apply it to the
    #: probe side *below* its exchanges, wherever the cost model says the
    #: filtered join plus the filter's build + broadcast is strictly
    #: cheaper.
    runtime_filters: bool = False
    #: When True the Executor arms the plan-analysis debug gates: every
    #: plan (including adaptive re-plans and runtime-filter placements) is
    #: verified against the static rule set before/while running, and any
    #: violation raises ``PlanVerificationError`` naming the rule.
    verify: bool = False
    #: When True the Executor checkpoints every region exchange boundary:
    #: the materialized intermediate's measured cardinality is audited
    #: against the optimizer's prediction, and past ``reopt_qerror`` the
    #: measured stats are folded into the remaining join graph and the
    #: System-R DP re-runs on the remainder (mid-query re-optimization).
    reopt: bool = False
    #: q-error threshold arming the checkpoint above.
    reopt_qerror: float = DEFAULT_REOPT_QERROR

    def select(self, left: TableStats, right: TableStats,
               props: JoinProperties, p: int) -> Selection:
        raise NotImplementedError


@dataclasses.dataclass
class RelJoinStrategy(Strategy):
    """The paper's strategy: Algorithm 1 on adaptive runtime statistics."""

    w: float = 1.0
    watermark_bytes: float = DEFAULT_WATERMARK_BYTES

    def __post_init__(self):
        self.name = f"RelJoin(w={self.w:g})"

    def select(self, left, right, props, p):
        return select_join_method(left, right, props, CostParams(p=p, w=self.w),
                                  watermark_bytes=self.watermark_bytes)


@dataclasses.dataclass
class SkewAwareStrategy(Strategy):
    """RelJoin's Algorithm 1 on skew-annotated runtime statistics.

    Method selection is exactly :func:`select_join_method`; the difference
    is in the statistics: the Executor, seeing ``skew_aware=True``, measures
    the join-key straggler factor s = max/mean partition load of both inputs
    at every exchange boundary. Shuffle-family costs then inflate by s,
    which (a) shifts the broadcast/shuffle threshold to k0(s) and (b) lets
    the SALTED_SHUFFLE_HASH method win when plain shuffle would straggle.
    At s = 1 (uniform keys, or fluctuation below ``skew_floor``) every
    selection is byte-for-byte the one RelJoinStrategy makes.
    """

    w: float = 1.0
    watermark_bytes: float = DEFAULT_WATERMARK_BYTES
    #: Measured skew below this is hashing noise and snaps to 1.0.
    skew_floor: float = 1.1

    def __post_init__(self):
        self.name = f"SkewAware(w={self.w:g})"
        self.skew_aware = True

    def select(self, left, right, props, p):
        return select_join_method(left, right, props, CostParams(p=p, w=self.w),
                                  watermark_bytes=self.watermark_bytes)


@dataclasses.dataclass
class AQEStrategy(Strategy):
    """Spark AQE: absolute-size broadcast criterion on adaptive stats."""

    threshold_bytes: float = 10 * 1024 ** 2
    prefer_sort: bool = True

    def __post_init__(self):
        self.name = "AQE"

    def select(self, left, right, props, p):
        return select_absolute_size(left, right, props, self.threshold_bytes,
                                    self.prefer_sort)


@dataclasses.dataclass
class ForcedStrategy(Strategy):
    """ShuffleSort / ShuffleHash forced via hint (paper Table 3)."""

    method: JoinMethod = JoinMethod.SHUFFLE_SORT

    def __post_init__(self):
        self.name = ("ShuffleSort" if self.method is JoinMethod.SHUFFLE_SORT
                     else "ShuffleHash")

    def select(self, left, right, props, p):
        return select_forced(self.method, left, right, props)


@dataclasses.dataclass
class ReorderingStrategy(Strategy):
    """Wrapper adding plan-space search to any baseline.

    Method selection is delegated to the wrapped strategy unchanged; the
    Executor, seeing ``reorder=True``, additionally runs predicate pushdown,
    projection pruning, and the System-R DP join reordering (scored with the
    RelJoin cost model at weight ``w``) with adaptive re-planning at every
    exchange boundary. This lets every baseline in bench_strategies run
    ±reordering.
    """

    inner: Strategy = dataclasses.field(default_factory=lambda:
                                        RelJoinStrategy())
    #: Workload weight for the ordering DP; None inherits the wrapped
    #: strategy's w (when it has one) so the DP optimizes the same
    #: objective the per-join selections use.
    w: float | None = None
    #: Checkpoint mid-query re-optimization (see ``Strategy.reopt``); a
    #: reordering concern, so the knob lives on this wrapper.
    reopt: bool = False
    reopt_qerror: float = DEFAULT_REOPT_QERROR

    def __post_init__(self):
        self.name = f"Reorder({self.inner.name})"
        if self.reopt:
            self.name += "+reopt"
        self.reorder = True
        # Forward the wrapped strategy's executor-facing flags: without
        # these, Reorder(SkewAware(...)) would silently lose skew handling
        # and Reorder(Filtered(...)) its runtime-filter pushdown.
        self.skew_aware = getattr(self.inner, "skew_aware", False)
        self.skew_floor = getattr(self.inner, "skew_floor", 1.1)
        self.runtime_filters = getattr(self.inner, "runtime_filters", False)
        self.bits_per_key = getattr(self.inner, "bits_per_key",
                                    BLOOM_DEFAULT_BITS_PER_KEY)
        self.filter_kinds = getattr(self.inner, "filter_kinds",
                                    DEFAULT_FILTER_KINDS)
        self.filter_cache = getattr(self.inner, "filter_cache", None)
        self.verify = getattr(self.inner, "verify", False)
        if self.w is None:
            self.w = getattr(self.inner, "w", 1.0)

    def select(self, left, right, props, p):
        return self.inner.select(left, right, props, p)


@dataclasses.dataclass
class FilteredStrategy(Strategy):
    """Wrapper adding runtime-filter pushdown to any baseline.

    Method selection is delegated to the wrapped strategy unchanged; the
    Executor, seeing ``runtime_filters=True``, additionally plans a runtime
    filter per join-graph edge (``planner.plan_runtime_filters``): every
    kind in ``kinds`` — bloom array, min/max zone map, exact semi-join key
    list — quotes the edge and the strictly cheapest wins. The filter is
    built from the build side's surviving join keys at its exchange
    boundary, applied to the probe relation's key column at the *leaf* —
    below every exchange the probe side later goes through — and only
    where the cost model prices the filtered join plus the filter's build
    + broadcast strictly below the unfiltered join. With every sigma
    estimate at 1 (no selective dimension predicate) nothing is planned
    and the wrapped strategy's selections are byte-identical.
    """

    inner: Strategy = dataclasses.field(default_factory=lambda:
                                        RelJoinStrategy())
    #: Bloom budget: bits per distinct build-side key (m is the next power
    #: of two; k the optimal ln2 * m/n).
    bits_per_key: int = BLOOM_DEFAULT_BITS_PER_KEY
    #: Reducer kinds the planner may quote, in tie-break order.
    #: ``("bloom",)`` restricts the framework to bloom-only quoting.
    kinds: tuple = DEFAULT_FILTER_KINDS
    #: Cross-query ``FilterCache`` shared across Executor instances: built
    #: payloads are reused on later queries against the same catalog, and
    #: cache-hit edges are quoted without the build + reduce terms. None
    #: (default) keeps every run cold — byte-identical to the uncached
    #: planner.
    cache: FilterCache | None = None

    def __post_init__(self):
        self.name = f"Filtered({self.inner.name})"
        self.runtime_filters = True
        self.filter_kinds = tuple(self.kinds)
        self.filter_cache = self.cache
        # Forward the wrapped strategy's executor-facing flags so
        # Filtered(Reorder(...)) / Filtered(SkewAware(...)) compose.
        self.reorder = getattr(self.inner, "reorder", False)
        self.skew_aware = getattr(self.inner, "skew_aware", False)
        self.skew_floor = getattr(self.inner, "skew_floor", 1.1)
        self.verify = getattr(self.inner, "verify", False)
        self.reopt = getattr(self.inner, "reopt", False)
        self.reopt_qerror = getattr(self.inner, "reopt_qerror",
                                    DEFAULT_REOPT_QERROR)
        self.w = getattr(self.inner, "w", 1.0)

    def select(self, left, right, props, p):
        return self.inner.select(left, right, props, p)


def default_strategies(w: float = 1.0):
    return [ForcedStrategy(JoinMethod.SHUFFLE_SORT),
            ForcedStrategy(JoinMethod.SHUFFLE_HASH),
            AQEStrategy(),
            RelJoinStrategy(w=w)]
