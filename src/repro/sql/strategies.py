"""Join-method selection strategies evaluated in the paper (Table 3)."""

from __future__ import annotations

import dataclasses

from ..core.cost_model import CostParams, JoinMethod
from ..core.selection import (JoinProperties, Selection, select_absolute_size,
                              select_forced, select_join_method)
from ..core.stats import DEFAULT_WATERMARK_BYTES, TableStats


class Strategy:
    name: str = "base"
    #: When True the Executor runs the planner: pushdown + pruning rewrites
    #: and adaptive cost-based join reordering (System-R DP per region).
    reorder: bool = False
    #: When True the Executor measures the join-key partition skew of both
    #: inputs at every exchange boundary (partition_hist histograms) and
    #: attaches it to the runtime statistics, enabling the straggler-aware
    #: costs and the salted shuffle method.
    skew_aware: bool = False

    def select(self, left: TableStats, right: TableStats,
               props: JoinProperties, p: int) -> Selection:
        raise NotImplementedError


@dataclasses.dataclass
class RelJoinStrategy(Strategy):
    """The paper's strategy: Algorithm 1 on adaptive runtime statistics."""

    w: float = 1.0
    watermark_bytes: float = DEFAULT_WATERMARK_BYTES

    def __post_init__(self):
        self.name = f"RelJoin(w={self.w:g})"

    def select(self, left, right, props, p):
        return select_join_method(left, right, props, CostParams(p=p, w=self.w),
                                  watermark_bytes=self.watermark_bytes)


@dataclasses.dataclass
class SkewAwareStrategy(Strategy):
    """RelJoin's Algorithm 1 on skew-annotated runtime statistics.

    Method selection is exactly :func:`select_join_method`; the difference
    is in the statistics: the Executor, seeing ``skew_aware=True``, measures
    the join-key straggler factor s = max/mean partition load of both inputs
    at every exchange boundary. Shuffle-family costs then inflate by s,
    which (a) shifts the broadcast/shuffle threshold to k0(s) and (b) lets
    the SALTED_SHUFFLE_HASH method win when plain shuffle would straggle.
    At s = 1 (uniform keys, or fluctuation below ``skew_floor``) every
    selection is byte-for-byte the one RelJoinStrategy makes.
    """

    w: float = 1.0
    watermark_bytes: float = DEFAULT_WATERMARK_BYTES
    #: Measured skew below this is hashing noise and snaps to 1.0.
    skew_floor: float = 1.1

    def __post_init__(self):
        self.name = f"SkewAware(w={self.w:g})"
        self.skew_aware = True

    def select(self, left, right, props, p):
        return select_join_method(left, right, props, CostParams(p=p, w=self.w),
                                  watermark_bytes=self.watermark_bytes)


@dataclasses.dataclass
class AQEStrategy(Strategy):
    """Spark AQE: absolute-size broadcast criterion on adaptive stats."""

    threshold_bytes: float = 10 * 1024 ** 2
    prefer_sort: bool = True

    def __post_init__(self):
        self.name = "AQE"

    def select(self, left, right, props, p):
        return select_absolute_size(left, right, props, self.threshold_bytes,
                                    self.prefer_sort)


@dataclasses.dataclass
class ForcedStrategy(Strategy):
    """ShuffleSort / ShuffleHash forced via hint (paper Table 3)."""

    method: JoinMethod = JoinMethod.SHUFFLE_SORT

    def __post_init__(self):
        self.name = ("ShuffleSort" if self.method is JoinMethod.SHUFFLE_SORT
                     else "ShuffleHash")

    def select(self, left, right, props, p):
        return select_forced(self.method, left, right, props)


@dataclasses.dataclass
class ReorderingStrategy(Strategy):
    """Wrapper adding plan-space search to any baseline.

    Method selection is delegated to the wrapped strategy unchanged; the
    Executor, seeing ``reorder=True``, additionally runs predicate pushdown,
    projection pruning, and the System-R DP join reordering (scored with the
    RelJoin cost model at weight ``w``) with adaptive re-planning at every
    exchange boundary. This lets every baseline in bench_strategies run
    ±reordering.
    """

    inner: Strategy = dataclasses.field(default_factory=lambda:
                                        RelJoinStrategy())
    #: Workload weight for the ordering DP; None inherits the wrapped
    #: strategy's w (when it has one) so the DP optimizes the same
    #: objective the per-join selections use.
    w: float | None = None

    def __post_init__(self):
        self.name = f"Reorder({self.inner.name})"
        self.reorder = True
        # Forward the wrapped strategy's executor-facing flags: without
        # these, Reorder(SkewAware(...)) would silently lose skew handling.
        self.skew_aware = getattr(self.inner, "skew_aware", False)
        self.skew_floor = getattr(self.inner, "skew_floor", 1.1)
        if self.w is None:
            self.w = getattr(self.inner, "w", 1.0)

    def select(self, left, right, props, p):
        return self.inner.select(left, right, props, p)


def default_strategies(w: float = 1.0):
    return [ForcedStrategy(JoinMethod.SHUFFLE_SORT),
            ForcedStrategy(JoinMethod.SHUFFLE_HASH),
            AQEStrategy(),
            RelJoinStrategy(w=w)]
