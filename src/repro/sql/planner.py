"""Logical plan optimizer: rewrites + cost-based join reordering.

The paper (§4.2-§4.3) selects the physical method *per logical join* but
takes the logical join order as given. This module supplies the missing
plan-space search so that relative-cost selection composes into a globally
optimal physical plan:

  1. **Predicate pushdown** — filters sink through projections, inner joins
     and group-by keys to the scans they constrain.
  2. **Projection pruning** — scans are narrowed to the columns the plan
     actually consumes (smaller row_bytes -> lower |A|,|B| -> lower k).
  3. **System-R join ordering** — a left-deep dynamic program over each
     inner-join region, scoring every candidate order with the RelJoin cost
     model (Eqs. 4/8/10 via Algorithm 1's best feasible method) and
     propagating intermediate sizes with ``estimate_join``. A bushy-plan
     extension sits behind the ``bushy`` flag.

The DP only ever *replaces* the written order when its modeled workload is
strictly lower, so enabling reordering can't regress a well-written plan
under the model. ``Executor`` re-runs the same DP at every exchange
boundary with runtime-measured statistics (adaptive re-planning), via
``enumerate_join_order(..., start=...)``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..core.cost_model import (BLOOM_DEFAULT_BITS_PER_KEY, CostParams,
                               JoinMethod, cached_filter_cost, cube_shares,
                               method_cost)
from ..core.selection import (JoinProperties, JoinType, Selection,
                              select_hypercube, select_join_method)
from ..core.stats import (DEFAULT_WATERMARK_BYTES, ColumnStats, TableStats,
                          estimate_filter, estimate_group_by, estimate_join,
                          estimate_project)
from .datagen import Catalog, catalog_fingerprint
from .logical import (Aggregate, Filter, Join, JoinGraph, Node, Project,
                      RuntimeFilter, Scan, Schema, augment_edges,
                      cyclic_core, extract_join_graph, filter_chain,
                      key_band_fraction, leaf_columns, leaf_retain_fraction,
                      signature)
from .runtime_filters import (DEFAULT_FILTER_KINDS, FILTER_KINDS,
                              FilterCache, filter_cache_key)
from .selectivity import derive_selectivity

#: Static guess for an aggregate's group count as a fraction of input rows
#: (used only when no runtime statistic exists yet; exchange boundaries
#: replace it with the measured cardinality).
DEFAULT_GROUP_FRACTION = 0.1


# ---------------------------------------------------------------------------
# Schema / statistics helpers
# ---------------------------------------------------------------------------

def catalog_schema(catalog: Catalog) -> Schema:
    return {name: tuple(t.columns) for name, t in catalog.tables.items()}


def catalog_base_stats(catalog: Catalog) -> Dict[str, TableStats]:
    """Exact base-table statistics (the catalog's header stats)."""
    return {name: t.measure() for name, t in catalog.tables.items()}


def estimate_leaf_stats(node: Node, base_stats: Dict[str, TableStats],
                        schema: Schema,
                        key_domains: Optional[Dict[str, float]] = None,
                        column_stats: Optional[Dict[str, ColumnStats]] = None
                        ) -> TableStats:
    """Statically propagate (size, cardinality) through a leaf subtree.

    Filter selectivity is op-aware: a per-column histogram
    (``column_stats``, e.g. ``Catalog.column_stats``) wins when it covers
    the filter's column; otherwise a declared ``Filter.selectivity`` wins,
    and underived filters (parsed SQL) get ``derive_selectivity``'s
    schema-derived fraction — ``between``/``eq``/``in`` on columns with
    known domains estimate their true kept fraction instead of a blanket
    0.5. ``key_domains`` (e.g. ``Catalog.key_domains``) refines key-column
    lookups; the static schema domains are the fallback. With histograms,
    aggregate group counts come from the group key's NDV and join output
    cardinalities from histogram-backed retain fractions instead of the
    fixed ``DEFAULT_GROUP_FRACTION`` / declared-only retains."""
    if isinstance(node, Scan):
        return base_stats[node.table]
    if isinstance(node, Filter):
        return estimate_filter(
            estimate_leaf_stats(node.child, base_stats, schema, key_domains,
                                column_stats),
            derive_selectivity(node, key_domains, column_stats))
    if isinstance(node, Project):
        child = estimate_leaf_stats(node.child, base_stats, schema,
                                    key_domains, column_stats)
        n_child = max(len(leaf_columns(node.child, schema)), 1)
        return estimate_project(child, len(node.columns) / n_child)
    if isinstance(node, Aggregate):
        child = estimate_leaf_stats(node.child, base_stats, schema,
                                    key_domains, column_stats)
        groups = max(child.cardinality * DEFAULT_GROUP_FRACTION, 1.0)
        if column_stats is not None:
            cs = column_stats.get(node.key)
            if cs is not None and cs.count > 0:
                groups = max(cs.ndv, 1.0)
        return estimate_group_by(child, groups)
    if isinstance(node, Join):
        left = estimate_leaf_stats(node.left, base_stats, schema,
                                   key_domains, column_stats)
        right = estimate_leaf_stats(node.right, base_stats, schema,
                                    key_domains, column_stats)
        retain = stats_retain_fraction(node.right, key_domains, column_stats)
        if node.join_type in (JoinType.LEFT_SEMI, JoinType.LEFT_ANTI):
            # Output keeps probe columns only; anti is the complement. The
            # match fraction is domain coverage: the build side's distinct
            # keys (its cardinality, by the unique-build-key contract —
            # histogram NDV for aggregate builds) over the probe key's
            # domain. A full-table build then correctly predicts the anti
            # residue of never-referenced keys, which no filter-retain
            # product can see.
            sigma = semi_match_fraction(right, node.left_key, key_domains,
                                        retain)
            frac = (sigma if node.join_type is JoinType.LEFT_SEMI
                    else max(1.0 - sigma, 0.0))
            card = left.cardinality * frac
            return TableStats(card * left.row_bytes, card)
        if node.join_type in (JoinType.LEFT_OUTER, JoinType.RIGHT_OUTER,
                              JoinType.FULL_OUTER):
            # Outer joins keep (at least) every probe row.
            return estimate_join(left, right)
        return estimate_join(left, right, fk_selectivity=retain)
    raise TypeError(f"unknown plan node {type(node)}")


def stats_retain_fraction(node: Node,
                          key_domains: Optional[Dict[str, float]] = None,
                          column_stats: Optional[Dict[str, ColumnStats]]
                          = None) -> float:
    """Histogram-aware twin of ``logical.leaf_retain_fraction``: the
    fraction of a build leaf's key domain surviving its filter chain,
    with each filter's fraction taken from the column's histogram when one
    exists. Without ``column_stats`` it reproduces the declared/derived
    fractions exactly."""
    base, filters = filter_chain(node)
    frac = 1.0
    for f in filters:
        frac *= min(max(derive_selectivity(f, key_domains, column_stats),
                        0.0), 1.0)
    if isinstance(base, Project):
        frac *= stats_retain_fraction(base.child, key_domains, column_stats)
    return frac


def semi_match_fraction(build: TableStats, probe_key: str,
                        key_domains: Optional[Dict[str, float]],
                        retain: float) -> float:
    """Fraction of probe rows a semi join keeps: the build side's distinct
    keys (its estimated cardinality — the engine's unique-build-key
    contract makes cardinality ≈ NDV) over the probe key's domain. Falls
    back to the build chain's filter-retain fraction when the probe key
    has no known domain."""
    domain = key_domains.get(probe_key) if key_domains else None
    if domain is not None and domain > 0:
        return min(max(build.cardinality, 0.0) / domain, 1.0)
    return min(max(retain, 0.0), 1.0)


def _step(probe: TableStats, build: TableStats, params: CostParams,
          ) -> Tuple[JoinMethod, float]:
    """Method + modeled workload of one candidate join (Algorithm 1 on the
    candidate's statistics; Eq. 4/8/10 dispatch when selection fell back)."""
    sel = select_join_method(probe, build, JoinProperties(), params)
    cost = sel.cost
    if not math.isfinite(cost):
        a, b = ((probe, build) if probe.size_bytes >= build.size_bytes
                else (build, probe))
        cost = method_cost(sel.method, a.size_bytes, b.size_bytes,
                           max(a.cardinality, 1.0), max(b.cardinality, 1.0),
                           params)
    return sel.method, cost


# ---------------------------------------------------------------------------
# System-R dynamic program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JoinStep:
    """One executed join of a left-deep order: intermediate |><| leaf."""

    build: int
    probe_key: str
    build_key: str
    method: JoinMethod
    cost: float


@dataclasses.dataclass
class JoinOrder:
    """A complete order over a region. ``tree`` generalizes to bushy shapes:
    a leaf index or ``(left_tree, right_tree, probe_key, build_key)``."""

    first: int
    steps: Tuple[JoinStep, ...]
    cost: float
    stats: TableStats
    tree: object

    def order(self) -> List[int]:
        """Leaf indices in join sequence (derived from the tree so bushy
        shapes are covered too; for left-deep orders this is
        [first, step1.build, step2.build, ...])."""

        def leaves(t):
            if isinstance(t, int):
                return [t]
            return leaves(t[0]) + leaves(t[1])

        return leaves(self.tree)


@dataclasses.dataclass
class _State:
    cost: float
    stats: TableStats
    retain: float      # product of member retain fractions (build-side role)
    root: int          # probe root (its unique key survives the joins)
    first: int
    steps: tuple
    tree: object


def enumerate_join_order(leaf_stats: List[TableStats],
                         retain: List[float],
                         edges,
                         params: CostParams,
                         bushy: bool = False,
                         start: Optional[int] = None) -> Optional[JoinOrder]:
    """System-R DP over a join region.

    Left-deep by default: states are relation subsets; a leaf ``r`` extends
    subset ``S`` iff an edge oriented toward ``r`` has its probe endpoint in
    ``S`` (so ``r`` always joins through its unique key — the engine's
    BuildRight contract is preserved under any enumerated order). With
    ``bushy=True``, two disjoint subsets may also be merged when the edge
    lands on the build subset's probe root, whose key stays unique through
    FK->PK joins.

    ``start`` pins the first (probe-root) relation — the executor's adaptive
    re-planning hook uses it to extend a partially-executed order.
    Returns None when no feasible complete order exists.
    """
    n = len(leaf_stats)
    if n == 0:
        return None
    seeds = range(n) if start is None else (start,)
    dp: Dict[frozenset, _State] = {}
    for i in seeds:
        dp[frozenset((i,))] = _State(0.0, leaf_stats[i], retain[i], i, i,
                                     (), i)

    by_build: Dict[int, list] = {}
    for e in edges:
        by_build.setdefault(e.build, []).append(e)

    for size in range(1, n):
        layer = [s for s in dp if len(s) == size]
        for S in sorted(layer, key=sorted):
            st = dp[S]
            # Left-deep extension: S |><| {r}.
            for r in range(n):
                if r in S:
                    continue
                usable = [e for e in by_build.get(r, []) if e.probe in S]
                if not usable:
                    continue
                e = usable[0]
                method, cost = _step(st.stats, leaf_stats[r], params)
                total = st.cost + cost
                T = S | {r}
                if T in dp and dp[T].cost <= total:
                    continue
                stats = estimate_join(st.stats, leaf_stats[r],
                                      fk_selectivity=retain[r])
                step = JoinStep(r, e.probe_key, e.build_key, method, cost)
                dp[T] = _State(total, stats, st.retain * retain[r], st.root,
                               st.first, st.steps + (step,),
                               (st.tree, r, e.probe_key, e.build_key))
        if bushy:
            # Merge disjoint subsets: S1 (probe) |><| S2 (build via root).
            subsets = sorted((s for s in dp if len(s) <= size), key=sorted)
            for S1 in subsets:
                for S2 in subsets:
                    if len(S1) + len(S2) > n or S1 & S2:
                        continue
                    s1, s2 = dp[S1], dp[S2]
                    usable = [e for e in by_build.get(s2.root, [])
                              if e.probe in S1]
                    if not usable:
                        continue
                    e = usable[0]
                    method, cost = _step(s1.stats, s2.stats, params)
                    total = s1.cost + s2.cost + cost
                    T = S1 | S2
                    if T in dp and dp[T].cost <= total:
                        continue
                    stats = estimate_join(s1.stats, s2.stats,
                                          fk_selectivity=s2.retain)
                    step = JoinStep(s2.root, e.probe_key, e.build_key,
                                    method, cost)
                    dp[T] = _State(total, stats, s1.retain * s2.retain,
                                   s1.root, s1.first,
                                   s1.steps + s2.steps + (step,),
                                   (s1.tree, s2.tree, e.probe_key,
                                    e.build_key))

    full = dp.get(frozenset(range(n)))
    if full is None:
        return None
    return JoinOrder(full.first, full.steps, full.cost, full.stats, full.tree)


def modeled_tree_cost(graph: JoinGraph, leaf_stats: List[TableStats],
                      retain: List[float], params: CostParams) -> float:
    """Modeled workload (Eq. 4/8/10 sum) of executing the region in its
    *written* order, with the same estimation rules the DP uses."""

    def go(t):
        if isinstance(t, int):
            return leaf_stats[t], retain[t], 0.0
        ls, lr, lc = go(t[0])
        rs, rr, rc = go(t[1])
        _, cost = _step(ls, rs, params)
        out = estimate_join(ls, rs, fk_selectivity=rr)
        return out, lr * rr, lc + rc + cost

    return go(graph.tree)[2]


# ---------------------------------------------------------------------------
# Hypercube multi-way planning (cyclic join cores)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HypercubePlan:
    """Physical plan of one hypercube multi-way join over a cyclic region.

    ``order`` lists the region's leaf indices with the probe relation
    first; all positional fields below index into that order. ``links``
    are the local probe chain as ``(build_position, probe_col,
    build_col)`` triples; ``checks`` the residual column equalities
    (unused binary edges + the closing eqcol predicates). ``selection``
    is the winning HYPERCUBE_SHUFFLE quote against ``binary_cost``."""

    order: Tuple[int, ...]
    dims: Tuple[int, ...]
    axis_keys: Tuple[Tuple[Tuple[int, str], ...], ...]
    links: Tuple[Tuple[int, str, str], ...]
    checks: Tuple[Tuple[str, str], ...]
    selection: Selection
    binary_cost: float


def plan_hypercube(graph: JoinGraph, closing,
                   leaf_stats: List[TableStats], binary_cost: float,
                   params: CostParams,
                   watermark_bytes: float = DEFAULT_WATERMARK_BYTES
                   ) -> Optional[HypercubePlan]:
    """Quote the hypercube multi-way shuffle against the best binary plan.

    ``closing`` is the list of column-equality predicates written above
    the region, as ``((leaf_u, col_u), (leaf_v, col_v))`` pairs — with the
    graph's equi-join edges they form the (possibly cyclic) join graph.
    Returns a plan only when (1) the region plus closing edges is one
    cyclic core covering every leaf, (2) the shape is hypercube-executable
    (a unique probe relation, every build reachable through the accumulated
    probe row), and (3) Algorithm 1's multi-way extension prices it
    *strictly cheaper* than ``binary_cost`` (the best binary tree's quote).
    Anything else returns None and the binary plan stands.
    """
    n = graph.n
    pairs = [(e.probe, e.build) for e in graph.edges]
    pairs += [(a[0], b[0]) for a, b in closing]
    if n < 3 or len(cyclic_core(n, pairs)) != n:
        return None

    # Join variables: key equivalence classes over equi + closing edges.
    parent: Dict[tuple, tuple] = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in graph.edges:
        parent[find((e.probe, e.probe_key))] = find((e.build, e.build_key))
    for a, b in closing:
        parent[find(tuple(a))] = find(tuple(b))
    classes: Dict[tuple, set] = {}
    for x in list(parent):
        classes.setdefault(find(x), set()).add(x)
    axes = sorted((sorted(c) for c in classes.values()
                   if len({leaf for leaf, _ in c}) > 1))
    if not axes:
        return None

    # Probe relation: the unique leaf never used as a build side.
    builds = {e.build for e in graph.edges}
    probes = [i for i in range(n) if i not in builds]
    if len(probes) != 1:
        return None
    order = [probes[0]]
    links: List[Tuple[int, str, str]] = []
    used = set()
    remaining = set(range(n)) - {probes[0]}
    progress = True
    while remaining and progress:
        progress = False
        for ei, e in enumerate(graph.edges):
            if ei in used or e.build not in remaining or e.probe not in order:
                continue
            order.append(e.build)
            links.append((len(order) - 1, e.probe_key, e.build_key))
            used.add(ei)
            remaining.discard(e.build)
            progress = True
    if remaining:
        return None
    checks = [(graph.edges[ei].probe_key, graph.edges[ei].build_key)
              for ei in range(len(graph.edges)) if ei not in used]
    checks += [(cu, cv) for (u, cu), (v, cv) in closing]

    memberships: List[Tuple[int, ...]] = []
    axis_keys: List[Tuple[Tuple[int, str], ...]] = []
    for leaf in order:
        keys = []
        for ax, members in enumerate(axes):
            cols = [c for (l, c) in members if l == leaf]
            if cols:
                keys.append((ax, cols[0]))
        memberships.append(tuple(ax for ax, _ in keys))
        axis_keys.append(tuple(keys))

    stats = [leaf_stats[i] for i in order]
    sel = select_hypercube(stats, memberships, len(axes), binary_cost,
                           params, watermark_bytes)
    if sel is None:
        return None
    dims = cube_shares(params.p, len(axes), memberships,
                       [s.size_bytes for s in stats], params)
    return HypercubePlan(tuple(order), tuple(dims), tuple(axis_keys),
                         tuple(links), tuple(checks), sel, binary_cost)


# ---------------------------------------------------------------------------
# Runtime bloom-filter placement (sideways information passing)
# ---------------------------------------------------------------------------

def leaf_key_domain(node: Node, base_stats: Dict[str, TableStats]
                    ) -> Optional[float]:
    """Cardinality of the key domain a leaf's unique key spans: the base
    scan's row count (dimension PKs cover [0, n)). None when the leaf is
    not rooted in a scan (e.g. an aggregated subquery) — the filter planner
    then falls back to the leaf's static retain fraction."""
    base, _ = filter_chain(node)
    if isinstance(base, Project):
        return leaf_key_domain(base.child, base_stats)
    if isinstance(base, Scan):
        st = base_stats.get(base.table)
        return st.cardinality if st is not None else None
    return None


def plan_runtime_filters(edges, leaf_stats: List[TableStats],
                         sigmas: List[float], params: CostParams,
                         bits_per_key: int = BLOOM_DEFAULT_BITS_PER_KEY,
                         leaves: Optional[List[Node]] = None,
                         kinds=DEFAULT_FILTER_KINDS,
                         cache: Optional[FilterCache] = None
                         ) -> List[RuntimeFilter]:
    """Decide runtime-filter placement + kind per join-graph edge.

    ``sigmas[i]`` is leaf i's estimated match fraction when it plays the
    build role: the share of the probe side's key domain its surviving keys
    cover (measured build cardinality / domain when the executor calls
    this, the static retain fraction in the planner). Every kind in
    ``kinds`` quotes the edge (bloom always; zone map only when ``leaves``
    lets the band test see a range predicate on the build key; semi-join
    always, priced by its exact key list) and the strictly cheapest quote
    wins — ties resolve to the earliest kind in ``kinds``, which keeps the
    bloom-only behaviour bit-stable under the default order. An edge gets
    its winning filter iff the filtered join plus the filter's build +
    broadcast cost is *strictly* cheaper under the RelJoin cost model than
    the unfiltered join — so at sigma = 1 (unfiltered build) nothing is
    ever planned and selections are byte-identical to the paper's. Edges
    derived through key equivalence classes participate too: that is what
    pushes a dimension's filter below exchanges of relations it never
    directly joins.

    With a ``cache`` (cross-query ``FilterCache``), a kind whose payload
    is already cached for the edge's build leaf is quoted at
    ``cached_filter_cost`` instead — broadcast only, the build + reduce
    terms drop — so warm filters clear the gate on edges a cold build
    would not. An empty or absent cache changes no quote: cold-cache
    decisions are byte-identical to the uncached planner's.
    """
    out: List[RuntimeFilter] = []
    seen = set()
    for e in edges:
        ident = (e.probe, e.build, e.probe_key, e.build_key)
        if ident in seen:
            continue
        seen.add(ident)
        a, b = leaf_stats[e.probe], leaf_stats[e.build]
        if a.cardinality <= 0:
            continue
        n = max(b.cardinality, 0.0)
        band = (key_band_fraction(leaves[e.build], e.build_key)
                if leaves is not None else None)
        _, unfiltered = _step(a, b, params)
        best = None          # (total, quote, filtered_cost, cached, cost)
        for kname in kinds:
            quote = FILTER_KINDS[kname].quote(n, sigmas[e.build], band,
                                              bits_per_key, params)
            if quote is None or quote.keep_est >= 1.0:
                continue
            cached = (cache is not None and leaves is not None
                      and cache.contains(filter_cache_key(
                          leaves[e.build], e.build_key, quote.kind,
                          quote.bits, quote.k)))
            cost = (cached_filter_cost(quote.bits, params) if cached
                    else quote.cost)
            _, filtered = _step(a.scaled(quote.keep_est), b, params)
            total = filtered + cost
            if best is None or total < best[0]:
                best = (total, quote, filtered, cached, cost)
        if best is None:
            continue
        total, quote, filtered, cached, cost = best
        if total < unfiltered * (1 - 1e-9):
            out.append(RuntimeFilter(e.probe, e.build, e.probe_key,
                                     e.build_key, quote.bits, quote.k,
                                     sigmas[e.build], quote.keep_est,
                                     unfiltered - filtered, cost,
                                     derived=e.derived, kind=quote.kind,
                                     cached=cached))
    return out


# ---------------------------------------------------------------------------
# Rewrites: predicate pushdown + projection pruning
# ---------------------------------------------------------------------------

def push_down_filters(node: Node, schema: Schema) -> Node:
    """Sink every filter as close to its scan as semantics allow."""
    if isinstance(node, Filter):
        child = push_down_filters(node.child, schema)
        return _sink(dataclasses.replace(node, child=child), schema)
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=push_down_filters(node.left, schema),
            right=push_down_filters(node.right, schema))
    if isinstance(node, (Project, Aggregate)):
        return dataclasses.replace(
            node, child=push_down_filters(node.child, schema))
    return node


#: join types whose probe (left) side accepts pushed filters.
_LEFT_PUSHABLE = (JoinType.INNER, JoinType.LEFT_OUTER, JoinType.LEFT_SEMI,
                  JoinType.LEFT_ANTI)


def _sink(f: Filter, schema: Schema) -> Node:
    c = f.child
    if f.op == "eqcol":
        # Column-to-column predicates reference two leaves of the region
        # (the closing edge of a cyclic join core) — only evaluable where
        # both columns coexist, i.e. exactly where they are written.
        return f
    if isinstance(c, Join):
        try:
            lcols = leaf_columns(c.left, schema)
            rcols = leaf_columns(c.right, schema)
        except (KeyError, TypeError):
            return f
        in_l, in_r = f.column in lcols, f.column in rcols
        if in_l and not in_r and c.join_type in _LEFT_PUSHABLE:
            return dataclasses.replace(
                c, left=_sink(dataclasses.replace(f, child=c.left), schema))
        if in_r and not in_l and c.join_type is JoinType.INNER:
            return dataclasses.replace(
                c, right=_sink(dataclasses.replace(f, child=c.right), schema))
        return f
    if isinstance(c, Filter):
        # Conjunctive filters commute: slide past a stuck sibling so a
        # pushable predicate stacked above an unpushable one still sinks.
        return dataclasses.replace(
            c, child=_sink(dataclasses.replace(f, child=c.child), schema))
    if isinstance(c, Project) and f.column in c.columns:
        return dataclasses.replace(
            c, child=_sink(dataclasses.replace(f, child=c.child), schema))
    if isinstance(c, Aggregate) and f.column == c.key:
        # Filtering on the group key commutes with grouping.
        return dataclasses.replace(
            c, child=_sink(dataclasses.replace(f, child=c.child), schema))
    return f


def prune_projections(node: Node, schema: Schema,
                      required=None) -> Node:
    """Narrow scans to the columns the plan consumes (top-down required-set
    propagation). The root's output columns are always preserved, so the
    rewrite never changes query results."""
    try:
        cols = leaf_columns(node, schema)
    except (KeyError, TypeError):
        return node
    if required is None:
        required = set(cols)
    required = set(required) & set(cols)

    if isinstance(node, Scan):
        keep = tuple(c for c in schema[node.table] if c in required)
        if keep and len(keep) < len(schema[node.table]):
            return Project(node, keep)
        return node
    if isinstance(node, Filter):
        need = required | {node.column}
        if node.column2 is not None:
            need |= {node.column2}
        return dataclasses.replace(
            node, child=prune_projections(node.child, schema, need))
    if isinstance(node, Project):
        keep = tuple(c for c in node.columns if c in required)
        if not keep:
            keep = node.columns
        child = prune_projections(node.child, schema, set(keep))
        return dataclasses.replace(node, child=child, columns=keep)
    if isinstance(node, Aggregate):
        need = {node.key} | {col for col, _ in node.aggs}
        return dataclasses.replace(
            node, child=prune_projections(node.child, schema, need))
    if isinstance(node, Join):
        try:
            lcols = set(leaf_columns(node.left, schema))
            rcols = set(leaf_columns(node.right, schema))
        except (KeyError, TypeError):
            return node
        if lcols & rcols:
            # Colliding names get order-dependent ``_r`` renames — pruning
            # could silently change output naming. Recurse with full sets.
            return dataclasses.replace(
                node, left=prune_projections(node.left, schema),
                right=prune_projections(node.right, schema))
        lneed = (required & lcols) | {node.left_key}
        rneed = (required & rcols) | {node.right_key}
        return dataclasses.replace(
            node, left=prune_projections(node.left, schema, lneed),
            right=prune_projections(node.right, schema, rneed))
    return node


# ---------------------------------------------------------------------------
# Whole-plan optimization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RegionDecision:
    """Audit of one region's ordering decision."""

    n_relations: int
    plan_order_cost: float   # modeled workload of the written order
    chosen_cost: float       # modeled workload of the emitted order
    reordered: bool


@dataclasses.dataclass
class OptimizedPlan:
    plan: Node
    regions: List[RegionDecision]

    @property
    def plan_order_cost(self) -> float:
        return sum(r.plan_order_cost for r in self.regions)

    @property
    def chosen_cost(self) -> float:
        return sum(r.chosen_cost for r in self.regions)

    @property
    def reordered(self) -> bool:
        return any(r.reordered for r in self.regions)


class PlanCache:
    """Cross-query compiled-plan cache, mirroring ``FilterCache``'s key
    discipline.

    Entries are keyed on ``logical.signature(plan)`` plus every
    ``optimize()`` knob that changes the emitted plan (pushdown / prune /
    reorder / bushy / min_region and the cost parameters ``p`` / ``w``),
    and the whole cache is bound to one catalog identity fingerprint
    (version + generation uid) via ``sync`` — a catalog change invalidates
    everything, exactly like ``FilterCache.sync``. A warm hit returns the
    stored ``OptimizedPlan`` and skips the rewrite + DP work entirely;
    ``signature()`` covers filter literals and aggregate specs, so two
    queries share an entry only when their logical plans are identical.
    """

    def __init__(self) -> None:
        self._entries: Dict[tuple, OptimizedPlan] = {}
        self._catalog_fingerprint: Optional[tuple] = None
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def sync(self, catalog: Catalog) -> None:
        """Bind the cache to ``catalog``; drop every entry if it is not
        the catalog the current plans were optimized against."""
        fingerprint = catalog_fingerprint(catalog)
        if fingerprint != self._catalog_fingerprint:
            if self._entries:
                self.invalidations += 1
            self._entries.clear()
            self._catalog_fingerprint = fingerprint

    @staticmethod
    def key(plan: Node, params: CostParams, *, pushdown: bool, prune: bool,
            reorder: bool, bushy: bool, min_region: int) -> tuple:
        return (signature(plan), pushdown, prune, reorder, bushy,
                min_region, params.p, params.w)

    def lookup(self, key: tuple) -> Optional[OptimizedPlan]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def store(self, key: tuple, optimized: OptimizedPlan) -> None:
        self._entries[key] = optimized


def modeled_plan_cost(plan: Node, base_stats: Dict[str, TableStats],
                      schema: Schema, params: CostParams,
                      key_domains: Optional[Dict[str, float]] = None,
                      column_stats: Optional[Dict[str, ColumnStats]] = None
                      ) -> float:
    """Modeled workload of a whole plan: the Eq. 4/8/10 sum of Algorithm 1's
    best feasible method over every join, with statistics statically
    propagated by ``estimate_leaf_stats`` (histogram-backed when
    ``column_stats`` is given). This is the admission controller's cost
    quote — a dimensionless relative workload comparable across queries
    against the same catalog, not a latency prediction."""
    total = 0.0
    for node in (plan, *_descendants(plan)):
        if isinstance(node, Join):
            probe = estimate_leaf_stats(node.left, base_stats, schema,
                                        key_domains, column_stats)
            build = estimate_leaf_stats(node.right, base_stats, schema,
                                        key_domains, column_stats)
            total += _step(probe, build, params)[1]
    return total


def _descendants(node: Node):
    for child in node.children():
        yield child
        yield from _descendants(child)


def build_join_tree(tree, leaves: List[Node]) -> Node:
    """Materialize a DP order tree back into logical Join nodes. A node is
    a leaf index or ``(left_tree, right_tree, probe_key, build_key)`` —
    left-deep steps are simply the case where the right subtree is a leaf."""
    if isinstance(tree, int):
        return leaves[tree]
    left, right, pk, bk = tree
    return Join(build_join_tree(left, leaves),
                build_join_tree(right, leaves), pk, bk)


def optimize(plan: Node, catalog: Optional[Catalog] = None, *,
             schema: Optional[Schema] = None,
             base_stats: Optional[Dict[str, TableStats]] = None,
             params: Optional[CostParams] = None,
             pushdown: bool = True, prune: bool = True,
             reorder: bool = True, bushy: bool = False,
             min_region: int = 3, verify: bool = False,
             plan_cache: Optional[PlanCache] = None) -> OptimizedPlan:
    """Full logical optimization pass.

    Statistics come from ``catalog`` (exact base stats) unless ``base_stats``
    is given. Regions smaller than ``min_region`` relations are left in plan
    order (a 2-relation region has nothing to reorder — side roles are
    already assigned by Algorithm 1).

    ``verify=True`` arms the plan-analysis debug gate: the input plan is
    statically analyzed, and the rewritten plan must pass the same
    analysis *and* preserve the output schema (rule P2) — any violation
    raises ``PlanVerificationError``.

    ``plan_cache`` (used only when ``catalog`` is given, since the cache
    binds to a catalog fingerprint) short-circuits the whole pass on a
    warm hit: the cache is synced to the catalog, keyed on the input
    plan's signature + every rewrite knob, and a stored ``OptimizedPlan``
    is returned as-is. Misses run the normal pass and store the result.
    """
    if schema is None:
        if catalog is None:
            raise ValueError("optimize() needs a catalog or an explicit "
                             "schema")
        schema = catalog_schema(catalog)
    if base_stats is None:
        base_stats = catalog_base_stats(catalog) if catalog else {}
    if params is None:
        params = CostParams(p=catalog.p if catalog else 8, w=1.0)
    cache_key = None
    if plan_cache is not None and catalog is not None:
        plan_cache.sync(catalog)
        cache_key = PlanCache.key(plan, params, pushdown=pushdown,
                                  prune=prune, reorder=reorder, bushy=bushy,
                                  min_region=min_region)
        cached = plan_cache.lookup(cache_key)
        if cached is not None:
            return cached
    original = plan
    if verify:
        # Imported here: plan_analysis is optimizer-independent, but
        # keeping the planner import-light avoids pulling the analyzer
        # into every planner consumer.
        from .plan_analysis import PlanVerificationError, analyze_plan
        violations = analyze_plan(plan, schema)
        if violations:
            raise PlanVerificationError(violations)

    if pushdown:
        plan = push_down_filters(plan, schema)
    if prune:
        plan = prune_projections(plan, schema)

    regions: List[RegionDecision] = []
    key_domains = catalog.key_domains if catalog is not None else None
    column_stats = catalog.column_stats if catalog is not None else None

    def rewrite(node: Node) -> Node:
        if reorder and isinstance(node, Join):
            graph = extract_join_graph(node, schema)
            if graph is not None and graph.n >= min_region:
                # Region leaves may hold nested reorderable regions (e.g.
                # under an Aggregate): rewrite them first.
                leaves = [rewrite(l) for l in graph.leaves]
                try:
                    stats = [estimate_leaf_stats(l, base_stats, schema,
                                                 key_domains, column_stats)
                             for l in leaves]
                except KeyError:
                    stats = None
                if stats is not None:
                    retain = [stats_retain_fraction(l, key_domains,
                                                    column_stats)
                              for l in leaves]
                    plan_cost = modeled_tree_cost(graph, stats, retain,
                                                  params)
                    order = enumerate_join_order(stats, retain,
                                                 augment_edges(graph),
                                                 params, bushy=bushy)
                    if (order is not None
                            and order.cost < plan_cost * (1 - 1e-9)):
                        regions.append(RegionDecision(graph.n, plan_cost,
                                                      order.cost, True))
                        return build_join_tree(order.tree, leaves)
                    regions.append(RegionDecision(graph.n, plan_cost,
                                                  plan_cost, False))
                return build_region_plan_order(
                    JoinGraph(leaves, graph.edges, graph.tree))
        if isinstance(node, Join):
            return dataclasses.replace(node, left=rewrite(node.left),
                                       right=rewrite(node.right))
        if isinstance(node, (Filter, Project, Aggregate)):
            return dataclasses.replace(node, child=rewrite(node.child))
        return node

    rewritten = rewrite(plan)
    if verify:
        from .plan_analysis import (PlanVerificationError, analyze_plan,
                                    check_schema_preserved)
        violations = (check_schema_preserved(original, rewritten, schema)
                      + analyze_plan(rewritten, schema))
        if violations:
            raise PlanVerificationError(violations)
    optimized = OptimizedPlan(rewritten, regions)
    if cache_key is not None:
        plan_cache.store(cache_key, optimized)
    return optimized


def build_region_plan_order(graph: JoinGraph) -> Node:
    """Rebuild a region's written order from its extracted tree."""

    def go(t):
        if isinstance(t, int):
            return graph.leaves[t]
        e = graph.edges[t[2]]
        return Join(go(t[0]), go(t[1]), e.probe_key, e.build_key)

    return go(graph.tree)
