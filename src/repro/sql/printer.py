"""SQL pretty-printer: render a logical plan back to dialect text.

The inverse of the front end for binder-producible plans: for any plan the
binder can emit, ``parse_sql(to_sql(plan))`` binds to a plan with the same
signature (and the same execution result — the property test pins both).
Declared filter selectivities are the one lossy part: SQL has no syntax for
them, so a reparse bakes the schema-derived estimate instead.

Rendering rules mirror the binder's lowering in reverse:

  * a top-of-tree Filter chain becomes the WHERE clause (innermost filter
    printed first, so textual re-application nests identically),
  * LEFT_SEMI / LEFT_ANTI joins become ``[NOT] IN (subquery)`` predicates,
  * INNER / LEFT_OUTER chains become explicit ``JOIN ... ON`` lists, with
    any non-Scan side parenthesized as a derived table,
  * Aggregate becomes ``SELECT key, AGG(col), ... GROUP BY key`` and
    Project a plain column list.

Literals render via ``repr`` (shortest exact float round-trip), so parsed
constants — and therefore plan signatures — are preserved bit-for-bit.
"""

from __future__ import annotations

from typing import List, Tuple

from ..core.selection import JoinType
from .logical import Aggregate, Filter, Join, Node, Project, Scan, filter_chain

__all__ = ["to_sql"]

_OP_SQL = {"eq": "=", "ne": "<>", "lt": "<", "le": "<=", "gt": ">",
           "ge": ">="}
_AGG_SQL = {"sum": "SUM", "count": "COUNT", "min": "MIN", "max": "MAX",
            "mean": "AVG"}


def _lit(v: float) -> str:
    return repr(float(v))


def _pred_sql(f: Filter) -> str:
    if f.op == "between":
        return f"{f.column} BETWEEN {_lit(f.value)} AND {_lit(f.value2)}"
    if f.op == "in":
        if not f.values:
            raise ValueError("cannot print an IN filter with no values")
        return f"{f.column} IN ({', '.join(_lit(v) for v in f.values)})"
    return f"{f.column} {_OP_SQL[f.op]} {_lit(f.value)}"


def _from_and_where(node: Node) -> Tuple[str, List[str]]:
    """Split a subtree into a FROM clause and its WHERE conjuncts, in the
    textual order whose re-binding rebuilds this exact subtree."""
    base, filters = filter_chain(node)  # outermost-first
    preds = [_pred_sql(f) for f in reversed(filters)]
    if isinstance(base, Join) and base.join_type in (JoinType.LEFT_SEMI,
                                                     JoinType.LEFT_ANTI):
        from_sql, inner = _from_and_where(base.left)
        op = "NOT IN" if base.join_type is JoinType.LEFT_ANTI else "IN"
        sub = _subquery_sql(base.right, base.right_key)
        return from_sql, inner + [f"{base.left_key} {op} ({sub})"] + preds
    return _chain_sql(base), preds


def _chain_sql(node: Node) -> str:
    """An INNER / LEFT OUTER join chain as explicit JOIN ... ON text."""
    if isinstance(node, Join) and node.join_type in (JoinType.INNER,
                                                     JoinType.LEFT_OUTER):
        kw = "LEFT JOIN" if node.join_type is JoinType.LEFT_OUTER else "JOIN"
        return (f"{_chain_sql(node.left)} {kw} {_rel_sql(node.right)}"
                f" ON {node.left_key} = {node.right_key}")
    return _rel_sql(node)


def _rel_sql(node: Node) -> str:
    """One FROM relation: a bare table name or a derived table."""
    if isinstance(node, Scan):
        return node.table
    return f"({to_sql(node)})"


def _subquery_sql(node: Node, key: str) -> str:
    """The text of an IN-subquery exposing ``key`` as its first item."""
    if isinstance(node, Aggregate) and node.key == key:
        return to_sql(node)
    from_sql, preds = _from_and_where(node)
    return f"SELECT {key} FROM {from_sql}{_where_sql(preds)}"


def _where_sql(preds: List[str]) -> str:
    return f" WHERE {' AND '.join(preds)}" if preds else ""


def to_sql(plan: Node) -> str:
    """Render a logical plan as one SELECT statement of the dialect."""
    if isinstance(plan, Aggregate):
        if not plan.aggs:
            raise ValueError("cannot print an Aggregate with no aggregates")
        from_sql, preds = _from_and_where(plan.child)
        items = ", ".join([plan.key] + [f"{_AGG_SQL[op]}({col})"
                                        for col, op in plan.aggs])
        return (f"SELECT {items} FROM {from_sql}{_where_sql(preds)}"
                f" GROUP BY {plan.key}")
    if isinstance(plan, Project):
        from_sql, preds = _from_and_where(plan.child)
        return (f"SELECT {', '.join(plan.columns)} FROM {from_sql}"
                f"{_where_sql(preds)}")
    from_sql, preds = _from_and_where(plan)
    return f"SELECT * FROM {from_sql}{_where_sql(preds)}"
