"""Op-aware filter selectivity derivation (shared by binder + estimator).

The hand-built suite declares every ``Filter.selectivity`` by hand; parsed
SQL text cannot. This module derives the estimate from the synthetic
schema's column metadata instead: every generated column is uniform over a
known domain (``datagen.COLUMN_DOMAINS`` for payload + date columns,
``datagen.STATIC_KEY_DOMAINS`` / ``Catalog.key_domains`` for FK/PK
columns), so an op-specific fraction is exact, not a guess — ``d_month eq
6`` is 1/12 under the 360-day calendar, ``ss_quantity lt 10`` is 9/99,
``i_category in (1,3,5)`` is 3/10.

Since PR 10 the estimator can also consult *measured* per-column
statistics (``Catalog.column_stats``: NDV / MCV / equi-depth histograms
from ``core.stats``). A histogram, when one covers the filter's column,
wins over both the declared selectivity and the domain fractions: the
parsed-SQL binder bakes a domain-derived estimate into every ``Filter``
it emits, so data-driven estimates must take precedence over declared
ones to ever bite — and a measured histogram is strictly better
information than either. Without stats (hand-built catalogs, unknown
columns) the old precedence stands: declared selectivity wins, then
domain fractions, then ``DEFAULT_SELECTIVITY``.
"""

from __future__ import annotations

import math
from typing import Mapping, Optional

from ..core.stats import ColumnStats
from . import datagen
from .logical import Filter

#: Fallback when nothing is known about the column.
DEFAULT_SELECTIVITY = 0.5

__all__ = ["DEFAULT_SELECTIVITY", "derive_selectivity"]


def _clamp(x: float) -> float:
    return min(max(x, 0.0), 1.0)


def _int_fraction(f: Filter, lo: float, hi: float) -> float:
    """Fraction of the integer domain ``[lo, hi)`` a predicate keeps."""
    n = hi - lo
    if n <= 0:
        return DEFAULT_SELECTIVITY

    def count_lt(v: float) -> float:
        return min(max(math.ceil(v) - lo, 0.0), n)

    def count_le(v: float) -> float:
        return min(max(math.floor(v) - lo + 1.0, 0.0), n)

    def count_eq(v: float) -> float:
        return 1.0 if (lo <= v < hi and float(v).is_integer()) else 0.0

    if f.op == "eq":
        return count_eq(f.value) / n
    if f.op == "ne":
        return 1.0 - count_eq(f.value) / n
    if f.op == "lt":
        return count_lt(f.value) / n
    if f.op == "le":
        return count_le(f.value) / n
    if f.op == "gt":
        return (n - count_le(f.value)) / n
    if f.op == "ge":
        return (n - count_lt(f.value)) / n
    if f.op == "between":
        return max(count_le(f.value2) - count_lt(f.value), 0.0) / n
    if f.op == "in":
        return sum(count_eq(v) for v in set(f.values)) / n
    raise ValueError(f"unknown filter op {f.op}")


def _float_fraction(f: Filter, lo: float, hi: float) -> float:
    """Fraction of the continuous-uniform domain ``[lo, hi)`` kept.
    Point predicates (``eq``/``in``) have measure zero; ``ne`` measure one.
    """
    width = hi - lo
    if width <= 0:
        return DEFAULT_SELECTIVITY
    if f.op == "eq":
        return 0.0
    if f.op == "ne":
        return 1.0
    if f.op in ("lt", "le"):
        return _clamp((f.value - lo) / width)
    if f.op in ("gt", "ge"):
        return _clamp((hi - f.value) / width)
    if f.op == "between":
        return _clamp((min(f.value2, hi) - max(f.value, lo)) / width)
    if f.op == "in":
        return 0.0
    raise ValueError(f"unknown filter op {f.op}")


def derive_selectivity(f: Filter,
                       key_domains: Optional[Mapping[str, float]] = None,
                       column_stats: Optional[Mapping[str, ColumnStats]]
                       = None) -> float:
    """Selectivity estimate for one Filter.

    A per-column histogram (``column_stats``, keyed by column name) wins
    when it covers the filter's column: its MCV/equi-depth fraction is the
    measured answer, overriding even a declared ``f.selectivity`` (the
    binder bakes domain estimates into every parsed filter — see the
    module docstring). Otherwise declared wins, then the column's domain
    is looked up — payload/date columns in ``COLUMN_DOMAINS``, key columns
    in ``key_domains`` (e.g. a live ``Catalog.key_domains``) falling back
    to the static ``STATIC_KEY_DOMAINS`` — and the op-specific kept
    fraction computed. Unknown columns get ``DEFAULT_SELECTIVITY``.
    """
    if f.op == "eqcol":
        # Column-to-column equality: no literal to intersect with a domain
        # or histogram. Declared wins; otherwise two independent uniform
        # columns over a shared domain of n values match with probability
        # 1/n — but the estimator has no join-aware domain here, so keep
        # the conservative default.
        if f.selectivity is not None:
            return f.selectivity
        return DEFAULT_SELECTIVITY
    if column_stats is not None:
        cs = column_stats.get(f.column)
        if cs is not None and cs.count > 0:
            return _clamp(cs.fraction(f.op, f.value, f.value2, f.values))
    if f.selectivity is not None:
        return f.selectivity
    dom = datagen.COLUMN_DOMAINS.get(f.column)
    if dom is None:
        n = None
        if key_domains is not None:
            n = key_domains.get(f.column)
        if n is None:
            n = datagen.STATIC_KEY_DOMAINS.get(f.column)
        if n is None or n <= 0:
            return DEFAULT_SELECTIVITY
        dom = (0, n, True)
    lo, hi, integral = dom
    frac = (_int_fraction(f, lo, hi) if integral
            else _float_fraction(f, lo, hi))
    return _clamp(frac)
