"""Pluggable runtime-filter kinds (sideways information passing framework).

PR 3 hard-wired one reducer — the bloom pair — into planner and executor.
This module turns that into a *framework*: a ``RuntimeFilterKind`` knows
how to

  * **quote** itself for a join-graph edge (serialized wire size, planned
    kept fraction, build+broadcast workload under the RelJoin cost model),
  * **build** its payload from the build side's surviving join keys, and
  * **probe** a key column into a keep-mask (never a false negative).

so ``plan_runtime_filters`` can price every applicable kind per edge and
keep the strictly cheapest — the same relative-cost selection Algorithm 1
applies to join methods, applied to reducers:

    kind        wire size      kept fraction        applicable when
    ---------   ------------   ------------------   --------------------
    bloom       m ~ 10n bits   max(sigma, fpr)      always
    zone_map    64 bits        band width           key set band-shaped
    semi_join   32n bits       sigma (exact)        key list small

Every payload is a pure function of the build key *set* (order- and
duplication-invariant), and every probe mask admits false positives only —
the two properties result preservation rests on. An empty build side
yields the reject-everything payload for every kind (zero bloom array,
empty zone interval, empty key list).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from ..core.cost_model import (CostParams, SEMI_JOIN_BITS_PER_KEY,
                               ZONE_MAP_BITS, bloom_fpr, bloom_params,
                               bloom_total_cost, filtered_probe_fraction,
                               semi_join_cost, zone_map_cost)
from ..core.psts import key_set, semi_join_mask
from ..joins.table import Table
from ..kernels.bloom import bloom_build, bloom_probe
from ..kernels.zone_map import key_range, range_probe
from .logical import RuntimeFilter


@dataclasses.dataclass(frozen=True)
class FilterQuote:
    """One kind's offer for one edge: what it ships, what it keeps, what
    it costs to build + broadcast (cost-model workload units)."""

    kind: str
    bits: int           # serialized wire size
    k: int              # bloom hash count (0 otherwise)
    keep_est: float     # planned kept fraction of the probe side
    cost: float         # reduce-tree + broadcast workload


class RuntimeFilterKind:
    """Protocol of one pluggable reducer. Subclasses are stateless."""

    name: str = "base"

    def quote(self, n_keys: float, sigma: float, band: Optional[float],
              bits_per_key: int, params: CostParams
              ) -> Optional[FilterQuote]:
        """Price this kind for an edge; None when not applicable.
        ``n_keys`` is the estimated distinct build-key count, ``sigma``
        the estimated match fraction, ``band`` the band-width fraction of
        the build leaf's key set (None = not band-shaped)."""
        raise NotImplementedError

    def build(self, build: Table, key: str, rf: RuntimeFilter):
        """Payload from the build side's surviving keys (a jax pytree)."""
        raise NotImplementedError

    def probe(self, keys: jax.Array, payload, rf: RuntimeFilter
              ) -> jax.Array:
        """Keep-mask of ``keys`` against a payload (no false negatives)."""
        raise NotImplementedError


class BloomKind(RuntimeFilterKind):
    """PR 3's bit-packed bloom pair: always applicable, densest encoding
    (~10 bits/key), kept fraction floored by the false-positive rate."""

    name = "bloom"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        m_bits, k = bloom_params(n_keys, bits_per_key)
        keep = filtered_probe_fraction(sigma, bloom_fpr(n_keys, m_bits, k))
        return FilterQuote(self.name, m_bits, k, keep,
                           bloom_total_cost(m_bits, params))

    def build(self, build, key, rf):
        return bloom_build(build.column(key), build.valid,
                           m_bits=rf.m_bits, k=rf.k)

    def probe(self, keys, payload, rf):
        return bloom_probe(keys, payload, k=rf.k)


class ZoneMapKind(RuntimeFilterKind):
    """Min/max interval (8 bytes on the wire): applicable when the build
    leaf's surviving keys are band-shaped — a range predicate on the key
    itself — where it keeps exactly the band at the lowest possible
    broadcast cost."""

    name = "zone_map"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        if band is None:
            return None
        keep = min(max(band, 0.0), 1.0)
        return FilterQuote(self.name, ZONE_MAP_BITS, 0, keep,
                           zone_map_cost(params))

    def build(self, build, key, rf):
        return key_range(build.column(key), build.valid)

    def probe(self, keys, payload, rf):
        return range_probe(keys, payload)


class SemiJoinKind(RuntimeFilterKind):
    """Exact semi-join reducer over the distinct-key machinery in
    ``core.psts``: ships the sorted key list (32 bits/key), keeps exactly
    sigma. Beats bloom when the key list is small enough that exactness
    outprices the denser encoding — high-selectivity, small-domain
    dimensions."""

    name = "semi_join"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        bits = int(max(n_keys, 0.0) * SEMI_JOIN_BITS_PER_KEY)
        keep = min(max(sigma, 0.0), 1.0)
        return FilterQuote(self.name, bits, 0, keep,
                           semi_join_cost(n_keys, params))

    def build(self, build, key, rf):
        return key_set(build.column(key), build.valid)

    def probe(self, keys, payload, rf):
        sorted_keys, n = payload
        return semi_join_mask(keys, sorted_keys, n)


FILTER_KINDS: Dict[str, RuntimeFilterKind] = {
    k.name: k for k in (BloomKind(), ZoneMapKind(), SemiJoinKind())
}

#: Planner's default scoring order. Bloom first: on an exact cost tie the
#: earlier kind wins, which keeps PR-3 decisions bit-stable.
DEFAULT_FILTER_KINDS: Tuple[str, ...] = ("bloom", "zone_map", "semi_join")


def build_filter_payload(rf: RuntimeFilter, build: Table):
    """Materialize the planned filter from the build side's live keys."""
    return FILTER_KINDS[rf.kind].build(build, rf.build_key, rf)


def probe_filter_mask(rf: RuntimeFilter, payload, keys: jax.Array
                      ) -> jax.Array:
    """Keep-mask of a probe-side key column against a built payload."""
    return FILTER_KINDS[rf.kind].probe(keys, payload, rf)
