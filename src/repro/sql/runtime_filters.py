"""Pluggable runtime-filter kinds (sideways information passing framework).

PR 3 hard-wired one reducer — the bloom pair — into planner and executor.
This module turns that into a *framework*: a ``RuntimeFilterKind`` knows
how to

  * **quote** itself for a join-graph edge (serialized wire size, planned
    kept fraction, build+broadcast workload under the RelJoin cost model),
  * **build** its payload from the build side's surviving join keys, and
  * **probe** a key column into a keep-mask (never a false negative).

so ``plan_runtime_filters`` can price every applicable kind per edge and
keep the strictly cheapest — the same relative-cost selection Algorithm 1
applies to join methods, applied to reducers:

    kind        wire size      kept fraction        applicable when
    ---------   ------------   ------------------   --------------------
    bloom       m ~ 10n bits   max(sigma, fpr)      always
    zone_map    64 bits        band width           key set band-shaped
    semi_join   32n bits       sigma (exact)        key list small

Every payload is a pure function of the build key *set* (order- and
duplication-invariant), and every probe mask admits false positives only —
the two properties result preservation rests on. An empty build side
yields the reject-everything payload for every kind (zero bloom array,
empty zone interval, empty key list).

**Distributed-equivalence contract.** Each kind's ``build`` has a
distributed twin in ``joins/distributed.py`` (``dist_bloom_build``,
``dist_zone_map_build``, ``dist_key_set_build``) whose merged result is
bit-/value-identical to the global build at any device count — so probe
masks, and therefore query results, never depend on where the build ran.
The cost model charges each kind its actual merge shape
(``filter_reduce_cost(kind=...)``).

**Cross-query caching.** Payload purity is also what makes filters
*cacheable*: two queries whose build leaves scan the same table through
the same (order-normalized) predicate chain surface the same key set, so
the built payload can be reused verbatim. ``FilterCache`` keys entries on
``(table, normalized predicate chain, join key, kind, size params)`` and
is invalidated by the catalog identity fingerprint (version + generation
uid, ``catalog_fingerprint``); the planner quotes a cache-hit
edge at ``cached_filter_cost`` (broadcast only — the build + reduce terms
drop), which plans cached filters more aggressively than cold ones while
leaving cold-cache decisions byte-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from ..core.cost_model import (CostParams, SEMI_JOIN_BITS_PER_KEY,
                               ZONE_MAP_BITS, bloom_fpr, bloom_params,
                               bloom_total_cost, filtered_probe_fraction,
                               semi_join_cost, zone_map_cost)
from ..core.psts import key_set, semi_join_mask
from ..core.stats import StatsSource, TableStats
from ..joins.table import Table
from ..kernels.bloom import bloom_build, bloom_probe
from ..kernels.zone_map import key_range, range_probe
from .datagen import catalog_fingerprint
from .logical import (Node, Project, RuntimeFilter, Scan, filter_chain)


@dataclasses.dataclass(frozen=True)
class FilterQuote:
    """One kind's offer for one edge: what it ships, what it keeps, what
    it costs to build + broadcast (cost-model workload units)."""

    kind: str
    bits: int           # serialized wire size
    k: int              # bloom hash count (0 otherwise)
    keep_est: float     # planned kept fraction of the probe side
    cost: float         # reduce-tree + broadcast workload


class RuntimeFilterKind:
    """Protocol of one pluggable reducer. Subclasses are stateless."""

    name: str = "base"

    def quote(self, n_keys: float, sigma: float, band: Optional[float],
              bits_per_key: int, params: CostParams
              ) -> Optional[FilterQuote]:
        """Price this kind for an edge; None when not applicable.
        ``n_keys`` is the estimated distinct build-key count, ``sigma``
        the estimated match fraction, ``band`` the band-width fraction of
        the build leaf's key set (None = not band-shaped)."""
        raise NotImplementedError

    def build(self, build: Table, key: str, rf: RuntimeFilter):
        """Payload from the build side's surviving keys (a jax pytree)."""
        raise NotImplementedError

    def probe(self, keys: jax.Array, payload, rf: RuntimeFilter
              ) -> jax.Array:
        """Keep-mask of ``keys`` against a payload (no false negatives)."""
        raise NotImplementedError


class BloomKind(RuntimeFilterKind):
    """PR 3's bit-packed bloom pair: always applicable, densest encoding
    (~10 bits/key), kept fraction floored by the false-positive rate."""

    name = "bloom"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        m_bits, k = bloom_params(n_keys, bits_per_key)
        keep = filtered_probe_fraction(sigma, bloom_fpr(n_keys, m_bits, k))
        return FilterQuote(self.name, m_bits, k, keep,
                           bloom_total_cost(m_bits, params))

    def build(self, build, key, rf):
        return bloom_build(build.column(key), build.valid,
                           m_bits=rf.m_bits, k=rf.k)

    def probe(self, keys, payload, rf):
        return bloom_probe(keys, payload, k=rf.k)


class ZoneMapKind(RuntimeFilterKind):
    """Min/max interval (8 bytes on the wire): applicable when the build
    leaf's surviving keys are band-shaped — a range predicate on the key
    itself — where it keeps exactly the band at the lowest possible
    broadcast cost."""

    name = "zone_map"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        if band is None:
            return None
        keep = min(max(band, 0.0), 1.0)
        return FilterQuote(self.name, ZONE_MAP_BITS, 0, keep,
                           zone_map_cost(params))

    def build(self, build, key, rf):
        return key_range(build.column(key), build.valid)

    def probe(self, keys, payload, rf):
        return range_probe(keys, payload)


class SemiJoinKind(RuntimeFilterKind):
    """Exact semi-join reducer over the distinct-key machinery in
    ``core.psts``: ships the sorted key list (32 bits/key), keeps exactly
    sigma. Beats bloom when the key list is small enough that exactness
    outprices the denser encoding — high-selectivity, small-domain
    dimensions."""

    name = "semi_join"

    def quote(self, n_keys, sigma, band, bits_per_key, params):
        bits = int(max(n_keys, 0.0) * SEMI_JOIN_BITS_PER_KEY)
        keep = min(max(sigma, 0.0), 1.0)
        return FilterQuote(self.name, bits, 0, keep,
                           semi_join_cost(n_keys, params))

    def build(self, build, key, rf):
        return key_set(build.column(key), build.valid)

    def probe(self, keys, payload, rf):
        sorted_keys, n = payload
        return semi_join_mask(keys, sorted_keys, n)


FILTER_KINDS: Dict[str, RuntimeFilterKind] = {
    k.name: k for k in (BloomKind(), ZoneMapKind(), SemiJoinKind())
}

#: Planner's default scoring order. Bloom first: on an exact cost tie the
#: earlier kind wins, which keeps PR-3 decisions bit-stable.
DEFAULT_FILTER_KINDS: Tuple[str, ...] = ("bloom", "zone_map", "semi_join")


def build_filter_payload(rf: RuntimeFilter, build: Table):
    """Materialize the planned filter from the build side's live keys."""
    return FILTER_KINDS[rf.kind].build(build, rf.build_key, rf)


def probe_filter_mask(rf: RuntimeFilter, payload, keys: jax.Array
                      ) -> jax.Array:
    """Keep-mask of a probe-side key column against a built payload."""
    return FILTER_KINDS[rf.kind].probe(keys, payload, rf)


# ---------------------------------------------------------------------------
# Cross-query filter cache
# ---------------------------------------------------------------------------

def predicate_chain(leaf: Node) -> Optional[Tuple[str, tuple]]:
    """Normalized conjunctive predicate chain of a Scan-rooted leaf.

    Returns ``(table, sorted (column, op, value, value2, values) specs)``
    — conjunctive filters commute, so sorting makes ``F1(F2(scan))`` and
    ``F2(F1(scan))`` identical, and projections are transparent (they
    never change a column's values). IN-list literals are part of the
    spec (order-normalized, deduplicated): two different IN lists select
    different key sets and must never share a cache entry. Returns None
    for leaves not rooted in a Scan (e.g. aggregated subqueries), whose
    surviving key set is not determined by a predicate chain. This
    normalization is the ground truth both for ``filter_cache_key`` and
    for the analyzer's cache-reuse rule (a stored payload may only serve
    an edge whose chain is a superset of the stored one)."""
    preds = []
    node = leaf
    while True:
        base, filters = filter_chain(node)
        preds.extend((f.column, f.op, float(f.value), float(f.value2),
                      tuple(sorted(set(float(v) for v in f.values))))
                     for f in filters)
        if isinstance(base, Project):
            node = base.child
            continue
        break
    if not isinstance(base, Scan):
        return None
    return base.table, tuple(sorted(preds))


def filter_cache_key(leaf: Node, build_key: str, kind: str, m_bits: int,
                     k: int) -> Optional[tuple]:
    """Canonical cache identity of one (build leaf, kind, params) combo.

    The payload is a pure function of the build leaf's surviving key
    *set*, which for a Scan-rooted leaf is fully determined by its
    :func:`predicate_chain` plus the key column. The kind and its size
    parameters (``m_bits``, and ``k`` for bloom) complete the key: a
    differently-sized bloom array is a different payload even over the
    same key set.

    Returns None — uncacheable — for leaves not rooted in a Scan (e.g.
    aggregated subqueries): their key set depends on the whole subtree's
    execution, which the chain normalization does not capture.
    """
    chain = predicate_chain(leaf)
    if chain is None:
        return None
    table, preds = chain
    return (table, preds, build_key, kind, m_bits, k)


def chain_stats_key(leaf: Node, build_key: str) -> Optional[tuple]:
    """Kind-independent identity of a build leaf's surviving key set —
    ``filter_cache_key`` minus the payload shape. Two payload-distinct
    cache entries (different kind or size) built over the same leaf chain
    measured the *same* build side, so the cache indexes its measured
    build-side stats by this key: a warm cache can then seed the planner's
    sigma estimate for any later query scanning the same chain, whatever
    filter kind that query ends up planning."""
    chain = predicate_chain(leaf)
    if chain is None:
        return None
    table, preds = chain
    return (table, preds, build_key)


@dataclasses.dataclass
class _CacheEntry:
    payload: object            # the built filter (a jax pytree)
    build_stats: TableStats    # measured build-side stats at build time


class FilterCache:
    """Cross-query runtime-filter cache (multi-query amortization).

    q19-q23 rebuild identical dimension filters on every run — exactly
    the redundant runtime work adaptive replanning overhead studies show
    dominating repeat executions. A ``FilterCache`` shared across
    ``Executor`` instances (pass it to ``FilteredStrategy(cache=...)``)
    reuses built payloads instead: the executor consults it before every
    build and stores what it builds (with the measured build-side stats),
    and the planner quotes cache-hit edges at ``cached_filter_cost`` —
    broadcast only, the build + reduce terms drop — so cached filters are
    planned *more* aggressively than cold ones. With an empty (or no)
    cache every quote and selection is byte-identical to the uncached
    planner, preserving the strictly-cheaper gate.

    Validity is keyed on the catalog identity fingerprint
    (``catalog_fingerprint``: version *and* generation uid): ``sync``
    drops every entry when the executor's catalog differs from the one
    the entries were built against (regenerated data, new
    scale/seed/skew), so a stale payload can never filter fresh data —
    even when two distinct catalogs happen to share a version number.
    Entries are never evicted
    otherwise — payloads are tiny (bits on the wire by design) and the
    workload suite is finite; an LRU bound can ride on top when needed.

    ``hits`` / ``misses`` / ``invalidations`` counters make the cache's
    behaviour auditable in tests and benchmarks.
    """

    def __init__(self) -> None:
        self._entries: Dict[tuple, _CacheEntry] = {}
        # Measured build-side stats by chain identity (``chain_stats_key``:
        # the entry key minus kind/shape) — the planner-facing side table
        # that seeds sigma estimates on warm runs. Only RUNTIME-sourced
        # stats enter: an estimated stat must never masquerade as a
        # measurement.
        self._chain_stats: Dict[tuple, TableStats] = {}
        self._catalog_fingerprint: Optional[tuple] = None
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def sync(self, catalog) -> None:
        """Bind the cache to ``catalog``; invalidate everything if it is
        not the catalog the current entries were built against. Identity
        is the full fingerprint (version + generation uid), so two
        distinct catalogs sharing a version number can never reuse each
        other's payloads."""
        fingerprint = catalog_fingerprint(catalog)
        if fingerprint != self._catalog_fingerprint:
            if self._entries:
                self.invalidations += 1
            self._entries.clear()
            self._chain_stats.clear()
            self._catalog_fingerprint = fingerprint

    def contains(self, key: Optional[tuple]) -> bool:
        """Planner-side peek: would ``lookup`` hit? (No counter traffic —
        quoting every kind for every edge is not a cache consultation.)"""
        return key is not None and key in self._entries

    def lookup(self, key: Optional[tuple]):
        """Executor-side consult: the cached payload, or None. Counts a
        hit or miss; uncacheable keys (None) count as misses."""
        entry = self._entries.get(key) if key is not None else None
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return entry.payload

    def store(self, key: Optional[tuple], payload,
              build_stats: TableStats) -> None:
        """Record a freshly built payload (no-op for uncacheable keys)."""
        if key is not None:
            self._entries[key] = _CacheEntry(payload, build_stats)
            if build_stats.source is StatsSource.RUNTIME:
                self._chain_stats[key[:3]] = build_stats

    def build_stats(self, key: Optional[tuple]) -> Optional[TableStats]:
        """Measured build-side stats recorded with a cached payload."""
        entry = self._entries.get(key) if key is not None else None
        return entry.build_stats if entry is not None else None

    def measured_build_stats(self, key: Optional[tuple]
                             ) -> Optional[TableStats]:
        """Runtime-measured build-side stats for a ``chain_stats_key`` —
        the warm-cache sigma seed (None when cold or never measured)."""
        return self._chain_stats.get(key) if key is not None else None
