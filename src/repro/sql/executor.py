"""Adaptive stage-wise plan execution (paper §4.1, Fig. 3).

The executor walks the physical plan bottom-up. Every Join/Aggregate is an
exchange boundary == query-stage barrier: its inputs are materialized, their
*measured* (size, cardinality) become the adaptive runtime statistics, and
the method for the join about to run is (re-)selected with those statistics
— the paper's per-stage re-optimization (selection per join is independent,
§4.2, so bottom-up re-selection yields the model-global optimum).

``adaptive=False`` reproduces a static optimizer: selections use statistics
propagated from base tables through operator estimation rules (optionally
perturbed by ``est_error`` to emulate stale catalogs — the paper's §1
motivation for adaptivity).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp

from ..core.cost_model import (BLOOM_DEFAULT_BITS_PER_KEY,
                               DEFAULT_REOPT_QERROR, CostParams, JoinMethod,
                               filter_reduce_cost, runtime_filter_cost)
from ..core.selection import JoinProperties, JoinType, Selection
from ..core.stats import (StatsSource, TableStats, estimate_filter,
                          estimate_group_by, estimate_join, q_error)
from ..joins.aggregate import group_aggregate
from ..joins.exchange import key_skew
from ..joins.methods import (HypercubeLink, HypercubeSpec, JoinReport,
                             hypercube_multiway_join, run_equi_join)
from ..joins.table import Table, compact_partitions
from .datagen import Catalog
from .logical import (Aggregate, Filter, Join, JoinEdge, Node, Project,
                      RuntimeFilter, Scan, augment_edges,
                      effective_selectivity, extract_join_graph,
                      key_retain_fraction, leaf_columns, signature)
from .plan_analysis import (PlanVerificationError, Violation, analyze_plan,
                            audit_exchanges, audit_filter_decision,
                            audit_selection, catalog_dtypes, check_cache_reuse,
                            check_cache_store, check_filter_placement,
                            check_filter_quote, check_reopt_decision,
                            check_replan_step, check_schema_preserved)
from .planner import (JoinStep, catalog_base_stats, catalog_schema,
                      enumerate_join_order, leaf_key_domain,
                      modeled_tree_cost, plan_hypercube,
                      plan_runtime_filters, prune_projections,
                      push_down_filters, semi_match_fraction,
                      stats_retain_fraction)
from .runtime_filters import (DEFAULT_FILTER_KINDS, build_filter_payload,
                              chain_stats_key, filter_cache_key,
                              predicate_chain, probe_filter_mask)
from .selectivity import derive_selectivity
from .strategies import Strategy

#: Shuffle-family methods: both sides cross the wire, so a probe-side
#: runtime filter reduces their exchange bytes (broadcast ships B only).
_SHUFFLE_FAMILY = (JoinMethod.SHUFFLE_HASH, JoinMethod.SHUFFLE_SORT,
                   JoinMethod.SALTED_SHUFFLE_HASH)

#: Join types for which a probe-side runtime filter is semantics-free.
#: INNER/LEFT_SEMI: dropped probe rows cannot appear in the result (no
#: filter kind has false negatives). LEFT_OUTER: dropped probe rows DO
#: appear (null-padded), so the executor captures them before the join
#: and re-injects them afterwards with zero-padded build columns and
#: ``_matched=False`` — exactly what the join itself would have produced
#: for them (the padding path; plan-analysis rule F1). LEFT_ANTI stays
#: unfilterable: the filter would drop exactly the rows the query keeps.
_FILTERABLE_TYPES = (JoinType.INNER, JoinType.LEFT_SEMI,
                     JoinType.LEFT_OUTER)


@dataclasses.dataclass
class JoinDecision:
    """Audit record of one join's selection + execution."""

    selection: Selection
    left_stats: TableStats
    right_stats: TableStats
    report: JoinReport
    #: The properties (incl. partition flags) the selection ran under —
    #: what the plan analyzer's exchange audit (E1/E2) checks against.
    props: Optional[JoinProperties] = None

    @property
    def network_bytes(self) -> float:
        return sum(e.network_bytes for e in self.report.exchanges)

    @property
    def local_bytes(self) -> float:
        return self.report.local_bytes

    @property
    def straggler_bytes(self) -> float:
        """Hottest-partition load of this join's exchanges (both sides must
        land before the local join starts, so the stage's straggler is the
        sum of the per-exchange straggler loads)."""
        return sum(e.straggler_bytes for e in self.report.exchanges)

    @property
    def probe_shuffle_bytes(self) -> float:
        """Network bytes the probe (plan-left) side shipped through this
        join's shuffle — the traffic runtime filters exist to cut.
        Broadcast-family joins never move the probe side, so 0 there."""
        if self.selection.method not in _SHUFFLE_FAMILY:
            return 0.0
        return self.report.exchanges[0].network_bytes


@dataclasses.dataclass
class FilterDecision:
    """Audit record of one planned-and-executed runtime filter (any kind)."""

    plan: RuntimeFilter      # the planner's placement + kind + cost rationale
    rows_before: int
    rows_after: int
    p: int                   # parallelism the filter was broadcast over
    #: True when the payload came out of the cross-query FilterCache —
    #: no build ran, so the distributed-build reduce bytes are zero.
    cached: bool = False

    @property
    def broadcast_bytes(self) -> float:
        """Wire bytes of shipping the serialized filter to the probe
        side's p-1 remote tasks (Eq. 1 on m_bits/8 bytes) — paid per
        query, cached or not. Delegates to ``runtime_filter_cost`` at
        w=1 (raw bytes) so the measured accounting tracks the planner's
        model, like ``reduce_bytes``."""
        return runtime_filter_cost(self.plan.m_bits,
                                   CostParams(p=self.p, w=1.0))

    @property
    def reduce_bytes(self) -> float:
        """Wire bytes of the distributed *build* merge, charged at the
        kind's actual reduce shape — ``filter_reduce_cost``'s per-kind
        model at w=1 (raw bytes), so the measured accounting can never
        drift from the planner's. Zero on a cache hit — nothing was
        built."""
        if self.cached:
            return 0.0
        return filter_reduce_cost(self.plan.m_bits,
                                  CostParams(p=self.p, w=1.0),
                                  kind=self.plan.kind)

    @property
    def network_bytes(self) -> float:
        """Total measured wire cost of the filter: build merge (if any)
        plus the per-query broadcast."""
        return self.reduce_bytes + self.broadcast_bytes

    @property
    def keep_measured(self) -> float:
        if self.rows_before <= 0:
            return 1.0
        return self.rows_after / self.rows_before


@dataclasses.dataclass
class CardinalityRecord:
    """Estimated-vs-measured cardinality at one exchange boundary (the
    estimator-accuracy audit trail the q-error harness asserts over)."""

    kind: str        # "join" | "aggregate"
    estimated: float
    measured: float

    @property
    def q_error(self) -> float:
        """Symmetric relative error, one-row-floored (``core.stats``)."""
        return q_error(self.estimated, self.measured)


@dataclasses.dataclass
class ReoptDecision:
    """Audit record of one checkpoint re-optimization decision.

    Emitted at every region exchange boundary of a reopt-enabled run,
    triggered or not — plan-analysis rule R2 audits the discipline:
    ``triggered`` iff the recomputed q-error exceeds the threshold, and a
    non-triggered checkpoint must leave the continuation untouched
    (``new_next == old_next``)."""

    boundary: int            # 0-based join index within the region
    estimated: TableStats    # the optimizer's predicted intermediate
    measured: TableStats     # the materialized intermediate, measured
    threshold: float         # the executor's q-error trigger
    q_error: float           # max(est/meas, meas/est), one-row-floored
    triggered: bool
    old_next: Optional[int]  # next build leaf under the unfolded stats
    new_next: Optional[int]  # next build leaf after the checkpoint


@dataclasses.dataclass
class ExecutionResult:
    table: Table
    decisions: List[JoinDecision]
    wall_time_s: float
    network_bytes: float
    local_bytes: float
    rows: int
    #: Sum over joins of their hottest-partition exchange loads — the
    #: skew-sensitive lower bound on stage wall time (straggler metric).
    straggler_bytes: float = 0.0
    #: Runtime filters (any kind) that were planned and applied, in order.
    filters: List["FilterDecision"] = dataclasses.field(default_factory=list)
    #: Checkpoint re-optimization audit trail (reopt-enabled runs only).
    reopts: List["ReoptDecision"] = dataclasses.field(default_factory=list)
    #: Estimated-vs-measured cardinality at every join/aggregate boundary.
    cardinalities: List["CardinalityRecord"] = dataclasses.field(
        default_factory=list)

    def methods(self):
        return [d.selection.method for d in self.decisions]

    def workload(self, w: float = 1.0) -> float:
        """Measured cluster workload under the paper's weighting."""
        return w * self.network_bytes + self.local_bytes

    @property
    def filter_network_bytes(self) -> float:
        """Wire bytes spent broadcasting runtime filters (already included
        in ``network_bytes`` — honest accounting of the filters' price)."""
        return sum(f.network_bytes for f in self.filters)

    @property
    def filter_reduce_bytes(self) -> float:
        """Wire bytes of the filters' distributed-build merges only (the
        per-kind reduce tree / all_gather) — the component a cross-query
        cache hit eliminates. Zero on a fully warm run."""
        return sum(f.reduce_bytes for f in self.filters)

    @property
    def cached_filters(self) -> int:
        """How many applied filters came out of the cross-query cache."""
        return sum(1 for f in self.filters if f.cached)

    @property
    def probe_shuffle_bytes(self) -> float:
        """Suite metric for runtime filters: bytes the probe sides shipped
        through shuffle-family exchanges."""
        return sum(d.probe_shuffle_bytes for d in self.decisions)

    @property
    def max_q_error(self) -> float:
        """Worst estimated-vs-measured divergence across all boundaries
        (1.0 when nothing was recorded — a perfect, if vacuous, score)."""
        return max((c.q_error for c in self.cardinalities), default=1.0)

    @property
    def reopt_count(self) -> int:
        """How many checkpoints actually triggered a re-optimization."""
        return sum(1 for r in self.reopts if r.triggered)


@dataclasses.dataclass
class _Annotated:
    table: Table
    measured: TableStats   # adaptive runtime statistic (post-materialization)
    estimated: TableStats  # statically-propagated estimate


class Executor:
    def __init__(self, catalog: Catalog, strategy: Strategy,
                 adaptive: bool = True, est_error: float = 1.0,
                 use_kernel: bool = False, capacity_factor: float = 2.0,
                 compact: bool = True, reorder: Optional[bool] = None,
                 verify: Optional[bool] = None,
                 hypercube: Optional[bool] = None,
                 intermediates: Optional[Dict[str, Table]] = None,
                 reopt: Optional[bool] = None,
                 reopt_qerror: Optional[float] = None):
        self.catalog = catalog
        self.strategy = strategy
        self.adaptive = adaptive
        self.est_error = est_error
        self.use_kernel = use_kernel
        self.capacity_factor = capacity_factor
        self.compact = compact
        self.p = catalog.p
        # Plan-space search: wrap any strategy in ReorderingStrategy (or pass
        # reorder=True) to enable pushdown/pruning + adaptive join reordering.
        self.reorder = (getattr(strategy, "reorder", False)
                        if reorder is None else reorder)
        # Hypercube multi-way execution for cyclic regions (eqcol closing
        # predicates above a reorderable region). Armed whenever reordering
        # is — the selection itself stays cost-gated, so acyclic plans and
        # losing quotes are untouched. ``hypercube=False`` forces the
        # binary plan (the benchmark's comparison arm).
        self.hypercube = (getattr(strategy, "hypercube", True)
                          if hypercube is None else hypercube)
        # Skew-aware strategies get runtime key-skew measurements attached
        # to the boundary statistics (everyone else sees the uniform 1.0,
        # keeping the paper's strategies bit-identical and measurement-free).
        self.skew_aware = getattr(strategy, "skew_aware", False)
        self.skew_floor = getattr(strategy, "skew_floor", 1.1)
        # Runtime-filter pushdown (FilteredStrategy): the Executor plans a
        # filter (cheapest applicable kind) per join-graph edge with
        # *measured* build-side statistics and applies it to the probe
        # side below its exchanges.
        self.runtime_filters = getattr(strategy, "runtime_filters", False)
        self.filter_bits_per_key = getattr(strategy, "bits_per_key",
                                           BLOOM_DEFAULT_BITS_PER_KEY)
        # Which reducer kinds the planner may quote per edge (FilteredStrategy
        # narrows this to e.g. ("bloom",) for PR-3-compatible behaviour).
        self.filter_kinds = getattr(strategy, "filter_kinds",
                                    DEFAULT_FILTER_KINDS)
        # Cross-query filter cache (FilteredStrategy(cache=...)): consulted
        # before every build, written after; None = cold path everywhere.
        self.filter_cache = getattr(strategy, "filter_cache", None)
        # Debug-mode plan verification: every plan (incl. adaptive re-plans
        # and filter placements) runs through the static analyzer's rules
        # before/while executing; violations raise PlanVerificationError.
        self.verify = (getattr(strategy, "verify", False)
                       if verify is None else verify)
        # Checkpoint mid-query re-optimization: at every region exchange
        # boundary the materialized intermediate's measured cardinality is
        # compared against the optimizer's prediction; past the q-error
        # threshold the measured stats are folded into the remaining join
        # graph and the System-R DP re-runs on the remainder. Off by
        # default — non-reopt runs are byte-identical to PR 9.
        self.reopt = (getattr(strategy, "reopt", False)
                      if reopt is None else reopt)
        self.reopt_qerror = (getattr(strategy, "reopt_qerror",
                                     DEFAULT_REOPT_QERROR)
                             if reopt_qerror is None else reopt_qerror)
        # Cross-query CSE injection (QueryService): pre-computed tables for
        # shared exchange-rooted subtrees, keyed on ``logical.signature``.
        # ``_eval`` returns them in place of re-executing the subtree.
        self.intermediates: Dict[str, Table] = (
            dict(intermediates) if intermediates else {})
        self._schema = catalog_schema(catalog)
        self._params = CostParams(p=self.p, w=getattr(strategy, "w", 1.0))
        # Key-domain denominators for the filter planner's sigma estimate.
        self._base_stats = (catalog_base_stats(catalog)
                            if self.runtime_filters else {})

    # -- public ---------------------------------------------------------------

    def execute(self, plan: Node) -> ExecutionResult:
        self._decisions: List[JoinDecision] = []
        self._filters: List[FilterDecision] = []
        self._reopts: List[ReoptDecision] = []
        self._cards: List[CardinalityRecord] = []
        if self.filter_cache is not None:
            # Bind the cache to this catalog: entries built against any
            # other catalog version are invalidated before planning.
            self.filter_cache.sync(self.catalog)
        if self.verify:
            self._gate(analyze_plan(plan, self._schema,
                                    catalog_dtypes(self.catalog)))
        if self.reorder:
            rewritten = prune_projections(
                push_down_filters(plan, self._schema), self._schema)
            if self.verify:
                self._gate(check_schema_preserved(plan, rewritten,
                                                  self._schema))
                self._gate(analyze_plan(rewritten, self._schema,
                                        catalog_dtypes(self.catalog)))
            plan = rewritten
        t0 = time.perf_counter()
        ann = self._eval(plan)
        ann.table.valid.block_until_ready()
        dt = time.perf_counter() - t0
        net = sum(d.network_bytes for d in self._decisions)
        net += sum(f.network_bytes for f in self._filters)
        loc = sum(d.local_bytes for d in self._decisions)
        strag = sum(d.straggler_bytes for d in self._decisions)
        return ExecutionResult(ann.table, self._decisions, dt, net, loc,
                               ann.table.count(), straggler_bytes=strag,
                               filters=self._filters, reopts=self._reopts,
                               cardinalities=self._cards)

    def _gate(self, violations: List[Violation]) -> None:
        if violations:
            raise PlanVerificationError(violations)

    # -- evaluation ------------------------------------------------------------

    def _eval(self, node: Node) -> _Annotated:
        if self.intermediates and isinstance(node, (Join, Aggregate)):
            # Cross-query CSE: a shared exchange-rooted subtree another
            # query (or an earlier producer pass) already materialized is
            # consumed directly — no joins run, no bytes move. Tables are
            # immutable (every operator derives a new one), so fanning one
            # table out to many consumers is safe. Measured stats stand in
            # for both channels: the subtree root is an exchange boundary,
            # where adaptive execution would re-measure anyway.
            shared = self.intermediates.get(signature(node))
            if shared is not None:
                measured = shared.measure()
                return _Annotated(shared, measured, measured)
        if isinstance(node, Scan):
            t = self.catalog.table(node.table)
            measured = t.measure()
            est = TableStats(measured.size_bytes * self.est_error,
                             measured.cardinality * self.est_error,
                             StatsSource.ESTIMATED)
            return _Annotated(t, measured, est)

        if isinstance(node, Filter):
            if node.op == "eqcol" and self.reorder and self.hypercube:
                # Closing edge(s) of a possibly-cyclic region: quote the
                # hypercube multi-way shuffle against the best binary tree.
                ann = self._try_hypercube(node)
                if ann is not None:
                    return ann
            child = self._eval(node.child)
            t = _apply_filter(child.table, node)
            # In-stage operator: runtime stats are *propagated estimates*
            # from the last materialization (paper §4.1 step 2). The
            # catalog's per-column histograms, when present, beat both the
            # declared selectivity and the uniform-domain fractions.
            sel = derive_selectivity(node, self.catalog.key_domains,
                                     self.catalog.column_stats or None)
            measured = estimate_filter(child.measured, sel)
            est = estimate_filter(child.estimated, sel)
            return _Annotated(t, measured, est)

        if isinstance(node, Project):
            child = self._eval(node.child)
            t = child.table.select(node.columns)
            frac = t.row_bytes / max(child.table.row_bytes, 1)
            m, e = child.measured, child.estimated
            return _Annotated(
                t,
                TableStats(m.size_bytes * frac, m.cardinality, m.source),
                TableStats(e.size_bytes * frac, e.cardinality, e.source))

        if isinstance(node, Join):
            if self.reorder or self.runtime_filters:
                # Regions are extracted for reordering AND for runtime
                # filters: leaf-level filter application is what pushes a
                # filter below the probe side's earlier exchanges.
                graph = extract_join_graph(node, self._schema)
                if graph is not None and graph.n >= 3:
                    return self._eval_region(graph)
            left = self._eval(node.left)
            right = self._eval(node.right)
            # Exchange boundary: re-measure both inputs (adaptive runtime
            # statistics). Non-adaptive mode keeps static estimates.
            lstats = self._boundary_stats(left, node.left)
            rstats = self._boundary_stats(right, node.right)
            spill = None
            if (self.runtime_filters and node.hint is None
                    and node.join_type in _FILTERABLE_TYPES):
                before = left
                left, lstats = self._filter_pair(left, lstats, right, rstats,
                                                 node)
                if (node.join_type is JoinType.LEFT_OUTER
                        and left is not before):
                    # Padding path: the rows the filter dropped are exactly
                    # the probe rows with no build match — capture them so
                    # they can re-enter the result null-padded.
                    spill = before.table.with_valid(before.table.valid
                                                    & ~left.table.valid)
            out = self._join(left, right, lstats, rstats, node.left_key,
                             node.right_key, node.join_type, node.hint,
                             retain=self._retain(node.right))
            if spill is not None:
                out = self._pad_outer_rows(out, spill)
            return out

        if isinstance(node, Aggregate):
            child = self._eval(node.child)
            out, _rep = self._run_agg_with_retry(child.table, node.key,
                                                 node.aggs)
            if self.compact:
                out = compact_partitions(out)
            measured = out.measure()
            cs = self.catalog.column_stats.get(node.key)
            if cs is not None and cs.count > 0:
                # Group-count estimate from the catalog's measured NDV —
                # a genuine prediction, so it enters the q-error trail.
                est = estimate_group_by(child.estimated, max(cs.ndv, 1.0))
                self._cards.append(CardinalityRecord(
                    "aggregate", est.cardinality, measured.cardinality))
            else:
                # No histogram for the group key (hand-built catalogs,
                # derived columns): fall back to the measured group count —
                # not a prediction, so it stays out of the q-error trail.
                est = estimate_group_by(child.estimated,
                                        measured.cardinality or 1)
            return _Annotated(out, measured, est)

        raise TypeError(f"unknown plan node {type(node)}")

    def _retain(self, leaf: Node) -> float:
        """Histogram-aware kept fraction of a build subtree's filter chain
        (the planner's ``stats_retain_fraction`` under this catalog)."""
        return stats_retain_fraction(leaf, self.catalog.key_domains,
                                     self.catalog.column_stats or None)

    # -- runtime bloom-filter pushdown -----------------------------------------

    def _leaf_sigma(self, leaf: Node, stat: TableStats,
                    build_key: str) -> float:
        """Estimated match fraction when ``leaf`` plays the build role: its
        surviving distinct keys (= measured cardinality; build keys are
        unique) over the key domain. Falls back to the static *key* retain
        fraction when no domain is known (e.g. aggregated subqueries from
        sources without header FK metadata) — key-aware so a filter on an
        aggregate's group key, above or below the grouping, still counts
        (group keys survive grouping).

        When the cross-query ``FilterCache`` holds *measured* build-side
        stats for this leaf's predicate chain (stored alongside every
        payload it caches), those replace a merely-estimated ``stat`` —
        a warm cache makes the sigma estimate runtime-accurate even for a
        static (non-adaptive) executor. Runtime-sourced stats are already
        measured and are never overridden."""
        if (self.filter_cache is not None
                and stat.source is not StatsSource.RUNTIME):
            cached = self.filter_cache.measured_build_stats(
                chain_stats_key(leaf, build_key))
            if cached is not None:
                stat = cached
        domain = self.catalog.key_domains.get(build_key)
        if domain is None:
            domain = leaf_key_domain(leaf, self._base_stats)
        if domain and domain > 0:
            return min(max(stat.cardinality, 0.0) / domain, 1.0)
        return key_retain_fraction(leaf, build_key)

    def _filter_pair(self, left: _Annotated, lstats: TableStats,
                     right: _Annotated, rstats: TableStats,
                     node: Join):
        """Plan + apply a runtime filter for a single (non-region) join:
        the probe table is masked before the join's exchange."""
        sigma = self._leaf_sigma(node.right, rstats, node.right_key)
        edge = JoinEdge(0, 1, node.left_key, node.right_key)
        plan = plan_runtime_filters([edge], [lstats, rstats], [1.0, sigma],
                                    self._params, self.filter_bits_per_key,
                                    leaves=[node.left, node.right],
                                    kinds=self.filter_kinds,
                                    cache=self.filter_cache)
        if not plan:
            return left, lstats
        if self.verify:
            # The executor compensates LEFT_OUTER placements via the
            # padding path in _eval — that's what licenses F1 here.
            padded = node.join_type is JoinType.LEFT_OUTER
            self._gate(check_filter_placement(plan[0], node.join_type,
                                              padded=padded)
                       + check_filter_quote(plan[0]))
        left = self._apply_runtime_filter(plan[0], left, right.table,
                                          node.right)
        return left, self._boundary_stats(left, node.left)

    def _region_filters(self, graph, anns, stats, edges):
        """Plan filters over a region's (augmented) edges with measured leaf
        statistics and apply them at the probe *leaves* — below every
        exchange of the region — then re-measure, so the reordering DP and
        all selections run on post-filter cardinalities."""
        sigmas = [1.0] * graph.n
        for e in edges:
            sigmas[e.build] = self._leaf_sigma(graph.leaves[e.build],
                                               stats[e.build], e.build_key)
        plan = plan_runtime_filters(edges, stats, sigmas, self._params,
                                    self.filter_bits_per_key,
                                    leaves=graph.leaves,
                                    kinds=self.filter_kinds,
                                    cache=self.filter_cache)
        masked = set()   # leaves already masked by an earlier filter
        for rf in plan:
            if self.verify:
                # Region edges are INNER by construction (extract_join_graph
                # only walks inner joins), so placement is always safe —
                # the gate still runs to catch a future loosening.
                self._gate(check_filter_placement(rf, JoinType.INNER)
                           + check_filter_quote(rf))
            # A build leaf that was itself a probe target earlier in this
            # region no longer matches its static predicate chain — its
            # payload is narrowed by *this query's* other filters and must
            # not be stored under the chain-only cache key (a later query
            # reusing it would drop rows that only this query excludes).
            anns[rf.probe] = self._apply_runtime_filter(
                rf, anns[rf.probe], anns[rf.build].table,
                graph.leaves[rf.build],
                cacheable=rf.build not in masked)
            masked.add(rf.probe)
            stats[rf.probe] = self._boundary_stats(anns[rf.probe],
                                                   graph.leaves[rf.probe])
        return anns, stats

    def _apply_runtime_filter(self, rf: RuntimeFilter, probe: _Annotated,
                              build: Table, build_leaf: Node,
                              cacheable: bool = True) -> _Annotated:
        """Build (or fetch from the cross-query cache) the planned filter
        kind and mask the probe table (no false negatives: only rows that
        cannot match are dropped). An empty build side yields the
        reject-everything payload for every kind (zero bloom array, empty
        zone interval, empty key list) — the join result is empty either
        way. Cache consults precede every build; fresh builds are stored
        with the measured build-side stats so later queries (and the
        planner's cache-aware quotes) can reuse them — unless the caller
        marks the build ``cacheable=False`` because its table no longer
        matches the leaf's static predicate chain (it was masked by
        another runtime filter of this query); a cached *lookup* is still
        safe there, since the chain-keyed payload is a superset (false
        positives only, never false negatives)."""
        payload = None
        ck = None
        if self.filter_cache is not None:
            ck = filter_cache_key(build_leaf, rf.build_key, rf.kind,
                                  rf.m_bits, rf.k)
            payload = self.filter_cache.lookup(ck)
        cached = payload is not None
        if cached and self.verify and ck is not None:
            # F3 reuse side: the cache keys payloads by (chain, key, kind,
            # shape), so a hit's stored chain must be subset-safe for this
            # edge's chain. Exact-key hits make this trivially true today;
            # the gate pins it against a future key loosening.
            self._gate(check_cache_reuse((ck[0], ck[1]),
                                         predicate_chain(build_leaf)))
        if payload is None:
            payload = build_filter_payload(rf, build)
            if self.filter_cache is not None and cacheable:
                if self.verify:
                    # F3 store side: only chain-faithful payloads may enter
                    # the cross-query cache.
                    self._gate(check_cache_store(
                        predicate_chain(build_leaf),
                        build_masked=not cacheable))
                # Store the *materialized* build table's measurement, not
                # the planner's ``build_stats`` quote: the payload was
                # just built from the real rows, so the true cardinality
                # is free — and in a static run the quote is merely
                # ESTIMATED, which the cache's RUNTIME guard (rightly)
                # refuses to treat as a measurement.
                self.filter_cache.store(ck, payload, build.measure())
        keep = probe_filter_mask(rf, payload,
                                 probe.table.column(rf.probe_key))
        table = probe.table.with_valid(probe.table.valid & keep)
        measured = table.measure()
        decision = FilterDecision(rf, probe.table.count(),
                                  int(measured.cardinality),
                                  self.p, cached=cached)
        if self.verify:
            self._gate(audit_filter_decision(decision))
        self._filters.append(decision)
        return _Annotated(table, measured,
                          probe.estimated.scaled(rf.keep_est))

    # -- join execution --------------------------------------------------------

    def _join(self, left: _Annotated, right: _Annotated,
              lstats: TableStats, rstats: TableStats, lk: str, rk: str,
              join_type: JoinType, hint,
              retain: float = 1.0) -> _Annotated:
        """Select (per strategy) + execute one physical join; audit it."""
        # Distribution properties: a side already hash-partitioned on its
        # join key gets its shuffle elided by the engine, so the model's
        # shuffle-family quotes drop that side's network term (the
        # redundant-exchange finding plan analysis rule E2 pins).
        props = JoinProperties(join_type=join_type, hint=hint,
                               left_partitioned=(left.table.partitioned_by
                                                 == lk),
                               right_partitioned=(right.table.partitioned_by
                                                  == rk))
        if self.skew_aware:
            # Adaptive runtime statistic beyond (size, cardinality): the
            # join-key straggler factor from per-partition load histograms.
            # A side already hash-partitioned by its join key keeps the
            # uniform default: its shuffle would be *elided* (§3.7's
            # C_shuffle = 0 case), so charging a straggler — or salting,
            # which un-elides the exchange — would regress exactly the
            # plans the elision optimizes.
            if left.table.partitioned_by != lk:
                lstats = lstats.with_skew(
                    key_skew(left.table, lk, self.p, self.skew_floor))
            if right.table.partitioned_by != rk:
                rstats = rstats.with_skew(
                    key_skew(right.table, rk, self.p, self.skew_floor))
        sel = self.strategy.select(lstats, rstats, props, self.p)
        sel = self._engine_feasible(sel, lstats, rstats, props)
        if self.verify:
            # Pre-run cost audit (C1/C2/S1): a bad selection is caught
            # before any bytes move.
            self._gate(audit_selection(sel, lstats, rstats, props,
                                       self._params))
        out, rep = self._run_join_with_retry(sel, left.table, right.table,
                                             lk, rk, join_type.value)
        if self.compact:
            out = compact_partitions(out)
        if self.verify:
            # Post-run exchange audit (E1/E2): every elision proven
            # necessary, every proven partitioning actually elided.
            self._gate(audit_exchanges(sel, props, rep))
        self._decisions.append(JoinDecision(sel, lstats, rstats, rep,
                                            props=props))
        measured = out.measure()
        # FK->PK output estimate, scaled by the build side's histogram
        # retain fraction (mirrors estimate_leaf_stats): INNER narrows
        # the probe by retain; semi keeps the domain-coverage match
        # fraction (build NDV over probe-key domain), anti its
        # complement; outer joins keep every probe row.
        if join_type in (JoinType.LEFT_SEMI, JoinType.LEFT_ANTI):
            sigma = semi_match_fraction(right.estimated, lk,
                                        self.catalog.key_domains, retain)
            frac = (sigma if join_type is JoinType.LEFT_SEMI
                    else max(1.0 - sigma, 0.0))
            est = left.estimated.scaled(frac)
        elif join_type is JoinType.INNER:
            est = estimate_join(left.estimated, right.estimated,
                                fk_selectivity=retain)
        else:
            est = estimate_join(left.estimated, right.estimated)
        self._cards.append(CardinalityRecord("join", est.cardinality,
                                             measured.cardinality))
        return _Annotated(out, measured, est)

    def _pad_outer_rows(self, ann: _Annotated, spill: Table) -> _Annotated:
        """LEFT_OUTER padding path: re-inject probe rows a runtime filter
        dropped. Those rows provably have no build match (filter kinds have
        no false negatives), so they re-enter exactly as the join would
        have emitted them: probe columns intact, build payload columns
        zero-padded, ``_matched`` False (bool zero)."""
        out = ann.table
        cols = {}
        for name, col in out.columns.items():
            if name in spill.columns:
                pad = spill.columns[name]
            else:
                pad = jnp.zeros(spill.valid.shape, dtype=col.dtype)
            cols[name] = jnp.concatenate([col, pad], axis=1)
        valid = jnp.concatenate([out.valid, spill.valid], axis=1)
        # The appended rows sit in the probe's original layout, so any
        # hash-partitioning the join established no longer holds.
        table = Table(cols, valid, partitioned_by=None)
        if self.compact:
            table = compact_partitions(table)
        return _Annotated(table, table.measure(), ann.estimated)

    def _engine_feasible(self, sel: Selection, lstats: TableStats,
                         rstats: TableStats,
                         props: JoinProperties) -> Selection:
        """The engine always broadcasts the RIGHT (unique-key build) side,
        while the model's broadcast-hash premise is that B — the *smaller*
        side — is broadcast (§3.1.4). When the build side is the larger one
        the premise is void: broadcasting it costs (p-1)|A_big|, strictly
        worse than the shuffle the model ranks next. Degrade to shuffle
        hash (same spirit as §4.4's validity fallback)."""
        if (props.hint is None
                and sel.method is JoinMethod.BROADCAST_HASH
                and rstats.size_bytes > lstats.size_bytes):
            return dataclasses.replace(
                sel, method=JoinMethod.SHUFFLE_HASH,
                # Honest audit trail: the quoted cost must be the cost of
                # the method that actually runs, not the voided broadcast.
                cost=sel.costs.get(JoinMethod.SHUFFLE_HASH, sel.cost),
                reason=sel.reason + "; engine: build side larger -> shuffle")
        # (The salted method needs no twin guard: selection only emits it
        # when the A role sits on the plan's left — the side the engine
        # actually salts.)
        return sel

    # -- adaptive join reordering (planner DP at exchange boundaries) ----------

    def _eval_region(self, graph) -> _Annotated:
        """Execute an inner-join region with cost-based ordering.

        All region leaves are materialized first (they are needed under any
        order), giving their adaptive runtime statistics. The System-R DP
        then enumerates the order; after every executed join — an exchange
        boundary — the *remaining* order is re-enumerated with the measured
        intermediate statistics, not just the next method re-selected. The
        written order is kept whenever the DP cannot model a strictly
        cheaper one.

        Checkpoint re-optimization (``reopt=True``) adds a divergence
        audit at every boundary: the materialized intermediate's measured
        cardinality is compared against the optimizer's prediction, and
        past the q-error threshold the measured stats are folded into the
        remaining join graph and the DP re-runs on the remainder — even
        when the written (left-deep) order was standing until then.
        """
        anns = [self._eval(leaf) for leaf in graph.leaves]
        stats = [self._boundary_stats(a, l)
                 for a, l in zip(anns, graph.leaves)]
        retain = [self._retain(l) for l in graph.leaves]
        edges = augment_edges(graph)
        if self.runtime_filters:
            # Sideways information passing: filters built from selective
            # build leaves mask the probe leaves *here*, before any of the
            # region's exchanges; the re-plan below then runs on measured
            # post-filter cardinalities.
            anns, stats = self._region_filters(graph, anns, stats, edges)
        if not self.reorder:
            # Filter-only strategies keep the written join order.
            return self._exec_region_tree(graph.tree, graph, anns, retain)
        plan_cost = modeled_tree_cost(graph, stats, retain, self._params)
        order = enumerate_join_order(stats, retain, edges, self._params)
        use_dp = order is not None and order.cost < plan_cost * (1 - 1e-9)
        written = (self._linear_steps(graph)
                   if self.reopt and not use_dp else None)
        if not use_dp and written is None:
            # Written order stands and no checkpointing is possible (reopt
            # off, or a bushy written tree): execute the tree as-is.
            return self._exec_region_tree(graph.tree, graph, anns, retain)
        if use_dp:
            first = order.first
            fallback = [(s.build, None) for s in order.steps]
        else:
            first, fallback = written
        # Until a checkpoint triggers, a standing written order is executed
        # verbatim (no step-wise re-plan: that could silently deviate from
        # the order the DP just declared non-improvable).
        replanning = use_dp
        cur = anns[first]
        cur_stats = stats[first]
        joined = {first}
        boundary = 0
        while len(joined) < graph.n:
            rest = [i for i in range(graph.n) if i not in joined]
            step = (self._replan_step(cur_stats, joined, rest, stats,
                                      retain, edges)
                    if replanning else None)
            if step is None:
                step = self._fallback_step(fallback, joined, edges)
            if self.verify:
                # R1: adaptive re-plans only follow real join-graph edges.
                self._gate(check_replan_step(step, joined, edges))
            b = step.build
            # What the optimizer believes this boundary will produce —
            # the estimate the checkpoint audits against.
            predicted = estimate_join(cur_stats, stats[b],
                                      fk_selectivity=retain[b])
            cur = self._join(cur, anns[b], cur_stats, stats[b],
                             step.probe_key, step.build_key, JoinType.INNER,
                             None, retain=retain[b])
            joined.add(b)
            next_stats = cur.measured if self.adaptive else cur.estimated
            if self.reopt:
                q = q_error(predicted.cardinality,
                            cur.measured.cardinality)
                triggered = q > self.reopt_qerror
                # Continuation under the *unfolded* policy, for the audit
                # trail (R2: a non-trigger must not change it).
                old_next = self._peek_next(replanning, next_stats, joined,
                                           stats, retain, edges, fallback)
                if triggered:
                    # Checkpoint: the intermediate is already materialized
                    # (every boundary materializes); fold its measured
                    # stats into the remaining join graph and re-run the
                    # DP on the remainder.
                    next_stats = cur.measured
                    replanning = True
                    new_next = self._peek_next(True, next_stats, joined,
                                               stats, retain, edges,
                                               fallback)
                else:
                    new_next = old_next
                dec = ReoptDecision(boundary, predicted, cur.measured,
                                    self.reopt_qerror, q, triggered,
                                    old_next, new_next)
                if self.verify:
                    # R2: trigger iff threshold exceeded; non-triggered
                    # checkpoints leave the continuation untouched.
                    self._gate(check_reopt_decision(dec))
                self._reopts.append(dec)
            cur_stats = next_stats
            boundary += 1
        return cur

    def _linear_steps(self, graph):
        """``(first leaf, [(build leaf, edge), ...])`` of a left-deep
        written region tree — the step form checkpoint re-optimization
        needs to audit a standing written order. None when the written
        tree is bushy (the tree path executes it unchanged)."""
        steps = []
        t = graph.tree
        while not isinstance(t, int):
            if not isinstance(t[1], int):
                return None
            steps.append((t[1], graph.edges[t[2]]))
            t = t[0]
        steps.reverse()
        return t, steps

    def _peek_next(self, replanning, cur_stats, joined, stats, retain,
                   edges, fallback) -> Optional[int]:
        """Build leaf the current policy would join next (None = region
        done) — pure lookahead, consumes nothing."""
        if len(joined) >= len(stats):
            return None
        rest = [i for i in range(len(stats)) if i not in joined]
        step = (self._replan_step(cur_stats, joined, rest, stats, retain,
                                  edges)
                if replanning else None)
        if step is None:
            step = self._fallback_step(fallback, joined, edges)
        return step.build

    def _replan_step(self, cur_stats, joined, rest, stats, retain, edges):
        """Re-enumerate the remaining join order from the current
        intermediate (pseudo-leaf 0); return its first step."""
        idx = {r: i + 1 for i, r in enumerate(rest)}
        pstats = [cur_stats] + [stats[r] for r in rest]
        pretain = [1.0] + [retain[r] for r in rest]
        pedges = []
        for e in edges:
            if e.build in joined:
                continue
            if e.probe in joined:
                pedges.append(JoinEdge(0, idx[e.build], e.probe_key,
                                       e.build_key, e.derived))
            else:
                pedges.append(JoinEdge(idx[e.probe], idx[e.build],
                                       e.probe_key, e.build_key, e.derived))
        order = enumerate_join_order(pstats, pretain, pedges, self._params,
                                     start=0)
        if order is None or not order.steps:
            return None
        s = order.steps[0]
        return JoinStep(rest[s.build - 1], s.probe_key, s.build_key,
                        s.method, s.cost)

    def _fallback_step(self, fallback, joined, edges):
        """Next feasible step from the static ``(build, edge)`` order: a
        written order carries its own tree edge; DP orders (edge None)
        take the first live join-graph edge for that build."""
        for b, e in fallback:
            if b in joined:
                continue
            if e is not None and e.probe in joined:
                return JoinStep(b, e.probe_key, e.build_key, None, 0.0)
            for ed in edges:
                if ed.build == b and ed.probe in joined:
                    return JoinStep(b, ed.probe_key, ed.build_key, None,
                                    0.0)
        raise RuntimeError("no feasible join step left in region")

    def _exec_region_tree(self, tree, graph, anns,
                          retain: List[float]) -> _Annotated:
        """Execute a region in its written order (leaves pre-evaluated)."""
        if isinstance(tree, int):
            return anns[tree]
        left = self._exec_region_tree(tree[0], graph, anns, retain)
        right = self._exec_region_tree(tree[1], graph, anns, retain)
        e = graph.edges[tree[2]]
        lstats = self._region_stats(left, tree[0], graph)
        rstats = self._region_stats(right, tree[1], graph)
        r = retain[tree[1]] if isinstance(tree[1], int) else 1.0
        return self._join(left, right, lstats, rstats, e.probe_key,
                          e.build_key, JoinType.INNER, None, retain=r)

    def _region_stats(self, ann, tree, graph) -> TableStats:
        if isinstance(tree, int):
            return self._boundary_stats(ann, graph.leaves[tree])
        return ann.measured if self.adaptive else ann.estimated

    # -- hypercube multi-way execution (cyclic join cores) ---------------------

    def _try_hypercube(self, node: Filter) -> Optional[_Annotated]:
        """Quote + execute the hypercube multi-way shuffle for a cyclic
        region: one-or-more consecutive eqcol Filters (the closing edges)
        sitting directly above a reorderable INNER region. Returns None
        whenever the shape does not match or the multi-way quote is not
        strictly cheaper than the best binary tree — the caller then falls
        through to the binary path, which evaluates the same eqcol
        predicates as post-join residuals (identical semantics)."""
        eqcols: List[Filter] = []
        base: Node = node
        while isinstance(base, Filter) and base.op == "eqcol":
            eqcols.append(base)
            base = base.child
        graph = extract_join_graph(base, self._schema)
        if graph is None or graph.n < 3:
            return None
        cols = [frozenset(leaf_columns(leaf, self._schema))
                for leaf in graph.leaves]

        def owner(col):
            found = [i for i in range(graph.n) if col in cols[i]]
            return found[0] if len(found) == 1 else None

        closing = []
        for f in eqcols:
            u, v = owner(f.column), owner(str(f.column2))
            if u is None or v is None or u == v:
                return None
            closing.append(((u, f.column), (v, str(f.column2))))
        # Materialize the region leaves (needed under either plan) for
        # their adaptive runtime statistics; roll back the audit trail if
        # the binary plan stands, since the caller re-evaluates them.
        n_dec, n_fil = len(self._decisions), len(self._filters)
        anns = [self._eval(leaf) for leaf in graph.leaves]
        stats = [self._boundary_stats(a, leaf)
                 for a, leaf in zip(anns, graph.leaves)]
        retain = [self._retain(leaf) for leaf in graph.leaves]
        binary = modeled_tree_cost(graph, stats, retain, self._params)
        order = enumerate_join_order(stats, retain, augment_edges(graph),
                                     self._params)
        if order is not None:
            binary = min(binary, order.cost)
        hp = plan_hypercube(graph, closing, stats, binary, self._params)
        if hp is None:
            del self._decisions[n_dec:]
            del self._filters[n_fil:]
            return None
        spec = HypercubeSpec(
            dims=hp.dims, axis_keys=hp.axis_keys,
            links=tuple(HypercubeLink(*lk) for lk in hp.links),
            checks=hp.checks)
        tables = tuple(anns[i].table for i in hp.order)
        out, rep = self._run_hypercube_with_retry(tables, spec)
        if self.compact:
            out = compact_partitions(out)
        probe = hp.order[0]
        build = max(hp.order[1:], key=lambda i: stats[i].size_bytes)
        props = JoinProperties()
        if self.verify:
            self._gate(audit_selection(hp.selection, stats[probe],
                                       stats[build], props, self._params))
            self._gate(audit_exchanges(hp.selection, props, rep))
        self._decisions.append(JoinDecision(hp.selection, stats[probe],
                                            stats[build], rep, props=props))
        est = anns[probe].estimated
        for i in hp.order[1:]:
            est = estimate_join(est, anns[i].estimated)
        for f in eqcols:
            est = est.scaled(effective_selectivity(f))
        return _Annotated(out, out.measure(), est)

    def _run_hypercube_with_retry(self, tables, spec):
        factor = self.capacity_factor
        for _ in range(self.MAX_CAPACITY_RETRIES):
            out, rep = hypercube_multiway_join(tables, spec,
                                               capacity_factor=factor,
                                               use_kernel=self.use_kernel)
            if all(e.overflow_rows == 0 for e in rep.exchanges):
                return out, rep
            factor *= 2
        raise RuntimeError("hypercube overflow persisted after retries")

    #: Overflow retries: geometric doubling (bounded memory growth per step,
    #: unlike the old ~p-times multiplier that could OOM a 20-partition run
    #: in one retry) with enough attempts to reach 2^6x the starting slot
    #: capacity for pathological skew.
    MAX_CAPACITY_RETRIES = 7

    def _run_join_with_retry(self, sel, left, right, lk, rk, jt):
        """Skew mitigation: double slot capacity until no overflow (the
        engine-level straggler guard; DESIGN.md scale-out design)."""
        factor = self.capacity_factor
        for _ in range(self.MAX_CAPACITY_RETRIES):
            out, rep = run_equi_join(sel.method, left, right, lk, rk,
                                     join_type=jt, use_kernel=self.use_kernel,
                                     capacity_factor=factor,
                                     salt_r=sel.salt_r)
            if all(e.overflow_rows == 0 for e in rep.exchanges):
                return out, rep
            factor *= 2
        raise RuntimeError("shuffle overflow persisted after capacity retries")

    def _run_agg_with_retry(self, table, key, aggs):
        factor = self.capacity_factor
        for _ in range(self.MAX_CAPACITY_RETRIES):
            out, rep = group_aggregate(table, key, aggs, factor)
            if rep.overflow_rows == 0:
                return out, rep
            factor *= 2
        raise RuntimeError("aggregate overflow persisted after retries")

    def _boundary_stats(self, ann: _Annotated, node: Node) -> TableStats:
        if not self.adaptive:
            return ann.estimated
        # Post-exchange children were just materialized: exact runtime stats.
        if isinstance(node, (Join, Aggregate, Scan)):
            return ann.table.measure()
        return ann.measured


def _apply_filter(table: Table, f: Filter) -> Table:
    c = table.column(f.column)
    if f.op == "eq":
        m = c == f.value
    elif f.op == "ne":
        m = c != f.value
    elif f.op == "lt":
        m = c < f.value
    elif f.op == "le":
        m = c <= f.value
    elif f.op == "gt":
        m = c > f.value
    elif f.op == "ge":
        m = c >= f.value
    elif f.op == "between":
        m = (c >= f.value) & (c <= f.value2)
    elif f.op == "in":
        # OR of equalities against the literal list; an empty list keeps
        # nothing (SQL's `x IN ()` has no match).
        m = jnp.zeros_like(table.valid)
        for v in f.values:
            m = m | (c == v)
    elif f.op == "eqcol":
        # Column-to-column equality: the binary engine's residual form of
        # a cyclic core's closing join edge.
        m = c == table.column(str(f.column2))
    else:
        raise ValueError(f"unknown filter op {f.op}")
    return table.with_valid(table.valid & m)
