"""Adaptive stage-wise plan execution (paper §4.1, Fig. 3).

The executor walks the physical plan bottom-up. Every Join/Aggregate is an
exchange boundary == query-stage barrier: its inputs are materialized, their
*measured* (size, cardinality) become the adaptive runtime statistics, and
the method for the join about to run is (re-)selected with those statistics
— the paper's per-stage re-optimization (selection per join is independent,
§4.2, so bottom-up re-selection yields the model-global optimum).

``adaptive=False`` reproduces a static optimizer: selections use statistics
propagated from base tables through operator estimation rules (optionally
perturbed by ``est_error`` to emulate stale catalogs — the paper's §1
motivation for adaptivity).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp

from ..core.selection import JoinProperties, Selection
from ..core.stats import (StatsSource, TableStats, estimate_filter,
                          estimate_group_by, estimate_join)
from ..joins.aggregate import group_aggregate
from ..joins.methods import JoinReport, run_equi_join
from ..joins.table import Table, compact_partitions
from .datagen import Catalog
from .logical import Aggregate, Filter, Join, Node, Project, Scan
from .strategies import Strategy


@dataclasses.dataclass
class JoinDecision:
    """Audit record of one join's selection + execution."""

    selection: Selection
    left_stats: TableStats
    right_stats: TableStats
    report: JoinReport

    @property
    def network_bytes(self) -> float:
        return sum(e.network_bytes for e in self.report.exchanges)

    @property
    def local_bytes(self) -> float:
        return self.report.local_bytes


@dataclasses.dataclass
class ExecutionResult:
    table: Table
    decisions: List[JoinDecision]
    wall_time_s: float
    network_bytes: float
    local_bytes: float
    rows: int

    def methods(self):
        return [d.selection.method for d in self.decisions]

    def workload(self, w: float = 1.0) -> float:
        """Measured cluster workload under the paper's weighting."""
        return w * self.network_bytes + self.local_bytes


@dataclasses.dataclass
class _Annotated:
    table: Table
    measured: TableStats   # adaptive runtime statistic (post-materialization)
    estimated: TableStats  # statically-propagated estimate


class Executor:
    def __init__(self, catalog: Catalog, strategy: Strategy,
                 adaptive: bool = True, est_error: float = 1.0,
                 use_kernel: bool = False, capacity_factor: float = 2.0,
                 compact: bool = True):
        self.catalog = catalog
        self.strategy = strategy
        self.adaptive = adaptive
        self.est_error = est_error
        self.use_kernel = use_kernel
        self.capacity_factor = capacity_factor
        self.compact = compact
        self.p = catalog.p

    # -- public ---------------------------------------------------------------

    def execute(self, plan: Node) -> ExecutionResult:
        self._decisions: List[JoinDecision] = []
        t0 = time.perf_counter()
        ann = self._eval(plan)
        ann.table.valid.block_until_ready()
        dt = time.perf_counter() - t0
        net = sum(d.network_bytes for d in self._decisions)
        loc = sum(d.local_bytes for d in self._decisions)
        return ExecutionResult(ann.table, self._decisions, dt, net, loc,
                               ann.table.count())

    # -- evaluation ------------------------------------------------------------

    def _eval(self, node: Node) -> _Annotated:
        if isinstance(node, Scan):
            t = self.catalog.table(node.table)
            measured = t.measure()
            est = TableStats(measured.size_bytes * self.est_error,
                             measured.cardinality * self.est_error,
                             StatsSource.ESTIMATED)
            return _Annotated(t, measured, est)

        if isinstance(node, Filter):
            child = self._eval(node.child)
            t = _apply_filter(child.table, node)
            # In-stage operator: runtime stats are *propagated estimates*
            # from the last materialization (paper §4.1 step 2).
            measured = estimate_filter(child.measured, node.selectivity)
            est = estimate_filter(child.estimated, node.selectivity)
            return _Annotated(t, measured, est)

        if isinstance(node, Project):
            child = self._eval(node.child)
            t = child.table.select(node.columns)
            frac = t.row_bytes / max(child.table.row_bytes, 1)
            m, e = child.measured, child.estimated
            return _Annotated(
                t,
                TableStats(m.size_bytes * frac, m.cardinality, m.source),
                TableStats(e.size_bytes * frac, e.cardinality, e.source))

        if isinstance(node, Join):
            left = self._eval(node.left)
            right = self._eval(node.right)
            # Exchange boundary: re-measure both inputs (adaptive runtime
            # statistics). Non-adaptive mode keeps static estimates.
            lstats = self._boundary_stats(left, node.left)
            rstats = self._boundary_stats(right, node.right)
            props = JoinProperties(join_type=node.join_type, hint=node.hint)
            sel = self.strategy.select(lstats, rstats, props, self.p)
            jt = {"inner": "inner"}.get(node.join_type.value,
                                        node.join_type.value)
            out, rep = self._run_join_with_retry(
                sel, left.table, right.table, node.left_key, node.right_key,
                jt)
            if self.compact:
                out = compact_partitions(out)
            self._decisions.append(JoinDecision(sel, lstats, rstats, rep))
            measured = out.measure()
            est = estimate_join(left.estimated, right.estimated)
            return _Annotated(out, measured, est)

        if isinstance(node, Aggregate):
            child = self._eval(node.child)
            out, _rep = self._run_agg_with_retry(child.table, node.key,
                                                 node.aggs)
            if self.compact:
                out = compact_partitions(out)
            measured = out.measure()
            est = estimate_group_by(child.estimated,
                                    measured.cardinality or 1)
            return _Annotated(out, measured, est)

        raise TypeError(f"unknown plan node {type(node)}")

    def _run_join_with_retry(self, sel, left, right, lk, rk, jt):
        """Skew mitigation: double slot capacity until no overflow (the
        engine-level straggler guard; DESIGN.md scale-out design)."""
        factor = self.capacity_factor
        for _ in range(4):
            out, rep = run_equi_join(sel.method, left, right, lk, rk,
                                     join_type=jt, use_kernel=self.use_kernel,
                                     capacity_factor=factor)
            if all(e.overflow_rows == 0 for e in rep.exchanges):
                return out, rep
            factor *= 2 * max(self.p // 2, 1)
        raise RuntimeError("shuffle overflow persisted after capacity retries")

    def _run_agg_with_retry(self, table, key, aggs):
        factor = self.capacity_factor
        for _ in range(4):
            out, rep = group_aggregate(table, key, aggs, factor)
            if rep.overflow_rows == 0:
                return out, rep
            factor *= 2 * max(self.p // 2, 1)
        raise RuntimeError("aggregate overflow persisted after retries")

    def _boundary_stats(self, ann: _Annotated, node: Node) -> TableStats:
        if not self.adaptive:
            return ann.estimated
        # Post-exchange children were just materialized: exact runtime stats.
        if isinstance(node, (Join, Aggregate, Scan)):
            return ann.table.measure()
        return ann.measured


def _apply_filter(table: Table, f: Filter) -> Table:
    c = table.column(f.column)
    if f.op == "eq":
        m = c == f.value
    elif f.op == "lt":
        m = c < f.value
    elif f.op == "le":
        m = c <= f.value
    elif f.op == "gt":
        m = c > f.value
    elif f.op == "ge":
        m = c >= f.value
    elif f.op == "between":
        m = (c >= f.value) & (c <= f.value2)
    else:
        raise ValueError(f"unknown filter op {f.op}")
    return table.with_valid(table.valid & m)
