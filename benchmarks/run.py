"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call is 0.0 for
derived-metric rows). Engine benchmarks use the measured-cluster-workload
metric as primary (the paper's own §3.1.1 cost metric); wall-clock on this
1-core container is a secondary signal.

    PYTHONPATH=src python -m benchmarks.run [--quick|--smoke] [--only a,b]
                                            [--json-out DIR]

``--smoke`` imports and runs EVERY registered benchmark at scale 0.01 with
minimal repeats — the CI job that keeps new benchmarks from rotting
unexecuted. Registration is the ``REGISTRY`` table below: a benchmark that
is not in it does not exist as far as run.py and CI are concerned.

``--json-out DIR`` additionally writes one ``DIR/<bench>.json`` per
benchmark — the emitted rows plus profile metadata and wall time — which
the CI smoke job uploads as the ``bench-smoke-json`` artifact, seeding the
cross-PR benchmark trajectory.

``--json-bundle FILE`` writes the same payloads as ONE file holding a
JSON list — the committable form. ``BENCH_BASELINE.json`` at the repo
root is such a bundle (from ``--smoke``); CI compares every push's fresh
smoke run against it.

``--compare OLD NEW`` diffs two such artifacts (files, bundles, or
directories of ``<bench>.json`` files) instead of running anything: every
tracked metric — per-benchmark wall seconds and every timed row's
``us_per_call`` — is compared, and any regression beyond ``--threshold``
(default 10%) exits non-zero with the offenders listed.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from . import common

#: Tiny scale for the CI smoke profile: every fact shrinks to its 8-row
#: floor .. ~1k rows; dimensions keep their fixed sizes. Fast enough to run
#: the whole registry in one CI job, big enough to execute every code path.
SMOKE_SCALE = 0.01


def _registry():
    """name -> (module, default_kwargs, quick_kwargs, smoke_kwargs).

    Bench modules are imported here rather than at module top level so
    ``--help`` and argument errors don't pay for the jax-heavy stack.
    (``--only`` still imports every registered module — imports are cheap
    relative to any single benchmark run.)"""
    from . import (bench_accuracy, bench_cost_model, bench_filters,
                   bench_hypercube, bench_kernels, bench_psts,
                   bench_reorder, bench_reopt, bench_roofline,
                   bench_service, bench_skew, bench_strategies,
                   bench_w_sweep)

    s = SMOKE_SCALE
    return {
        "cost_model": (bench_cost_model, {}, {}, {}),
        "kernels": (bench_kernels, {}, {}, {}),
        "strategies": (bench_strategies,
                       {"scales": (0.2, 0.5), "runs": 2},
                       {"scales": (0.2,), "runs": 1},
                       {"scales": (s,), "runs": 1}),
        "accuracy": (bench_accuracy, {"scale": 0.3, "runs": 2},
                     {"scale": 0.2, "runs": 1}, {"scale": s, "runs": 1}),
        "psts": (bench_psts, {"scale": 0.3, "runs": 2},
                 {"scale": 0.2, "runs": 1}, {"scale": s, "runs": 1}),
        "w_sweep": (bench_w_sweep, {"scale": 0.3, "runs": 2},
                    {"scale": 0.2, "runs": 1}, {"scale": s, "runs": 1}),
        "reorder": (bench_reorder, {"scale": 0.2}, {"scale": 0.2},
                    {"scale": s}),
        "hypercube": (bench_hypercube, {"scale": 0.2}, {"scale": 0.2},
                      {"scale": s}),
        "skew": (bench_skew, {"scale": 0.2, "zipfs": (0.0, 0.8, 1.2, 1.4)},
                 {"scale": 0.2, "zipfs": (0.0, 1.2)},
                 {"scale": s, "zipfs": (0.0, 1.2)}),
        "filters": (bench_filters, {"scale": 0.2}, {"scale": 0.2},
                    {"scale": s}),
        "reopt": (bench_reopt, {"scale": 0.1}, {"scale": 0.1},
                  {"scale": s}),
        "service": (bench_service, {"scale": 0.2}, {"scale": 0.1},
                    {"scale": s}),
        "roofline": (bench_roofline, {}, {}, {}),
    }


def _load_artifacts(path: pathlib.Path) -> dict:
    """Load bench-JSON artifacts keyed by benchmark name: one per-bench
    file, a bundle file holding a JSON list of payloads (the committed
    ``BENCH_BASELINE.json`` form), or a directory of ``*.json`` files
    (each itself a payload or a bundle)."""
    if path.is_dir():
        files = sorted(path.glob("*.json"))
    else:
        files = [path]
    out = {}
    for f in files:
        payload = json.loads(f.read_text())
        for p in payload if isinstance(payload, list) else [payload]:
            out[p["bench"]] = p
    return out


def _tracked_metrics(artifacts: dict) -> dict:
    """Flatten artifacts into ``metric-name -> value`` for comparison:
    per-benchmark wall seconds plus every row's ``us_per_call`` — zero
    rows (derived metrics, warm-cache passes) included, so a baseline
    that was 0 still has teeth via the absolute-delta fallback."""
    metrics = {}
    for bench, payload in artifacts.items():
        metrics[f"{bench}:seconds"] = float(payload["seconds"])
        for row in payload.get("rows", []):
            metrics[f"{bench}/{row['name']}:us_per_call"] = float(
                row.get("us_per_call", 0.0))
    return metrics


def compare_artifacts(old_path: str, new_path: str,
                      threshold: float = 0.10,
                      abs_threshold: float = 100.0) -> list:
    """Regressions of ``new`` vs ``old``: tracked metrics that grew by
    more than ``threshold`` (fraction), plus tracked metrics that vanished
    (a silently dropped benchmark is a regression, not a win). A
    zero-valued baseline has no ratio to regress against — dividing by it
    (or guarding on ``old > 0`` alone) would let any blowup through
    silently — so those metrics fall back to an absolute gate: new value
    beyond ``abs_threshold`` (same unit as the metric) is an offense.
    Returns a list of human-readable offense lines, empty when clean."""
    old = _tracked_metrics(_load_artifacts(pathlib.Path(old_path)))
    new = _tracked_metrics(_load_artifacts(pathlib.Path(new_path)))
    offenses = []
    for name, old_val in sorted(old.items()):
        if name not in new:
            offenses.append(f"{name}: missing from new artifact "
                            f"(was {old_val:g})")
            continue
        new_val = new[name]
        if old_val > 0:
            if new_val > old_val * (1 + threshold):
                pct = 100.0 * (new_val / old_val - 1)
                offenses.append(f"{name}: {old_val:g} -> {new_val:g} "
                                f"(+{pct:.1f}% > {100 * threshold:.0f}%)")
        elif new_val > abs_threshold:
            offenses.append(f"{name}: {old_val:g} -> {new_val:g} "
                            f"(zero baseline; exceeds absolute "
                            f"threshold {abs_threshold:g})")
    return offenses


def new_benchmarks(old_path: str, new_path: str) -> list:
    """Benchmarks present only in the NEW artifact (freshly registered, no
    baseline entry). ``--compare`` used to skip these silently — CI passed
    while tracking none of their metrics. They are informational, not
    offenses (a new benchmark is not a regression), but surfacing them
    prompts the re-baseline that gives their metrics teeth."""
    old = _load_artifacts(pathlib.Path(old_path))
    new = _load_artifacts(pathlib.Path(new_path))
    return sorted(set(new) - set(old))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scales / fewer repeats")
    ap.add_argument("--smoke", action="store_true",
                    help=f"run every registered benchmark at scale "
                         f"{SMOKE_SCALE} (CI rot-guard)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of registered names")
    ap.add_argument("--json-out", default="",
                    help="directory for per-benchmark JSON result files")
    ap.add_argument("--json-bundle", default="",
                    help="write all results as one JSON-list bundle file "
                         "(the BENCH_BASELINE.json form)")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two bench-JSON artifacts (files or "
                         "directories) instead of running; exit non-zero "
                         "on any regression beyond --threshold")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="--compare regression threshold as a fraction "
                         "(default 0.10 = 10%%)")
    ap.add_argument("--abs-threshold", type=float, default=100.0,
                    help="--compare absolute fallback gate for metrics "
                         "whose baseline is 0 (default 100, metric units)")
    args = ap.parse_args(argv)
    if args.compare:
        offenses = compare_artifacts(args.compare[0], args.compare[1],
                                     args.threshold, args.abs_threshold)
        for line in offenses:
            print(f"REGRESSION {line}")
        for bench in new_benchmarks(args.compare[0], args.compare[1]):
            print(f"NEW {bench}: not in the baseline — informational only; "
                  f"re-baseline to start tracking its metrics")
        if offenses:
            sys.exit(1)
        print(f"no regressions beyond {100 * args.threshold:.0f}%")
        return
    json_dir = pathlib.Path(args.json_out) if args.json_out else None
    if json_dir is not None:
        json_dir.mkdir(parents=True, exist_ok=True)
    bundle_path = (pathlib.Path(args.json_bundle) if args.json_bundle
                   else None)
    capture = json_dir is not None or bundle_path is not None
    bundle = []
    registry = _registry()
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(registry)
        if unknown:
            ap.error(f"unknown benchmarks: {sorted(unknown)}; "
                     f"registered: {sorted(registry)}")

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, (module, default, quick, smoke) in registry.items():
        if only is not None and name not in only:
            continue
        kwargs = smoke if args.smoke else (quick if args.quick else default)
        t1 = time.time()
        if capture:
            common.start_capture()
        module.run(**kwargs)
        dt = time.time() - t1
        if capture:
            profile = ("smoke" if args.smoke
                       else "quick" if args.quick else "default")
            payload = {"bench": name, "profile": profile, "kwargs": kwargs,
                       "seconds": round(dt, 3), "rows": common.end_capture()}
            bundle.append(payload)
            if json_dir is not None:
                (json_dir / f"{name}.json").write_text(
                    json.dumps(payload, indent=1, default=str) + "\n")
        print(f"# {name} {dt:.1f}s", file=sys.stderr)

    if bundle_path is not None:
        bundle_path.write_text(
            json.dumps(bundle, indent=1, default=str) + "\n")
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
