"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call is 0.0 for
derived-metric rows). Engine benchmarks use the measured-cluster-workload
metric as primary (the paper's own §3.1.1 cost metric); wall-clock on this
1-core container is a secondary signal.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scales / fewer repeats")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: strategies,accuracy,psts,"
                         "w_sweep,cost_model,kernels,roofline,reorder,skew")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_accuracy, bench_cost_model, bench_kernels,
                   bench_psts, bench_reorder, bench_roofline, bench_skew,
                   bench_strategies, bench_w_sweep)

    print("name,us_per_call,derived")
    t0 = time.time()

    def want(name):
        return only is None or name in only

    if want("cost_model"):
        bench_cost_model.run()
    if want("kernels"):
        bench_kernels.run()
    if want("strategies"):
        bench_strategies.run(scales=(0.2,) if args.quick else (0.2, 0.5),
                             runs=1 if args.quick else 2)
    if want("accuracy"):
        bench_accuracy.run(scale=0.2 if args.quick else 0.3,
                           runs=1 if args.quick else 2)
    if want("psts"):
        bench_psts.run(scale=0.2 if args.quick else 0.3,
                       runs=1 if args.quick else 2)
    if want("w_sweep"):
        bench_w_sweep.run(scale=0.2 if args.quick else 0.3,
                          runs=1 if args.quick else 2)
    if want("reorder"):
        bench_reorder.run(scale=0.2)
    if want("skew"):
        bench_skew.run(scale=0.2,
                       zipfs=(0.0, 1.2) if args.quick else (0.0, 0.8, 1.2,
                                                            1.4))
    if want("roofline"):
        bench_roofline.run()

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
