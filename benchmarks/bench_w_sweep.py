"""Fig. 6 reproduction: RelJoin sensitivity to the network-cost weight w.

Paper: average time is flat-ish in w; the max query time shows a "V" with
the optimum near w=1 (their GbE testbed); extreme w degrades mildly but
stays better than forced strategies.

k0 = (pw+p-w)/w = p + p/w - 1 DEcreases in w: a more expensive network
makes broadcasting (which moves only (p-1)|B|) preferable earlier, so the
broadcast count is NONDECREASING in w (w->0 degenerates to the forced-
shuffle strategies, exactly the paper's §5.5 observation)."""

from __future__ import annotations

from repro.sql import RelJoinStrategy, generate

from .common import emit, mean, run_suite

W_VALUES = (1e-5, 0.1, 1.0, 10.0, 1e5)


def run(scale: float = 0.3, p: int = 8, runs: int = 2):
    catalog = generate(scale=scale, p=p, seed=0)
    results = {}
    for w in W_VALUES:
        suite = run_suite(catalog, RelJoinStrategy(w=w), runs=runs)
        walls = [r["wall_s"] for r in suite.values()]
        works = [r["workload"] for r in suite.values()]
        n_bcast = sum(m.value == "broadcast_hash"
                      for r in suite.values() for m in r["methods"])
        results[w] = (mean(walls), max(walls), mean(works), n_bcast)
        emit(f"w_sweep/w={w:g}", mean(walls) * 1e6,
             f"max_wall_s={max(walls):.2f};"
             f"workload_MB={mean(works) / 2 ** 20:.1f};"
             f"n_broadcast={n_bcast}")
    # derived claim: broadcast count is nondecreasing in w (k0 = p+p/w-1)
    counts = [results[w][3] for w in W_VALUES]
    ok = all(a <= b for a, b in zip(counts, counts[1:]))
    emit("w_sweep/claim_broadcast_monotone", 0.0,
         f"counts={counts};expect_nondecreasing;holds={ok}")
    return results


if __name__ == "__main__":
    run()
