"""Table 5 reproduction: selection-difference statistics and the PSTS
metric (%TimeDiff / %JoinDiff with AQE as the baseline).

Paper: RelJoin PSTS = 1.98, ShuffleSort/ShuffleHash ~ -0.03/-0.04. We
compute PSTS on both wall time and on measured workload (the deterministic
variant, immune to 1-core CI noise)."""

from __future__ import annotations

from repro.core import compute_psts
from repro.sql import default_strategies, generate

from .common import emit, run_suite


def run(scale: float = 0.3, p: int = 8, runs: int = 2):
    catalog = generate(scale=scale, p=p, seed=0)
    strategies = default_strategies()
    suites = {s.name: run_suite(catalog, s, runs=runs) for s in strategies}
    qnames = list(next(iter(suites.values())))
    base = suites["AQE"]

    reports = {}
    for name, suite in suites.items():
        if name == "AQE":
            continue
        s_methods, b_methods = [], []
        s_costs, b_costs = [], []
        for q in qnames:
            s_methods += suite[q]["methods"]
            b_methods += base[q]["methods"]
            s_costs += [d.selection.cost or 0.0
                        for d in suite[q]["decisions"]]
            b_costs += [d.selection.cost or 0.0
                        for d in base[q]["decisions"]]
        t_s = sum(suite[q]["wall_s"] for q in qnames)
        t_b = sum(base[q]["wall_s"] for q in qnames)
        w_s = sum(suite[q]["workload"] for q in qnames)
        w_b = sum(base[q]["workload"] for q in qnames)
        rep_t = compute_psts(s_methods, b_methods, t_s, t_b)
        rep_w = compute_psts(s_methods, b_methods, w_s, w_b)
        reports[name] = (rep_t, rep_w)
        emit(f"psts/{name}", 0.0,
             f"joindiff={rep_t.n_join_diff}/{rep_t.n_joins};"
             f"pct_join={rep_t.pct_join_diff:.1f}%;"
             f"psts_wall={rep_t.psts:.2f};psts_workload={rep_w.psts:.2f}")
    rel_t, rel_w = reports["RelJoin(w=1)"]
    emit("psts/claim_reljoin_positive", 0.0,
         f"psts_workload={rel_w.psts:.2f};expect>0")
    return reports


if __name__ == "__main__":
    run()
