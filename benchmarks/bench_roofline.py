"""Roofline summary (deliverable g): reads the dry-run artifacts from
experiments/dryrun/ and emits the per-(arch x shape) roofline table rows.
Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(mesh: str = "single"):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json")))
    if not files:
        emit("roofline/no_dryrun_artifacts", 0.0,
             "run repro.launch.dryrun --all first")
        return []
    rows = []
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        cell = f"{rec['arch']}/{rec['shape']}"
        if rec["status"] == "skip":
            emit(f"roofline/{cell}", 0.0, "SKIP:" + rec["reason"][:60])
            continue
        if rec["status"] != "ok":
            emit(f"roofline/{cell}", 0.0,
                 "ERROR:" + rec.get("error", "?")[:80])
            continue
        r = rec["roofline"]
        step = r["step_time_s"]
        frac = r["compute_s"] / step if step else 0.0
        emit(f"roofline/{cell}", step * 1e6,
             f"bound={r['bound']};compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"roofline_frac={frac:.3f};"
             f"model_flops_ratio={rec['model_flops_ratio']:.3f}")
        rows.append((cell, r))
    return rows


if __name__ == "__main__":
    run()
