"""Hypercube multi-way shuffle join benchmark: the cyclic queries q35-q37.

Per query, two arms of the same executor: the full planner (which quotes
the hypercube against the System-R DP's best binary tree and takes it only
when the modeled replication volume is strictly cheaper) and a
``hypercube=False`` arm forced onto the best binary plan. Reported per
query:

  * whether Algorithm 1 selected the multi-way plan from cost alone,
  * measured NETWORK bytes of each arm (the paper's §3.1.1 metric) and
    their ratio — the replication volume vs the binary plan's
    intermediate re-shipping,
  * row-multiset equality of the two arms (the plans must agree on the
    answer, not just the bill).

Paper-claim check (at the default scale-0.2 / p=8 profile): on every
cyclic query the cube is selected on relative cost and its measured
network bytes are strictly lower than the best binary order's. The smoke
profile (scale 0.01) only exercises the code paths — at toy sizes the
gate may correctly keep the binary plan, so the claim row reports but the
expectation is scoped to the default profile.
"""

from __future__ import annotations

from repro.core.cost_model import JoinMethod
from repro.joins.ref import rows_as_set
from repro.sql import Executor, ReorderingStrategy, cyclic_queries, generate

from .common import emit


def run(scale: float = 0.2, p: int = 8, w: float = 1.0):
    catalog = generate(scale=scale, p=p, seed=0)
    rows = []
    for qname, plan in cyclic_queries().items():
        hyper = Executor(catalog, ReorderingStrategy(w=w),
                         verify=True).execute(plan)
        binary = Executor(catalog, ReorderingStrategy(w=w), verify=True,
                          hypercube=False).execute(plan)
        selected = JoinMethod.HYPERCUBE_SHUFFLE in hyper.methods()
        same = (rows_as_set(hyper.table.to_numpy())
                == rows_as_set(binary.table.to_numpy()))
        ratio = hyper.network_bytes / max(binary.network_bytes, 1.0)
        rows.append((qname, selected, same, hyper, binary))
        emit(f"hypercube/measured/{qname}", hyper.wall_time_s * 1e6,
             f"net_KB={binary.network_bytes / 1024:.1f}"
             f"->{hyper.network_bytes / 1024:.1f};"
             f"ratio={ratio:.3f};selected={int(selected)};"
             f"rows_equal={int(same)}")
        if selected:
            d = next(d for d in hyper.decisions
                     if d.selection.method is JoinMethod.HYPERCUBE_SHUFFLE)
            emit(f"hypercube/modeled/{qname}", 0.0,
                 f"cube_MB={d.selection.cost / 2 ** 20:.3f};"
                 f"reason={d.selection.reason}")

    n_sel = sum(1 for r in rows if r[1])
    n_win = sum(1 for r in rows
                if r[1] and r[3].network_bytes < r[4].network_bytes)
    n_same = sum(1 for r in rows if r[2])
    emit("hypercube/claim/cyclic_suite", 0.0,
         f"selected={n_sel}/{len(rows)};net_wins={n_win}/{len(rows)};"
         f"rows_equal={n_same}/{len(rows)};"
         f"expect_all_at_scale>=0.2")
    if scale >= 0.2:
        assert n_same == len(rows), "hypercube arm changed the answer"
        assert n_sel == n_win == len(rows), (
            "hypercube must be cost-selected AND net-cheaper on every "
            f"cyclic query at scale {scale}: selected {n_sel}, "
            f"wins {n_win} of {len(rows)}")
    return rows


if __name__ == "__main__":
    run()
