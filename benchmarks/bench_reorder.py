"""Join-reordering benchmark: modeled and measured deltas from plan-space
search (planner.py) on top of every selection strategy.

Reported per query:
  * modeled workload (Eq. 4/8/10 sum) of the written order vs the System-R
    DP order — the planner's predicted win,
  * executed network bytes and total measured workload ± reordering.

Paper-claim checks: the DP order is never modeled worse than the written
order (the planner keeps plan order otherwise), the mis-ordered queries
(q13-q15) see large wins, and suite-total network bytes do not regress."""

from __future__ import annotations

from repro.sql import (Executor, ReorderingStrategy, default_strategies,
                       every_query, generate, misordered_queries, optimize)

from .common import emit, mean


def run(scale: float = 0.2, p: int = 8, w: float = 1.0):
    catalog = generate(scale=scale, p=p, seed=0)
    queries = every_query()

    # -- modeled deltas (static planner, exact base stats) ------------------
    for qname, plan in queries.items():
        res = optimize(plan, catalog)
        if not res.regions:
            continue
        ratio = res.chosen_cost / max(res.plan_order_cost, 1.0)
        emit(f"reorder/modeled/{qname}", 0.0,
             f"plan_MB={res.plan_order_cost / 2 ** 20:.3f};"
             f"dp_MB={res.chosen_cost / 2 ** 20:.3f};"
             f"ratio={ratio:.3f};reordered={int(res.reordered)}")

    # -- measured deltas per strategy ---------------------------------------
    rows = []
    for strat in default_strategies(w=w):
        for qname, plan in queries.items():
            base = Executor(catalog, strat).execute(plan)
            reord = Executor(catalog, ReorderingStrategy(strat, w=w)
                             ).execute(plan)
            rows.append((strat.name, qname, base, reord))
            emit(f"reorder/measured/{strat.name}/{qname}",
                 reord.wall_time_s * 1e6,
                 f"net_KB={base.network_bytes / 1024:.1f}"
                 f"->{reord.network_bytes / 1024:.1f};"
                 f"work_KB={base.workload(w) / 1024:.1f}"
                 f"->{reord.workload(w) / 1024:.1f}")

    # -- claim checks -------------------------------------------------------
    for strat in default_strategies(w=w):
        mine = [r for r in rows if r[0] == strat.name]
        net_base = sum(r[2].network_bytes for r in mine)
        net_re = sum(r[3].network_bytes for r in mine)
        work_base = sum(r[2].workload(w) for r in mine)
        work_re = sum(r[3].workload(w) for r in mine)
        emit(f"reorder/claim/{strat.name}/suite_totals", 0.0,
             f"net_ratio={net_re / max(net_base, 1):.3f};"
             f"work_ratio={work_re / max(work_base, 1):.3f};expect<=1")
    mis = [r for r in rows if r[1] in misordered_queries()
           and r[0].startswith("RelJoin")]
    if mis:
        gains = [r[2].network_bytes / max(r[3].network_bytes, 1.0)
                 for r in mis]
        emit("reorder/claim/misordered_net_gain", 0.0,
             f"mean_x={mean(gains):.2f};expect>1")
    return rows


if __name__ == "__main__":
    run()
