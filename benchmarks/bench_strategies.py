"""Fig. 4/5 reproduction: query completion (wall + measured workload) per
selection strategy, across benchmark scales.

Paper claims validated here: RelJoin <= AQE <= forced-shuffle strategies
on average; RelJoin reduces the max query time; forced strategies suffer
most on broadcast-friendly queries (q72/q2-like chains)."""

from __future__ import annotations

import statistics

from repro.sql import ReorderingStrategy, default_strategies, generate

from .common import emit, mean, run_suite


def run(scales=(0.2, 0.5), p: int = 8, runs: int = 2,
        reorder: bool = False):
    """``reorder=True`` wraps every baseline in ReorderingStrategy so the
    whole comparison also exercises plan-space search (bench_reorder holds
    the direct ± comparison)."""
    rows = []
    for scale in scales:
        catalog = generate(scale=scale, p=p, seed=0)
        for strat in default_strategies():
            if reorder:
                strat = ReorderingStrategy(strat)
            suite = run_suite(catalog, strat, runs=runs)
            walls = [r["wall_s"] for r in suite.values()]
            works = [r["workload"] for r in suite.values()]
            nets = [r["network_bytes"] for r in suite.values()]
            emit(f"strategies/scale{scale}/{strat.name}/avg_wall",
                 mean(walls) * 1e6,
                 f"workload_MB={mean(works) / 2 ** 20:.1f};"
                 f"net_MB={mean(nets) / 2 ** 20:.2f};"
                 f"max_wall_s={max(walls):.2f};"
                 f"std_wall_s={statistics.pstdev(walls):.2f}")
            rows.append((scale, strat.name, mean(walls), max(walls),
                         mean(works), mean(nets)))
    # paper-claim checks (soft, printed as derived values)
    by = {(s, n): (aw, mw, wk, nb) for s, n, aw, mw, wk, nb in rows}
    wrap = (lambda n: f"Reorder({n})") if reorder else (lambda n: n)
    for scale in scales:
        rel = by[(scale, wrap("RelJoin(w=1)"))]
        aqe = by[(scale, wrap("AQE"))]
        ss = by[(scale, wrap("ShuffleSort"))]
        emit(f"strategies/scale{scale}/claim_rel_vs_shufflesort_workload",
             0.0, f"ratio={rel[2] / ss[2]:.3f};expect<1")
        emit(f"strategies/scale{scale}/claim_rel_le_aqe_workload",
             0.0, f"ratio={rel[2] / aqe[2]:.3f};expect<=1.02")
    return rows


if __name__ == "__main__":
    run()
