"""Runtime-filter framework benchmark: probe-side shuffle bytes, total
network bytes, per-edge kind selection and result equality of
FilteredStrategy (bloom + zone-map + semi-join) vs RelJoinStrategy — and
vs the PR-3 bloom-only configuration — on the filter-friendly queries
(q19-q23), plus the warm-vs-cold cross-query FilterCache pass.

Reported per query:
  * probe-side shuffle bytes (the traffic runtime filters exist to cut)
    and total network bytes (which *includes* the filters' reduce/gather
    + broadcast — the win is net of the filters' price),
  * the planned filters: kind, keys, wire bits, predicted vs measured
    kept fraction, and the wire split — distributed-build reduce bytes
    (per-kind shape: log-tree for bloom/zone-map, all_gather for
    semi-join) separately from broadcast bytes, so the per-kind
    ``filter_reduce_cost`` model is auditable in the JSON artifact,
  * result equality (identical up to float summation order).

Claim checks: every filtered query plans at least one filter, results are
identical, the framework picks a non-bloom kind on at least one query
(q22 -> zone_map, q23 -> semi_join), the suite-total probe-side shuffle
bytes shrink by >= 2x, and on the PR-3 queries (q19-q21) the framework's
probe-shuffle bytes are never worse than bloom-only. A parity check on
unfiltered-build queries (q2, q9) asserts the strict cost gate: no
filters planned, selections byte-identical.

The warm-cache pass replays the whole suite against one shared
``FilterCache``: the first run populates it, the repeat run must plan
>= 1 *cached* filter per query with zero rebuild (reduce) bytes and
identical results — q19-q23's repeat-run filter build work drops to ~0.
"""

from __future__ import annotations

from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (Executor, FilterCache, FilteredStrategy,
                       RelJoinStrategy, all_queries, filtered_queries,
                       generate)

from .common import emit

#: The PR-3 queries: filter-friendly, but with no kind diversity — the
#: bloom-vs-framework parity claim runs on these.
_BLOOM_ERA = ("q19_filtered_customer", "q20_filter_below_earlier_exchange",
              "q21_catalog_filtered_dates")


def run(scale: float = 0.2, p: int = 8, w: float = 1.0):
    catalog = generate(scale=scale, p=p, seed=0)
    rows = []
    for qname, plan in filtered_queries().items():
        base = Executor(catalog, RelJoinStrategy(w=w)).execute(plan)
        filt = Executor(catalog, FilteredStrategy(RelJoinStrategy(w=w))
                        ).execute(plan)
        # The bloom-only run only feeds the q19-q21 parity claim.
        bloom = (Executor(catalog,
                          FilteredStrategy(RelJoinStrategy(w=w),
                                           kinds=("bloom",))).execute(plan)
                 if qname in _BLOOM_ERA else None)
        same = rows_close(rows_as_set(filt.table.to_numpy()),
                          rows_as_set(base.table.to_numpy()))
        rows.append((qname, base, filt, bloom, same))
        fdesc = ";".join(
            f"{f.plan.kind}:{f.plan.probe_key}<-{f.plan.build_key}"
            f"(bits={f.plan.m_bits},"
            f"keep_est={f.plan.keep_est:.3f},keep={f.keep_measured:.3f},"
            f"reduce_B={f.reduce_bytes:.0f},bcast_B={f.broadcast_bytes:.0f})"
            for f in filt.filters) or "none"
        emit(f"filters/measured/{qname}", filt.wall_time_s * 1e6,
             f"probe_shuffle_KB={base.probe_shuffle_bytes / 1024:.1f}"
             f"->{filt.probe_shuffle_bytes / 1024:.1f};"
             f"net_KB={base.network_bytes / 1024:.1f}"
             f"->{filt.network_bytes / 1024:.1f};"
             f"filter_KB={filt.filter_network_bytes / 1024:.2f};"
             f"reduce_KB={filt.filter_reduce_bytes / 1024:.2f};"
             f"same={int(same)};filters={fdesc}")

    # -- claim checks -------------------------------------------------------
    for qname, base, filt, bloom, same in rows:
        ratio = (base.probe_shuffle_bytes
                 / max(filt.probe_shuffle_bytes, 1.0))
        emit(f"filters/claim/{qname}", 0.0,
             f"planned={int(bool(filt.filters))};"
             f"probe_shuffle_x={ratio:.2f};same={int(same)};"
             f"expect=planned&same")
    total_base = sum(r[1].probe_shuffle_bytes for r in rows)
    total_filt = sum(r[2].probe_shuffle_bytes for r in rows)
    suite_x = total_base / max(total_filt, 1.0)
    emit("filters/claim/suite_probe_shuffle", 0.0,
         f"KB={total_base / 1024:.1f}->{total_filt / 1024:.1f};"
         f"x={suite_x:.2f};expect>=2")

    # -- framework claims: kind diversity + no regression vs bloom-only -----
    kinds = sorted({f.plan.kind for _, _, filt, _, _ in rows
                    for f in filt.filters})
    emit("filters/claim/kind_diversity", 0.0,
         f"kinds={'+'.join(kinds)};non_bloom={int(any(k != 'bloom' for k in kinds))};"
         f"expect=non_bloom")
    for qname, base, filt, bloom, _ in rows:
        if qname not in _BLOOM_ERA:
            continue
        ok = filt.probe_shuffle_bytes <= bloom.probe_shuffle_bytes * 1.001
        emit(f"filters/claim/no_worse_than_bloom/{qname}", 0.0,
             f"framework_KB={filt.probe_shuffle_bytes / 1024:.1f};"
             f"bloom_only_KB={bloom.probe_shuffle_bytes / 1024:.1f};"
             f"ok={int(ok)};expect=1")

    # -- parity: unfiltered builds plan nothing -----------------------------
    for qname in ("q2_chain7", "q9_inventory_star"):
        plan = all_queries()[qname]
        base = Executor(catalog, RelJoinStrategy(w=w)).execute(plan)
        filt = Executor(catalog, FilteredStrategy(RelJoinStrategy(w=w))
                        ).execute(plan)
        ok = (not filt.filters and filt.methods() == base.methods())
        emit(f"filters/claim/parity/{qname}", 0.0,
             f"no_filters_and_identical_selections={int(ok)};expect=1")

    # -- warm-vs-cold cross-query cache pass --------------------------------
    # One FilterCache per query, so every cold replay is *truly* cold —
    # a suite-shared cache would let one query's payloads pre-warm
    # another's cold run whenever two builds share a predicate chain,
    # silently corrupting the cold-identity claim. (Cross-query sharing
    # semantics are pinned by tests/test_filter_kinds.py instead.) The
    # cold replay must select exactly what the uncached runs above
    # selected — the cold-cache byte-identity claim — and the warm replay
    # must reuse every cacheable payload with zero rebuild (reduce)
    # bytes.
    total_cold_reduce = total_warm_reduce = 0.0
    total_hits = total_misses = 0
    all_warm_ok = True
    for qname, base, filt, _bloom, _same in rows:
        plan = filtered_queries()[qname]
        cache = FilterCache()
        strat = FilteredStrategy(RelJoinStrategy(w=w), cache=cache)
        cold = Executor(catalog, strat).execute(plan)
        warm = Executor(catalog, strat).execute(plan)
        total_hits += cache.hits
        total_misses += cache.misses
        cold_identical = ([f.plan.kind for f in cold.filters]
                          == [f.plan.kind for f in filt.filters])
        warm_same = rows_close(rows_as_set(warm.table.to_numpy()),
                               rows_as_set(base.table.to_numpy()))
        # At tiny scales a query may legitimately plan no filter at all
        # (the strict gate); the cache claim then degrades to "nothing to
        # rebuild" rather than failing on a vacuous expectation.
        ok = (cold_identical and warm_same
              and warm.filter_reduce_bytes == 0.0
              and (warm.cached_filters >= 1 or not cold.filters))
        all_warm_ok &= ok
        total_cold_reduce += cold.filter_reduce_bytes
        total_warm_reduce += warm.filter_reduce_bytes
        emit(f"filters/cache/{qname}", warm.wall_time_s * 1e6,
             f"cold_identical_to_uncached={int(cold_identical)};"
             f"cached={warm.cached_filters}/{len(warm.filters)};"
             f"reduce_KB={cold.filter_reduce_bytes / 1024:.2f}"
             f"->{warm.filter_reduce_bytes / 1024:.2f};"
             f"net_KB={cold.network_bytes / 1024:.1f}"
             f"->{warm.network_bytes / 1024:.1f};"
             f"same={int(warm_same)}")
    emit("filters/claim/warm_cache", 0.0,
         f"suite_reduce_KB={total_cold_reduce / 1024:.2f}"
         f"->{total_warm_reduce / 1024:.2f};"
         f"hits={total_hits};misses={total_misses};"
         f"ok={int(all_warm_ok)};expect=1")
    return rows


if __name__ == "__main__":
    run()
