"""Concurrent query service benchmark: aggregate throughput of the batched
multi-tenant path vs serial solo execution on the service suite (q19-q23
plus the deliberately-overlapping q33/q34).

Headline metrics:
  * aggregate queries/sec — batch wall time vs the summed solo wall times,
  * total suite network bytes — every shared subtree's single producer
    execution plus every consumer, vs the serial sum; shared subtrees and
    the batch-wide FilterCache make this strictly lower.

Claim checks: every deduped subtree has >= 2 occurrences and exactly one
producer execution, per-query batched rows identical to solo (up to float
summation order), batched suite bytes strictly below serial, and a warm
resubmission of the whole suite hits the plan cache on every query.

Wall-clock ordering note: the serial pass runs first, so JIT compilation
of the shared join shapes lands on the serial side's first executions and
the batch pass runs against a warm compile cache — the wall-clock ratio
is therefore a friendly upper bound on this 1-core container, while the
byte metrics are exact and scheduler-independent.
"""

from __future__ import annotations

import time

from repro.joins.ref import rows_as_set, rows_close
from repro.sql import QueryService, generate, service_queries

from .common import emit


def run(scale: float = 0.2, p: int = 8):
    catalog = generate(scale=scale, p=p, seed=0)
    queries = service_queries()
    service = QueryService(catalog)

    # -- serial baseline: each query alone, cold caches -----------------------
    solos = {}
    t0 = time.perf_counter()
    for qname, plan in queries.items():
        solos[qname] = service.execute_solo(plan)
    serial_wall = time.perf_counter() - t0
    serial_bytes = sum(r.network_bytes for r in solos.values())
    serial_joins = sum(len(r.decisions) for r in solos.values())

    # -- batched pass ---------------------------------------------------------
    for qname, plan in queries.items():
        service.submit(plan, name=qname)
    reports = service.run()
    assert len(reports) == 1
    report = reports[0]
    batch_bytes = report.total_network_bytes
    batch_joins = (sum(len(s.result.decisions) for s in report.shared)
                   + sum(len(r.decisions) for r in report.results.values()))

    all_same = True
    for qname in queries:
        solo, batched = solos[qname], report.results[qname]
        same = rows_close(rows_as_set(batched.table.to_numpy()),
                          rows_as_set(solo.table.to_numpy()))
        all_same &= same
        emit(f"service/measured/{qname}", batched.wall_time_s * 1e6,
             f"net_KB={solo.network_bytes / 1024:.1f}"
             f"->{batched.network_bytes / 1024:.1f};"
             f"joins={len(solo.decisions)}->{len(batched.decisions)};"
             f"cached_filters={batched.cached_filters};same={int(same)}")
    # Row name = consumer list (stable + CSV-safe; raw signatures carry
    # commas/brackets that would corrupt the emitted CSV metric names).
    for s in report.shared:
        emit(f"service/shared/{'+'.join(s.consumers)}",
             s.result.wall_time_s * 1e6,
             f"occurrences={s.occurrences};"
             f"net_KB={s.result.network_bytes / 1024:.1f};"
             f"rows={s.result.rows}")

    # -- headline metrics -----------------------------------------------------
    serial_qps = len(queries) / max(serial_wall, 1e-9)
    emit("service/throughput", report.wall_time_s * 1e6,
         f"qps={report.queries_per_second:.2f};serial_qps={serial_qps:.2f};"
         f"x={report.queries_per_second / max(serial_qps, 1e-9):.2f}")
    emit("service/claim/suite_bytes", 0.0,
         f"KB={serial_bytes / 1024:.1f}->{batch_bytes / 1024:.1f};"
         f"x={serial_bytes / max(batch_bytes, 1.0):.2f};"
         f"below_serial={int(batch_bytes < serial_bytes)};expect=1")
    dedup_ok = (bool(report.shared)
                and all(s.occurrences >= 2 for s in report.shared)
                and batch_joins < serial_joins)
    emit("service/claim/shared_dedup", 0.0,
         f"shared={len(report.shared)};joins={serial_joins}->{batch_joins};"
         f"ok={int(dedup_ok)};expect=1")
    emit("service/claim/rows_identical", 0.0,
         f"ok={int(all_same)};expect=1")

    # -- warm plan cache: resubmit the whole suite ----------------------------
    warm = [service.submit(plan, name=f"warm_{qname}")
            for qname, plan in queries.items()]
    service.run()
    warm_hits = sum(1 for sub in warm if sub.plan_cached)
    emit("service/claim/plan_cache_warm", 0.0,
         f"cached={warm_hits}/{len(warm)};"
         f"ok={int(warm_hits == len(warm))};expect=1")
    return report


if __name__ == "__main__":
    run()
