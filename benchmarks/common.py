"""Shared benchmark utilities: suite runner + CSV emission.

Primary engine metric: *measured cluster workload* (exact bytes counted by
the executor — the paper's own §3.1.1 cost metric). Wall-clock on the
1-core CPU container is reported as a secondary signal (warm, best-of-k),
mirroring the paper's 3-run averaging.
"""

from __future__ import annotations

from typing import Dict, List

from repro.sql import Executor, all_queries
from repro.sql.strategies import Strategy


def run_suite(catalog, strategy: Strategy, runs: int = 2,
              queries: Dict | None = None) -> Dict[str, dict]:
    """Execute every query; returns per-query record."""
    queries = queries or all_queries()
    out = {}
    for qname, plan in queries.items():
        best_wall = float("inf")
        res = None
        for _ in range(runs):
            ex = Executor(catalog, strategy)
            r = ex.execute(plan)
            best_wall = min(best_wall, r.wall_time_s)
            res = r
        out[qname] = {
            "wall_s": best_wall,
            "workload": res.workload(w=1.0),
            "network_bytes": res.network_bytes,
            "local_bytes": res.local_bytes,
            "methods": res.methods(),
            "decisions": res.decisions,
            "rows": res.rows,
        }
    return out


#: When a capture is active (run.py --json-out), every emitted row is also
#: recorded here so the orchestrator can persist machine-readable results.
_capture: List[dict] | None = None


def start_capture() -> None:
    """Begin recording emitted rows (one benchmark module's run)."""
    global _capture
    _capture = []


def end_capture() -> List[dict]:
    """Stop recording; return the rows emitted since ``start_capture``."""
    global _capture
    rows, _capture = (_capture or []), None
    return rows


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    if _capture is not None:
        _capture.append({"name": name, "us_per_call": round(us_per_call, 2),
                         "derived": derived})


def mean(xs: List[float]) -> float:
    return sum(xs) / max(len(xs), 1)
