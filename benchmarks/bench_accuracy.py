"""Table 4 reproduction: per-query optimization accuracy — for how many
queries is each strategy the fastest (First-3 excludes RelJoin, All
includes it). Winner decided on measured workload (exact), wall as tiebreak
signal only."""

from __future__ import annotations

from repro.sql import default_strategies, generate

from .common import emit, run_suite


def run(scale: float = 0.3, p: int = 8, runs: int = 2):
    catalog = generate(scale=scale, p=p, seed=0)
    strategies = default_strategies()
    suites = {s.name: run_suite(catalog, s, runs=runs) for s in strategies}
    names = [s.name for s in strategies]
    qnames = list(next(iter(suites.values())))

    def winners(cands):
        # workload ties are exact when strategies pick identical plans (the
        # paper's continuous-time metric cannot tie); award the win to
        # every strategy within 0.5% of the minimum.
        wins = {n: 0 for n in cands}
        for q in qnames:
            best = min(suites[n][q]["workload"] for n in cands)
            for n in cands:
                if suites[n][q]["workload"] <= best * 1.005:
                    wins[n] += 1
        return wins

    first3 = winners(names[:3])
    all4 = winners(names)
    total = len(qnames)
    for n in names:
        emit(f"accuracy/first3/{n}", 0.0,
             f"wins={first3.get(n, 0)};acc={100 * first3.get(n, 0) / total:.1f}%")
        emit(f"accuracy/all/{n}", 0.0,
             f"wins={all4[n]};acc={100 * all4[n] / total:.1f}%")
    # paper claim: RelJoin wins the most queries when included
    rel_wins = all4["RelJoin(w=1)"]
    emit("accuracy/claim_reljoin_most_wins", 0.0,
         f"rel={rel_wins};max_other={max(v for k, v in all4.items() if k != 'RelJoin(w=1)')}")
    return all4


if __name__ == "__main__":
    run()
