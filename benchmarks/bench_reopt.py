"""Checkpoint re-optimization benchmark: the headline claim of the
statistics tentpole (see docs/statistics.md).

Scenario: a static (estimate-driven) executor plans
``(store_sales ⋈ σ(item)) ⋈ date_dim`` on a catalog whose ``ss_item_sk``
is Zipf-tilted — the per-column histogram cannot see the correlation
between the item filter and the fact table's hot keys, so the first
join's output blows past the estimate. With ``reopt=True`` the checkpoint
at that boundary triggers (q-error > threshold), folds the measured
intermediate into the remaining join graph, and the re-run DP flips the
second join's method from shuffle to broadcast — cutting measured network
bytes while producing byte-identical rows.

Reported rows:
  * both arms per scenario: methods, network bytes, trigger count,
    worst boundary q-error;
  * ``reopt/claim/divergent`` — the headline: >= 1 triggered checkpoint,
    a method flip, strictly fewer network bytes, identical rows;
  * ``reopt/claim/uniform`` — the discipline: on the uniform catalog no
    checkpoint triggers and the reopt arm is byte-identical (same
    methods, same bytes) — re-planning is only ever bought with evidence.
"""

from __future__ import annotations

from repro.joins.ref import rows_as_set
from repro.sql import Executor, RelJoinStrategy, ReorderingStrategy, generate
from repro.sql.logical import Filter, Join, Scan

from .common import emit


def _plan():
    return Join(
        Join(Scan("store_sales"),
             Filter(Scan("item"), "i_item_sk", "lt", 150.0),
             "ss_item_sk", "i_item_sk"),
        Scan("date_dim"), "ss_sold_date_sk", "d_date_sk")


def _arm(catalog, reopt: bool, w: float):
    ex = Executor(catalog,
                  strategy=ReorderingStrategy(RelJoinStrategy(w=w),
                                              reopt=reopt),
                  adaptive=False, verify=True)
    return ex.execute(_plan())


def run(scale: float = 0.1, p: int = 4, w: float = 1.0):
    scenarios = {
        "divergent": generate(scale=scale, p=p, seed=7,
                              skew_overrides={"ss_item_sk": 1.3}),
        "uniform": generate(scale=scale, p=p, seed=7),
    }
    for name, catalog in scenarios.items():
        off = _arm(catalog, reopt=False, w=w)
        on = _arm(catalog, reopt=True, w=w)
        same = (rows_as_set(on.table.to_numpy())
                == rows_as_set(off.table.to_numpy()))
        for arm, res in (("off", off), ("on", on)):
            emit(f"reopt/measured/{name}/{arm}", res.wall_time_s * 1e6,
                 f"methods={'+'.join(m.name for m in res.methods())};"
                 f"net_KB={res.network_bytes / 1024:.1f};"
                 f"triggers={res.reopt_count};"
                 f"max_q={res.max_q_error:.2f};rows={res.rows}")
        if name == "divergent":
            flipped = on.methods() != off.methods()
            cut = on.network_bytes < off.network_bytes
            emit("reopt/claim/divergent", 0.0,
                 f"triggers={on.reopt_count};flipped={int(flipped)};"
                 f"net_KB={off.network_bytes / 1024:.1f}"
                 f"->{on.network_bytes / 1024:.1f};cut={int(cut)};"
                 f"same={int(same)};expect=triggers>=1&flipped&cut&same")
        else:
            identical = (on.methods() == off.methods()
                         and on.network_bytes == off.network_bytes)
            emit("reopt/claim/uniform", 0.0,
                 f"triggers={on.reopt_count};identical={int(identical)};"
                 f"same={int(same)};expect=triggers=0&identical&same")
