"""Kernel + join-method microbenchmarks (us_per_call; interpret-mode Pallas
timings are NOT TPU-representative and are labeled as such — the TPU story
lives in §Roofline)."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cost_model import JoinMethod
from repro.joins import from_numpy, partition_round_robin, run_equi_join
from repro.kernels import ops, ref

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(
        x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 4096, 4096).astype(np.int32))
    b = jnp.asarray(rng.permutation(4096).astype(np.int32)[:1024])
    emit("kernels/tiled_probe_ref_4096x1024",
         _time(lambda: ref.tiled_probe_ref(a, b).block_until_ready()),
         "jnp_oracle")
    emit("kernels/tiled_probe_interp_4096x1024",
         _time(lambda: ops.probe(a, b).block_until_ready()),
         "pallas_interpret_NOT_tpu_time")

    d = jnp.asarray(rng.integers(0, 64, 65536).astype(np.int32))
    emit("kernels/partition_hist_ref_64k",
         _time(lambda: ref.partition_hist_ref(d, 64).block_until_ready()),
         "jnp_oracle")
    emit("kernels/partition_hist_interp_64k",
         _time(lambda: ops.hist(d, 64).block_until_ready()),
         "pallas_interpret_NOT_tpu_time")

    k = jnp.asarray(rng.integers(0, 1 << 20, 2048).astype(np.int32))
    v = jnp.arange(2048, dtype=jnp.int32)
    emit("kernels/bitonic_sort_2048",
         _time(lambda: ops.sort_pairs(k, v)[0].block_until_ready()),
         "pallas_interpret_NOT_tpu_time")

    # join methods end-to-end (eager engine)
    bn = from_numpy({"k": np.arange(2000, dtype=np.int32),
                     "pay": np.ones(2000, np.int32)})
    an = from_numpy({"k": rng.integers(0, 2000, 50_000).astype(np.int32),
                     "v": np.ones(50_000, np.float32)})
    A, B = partition_round_robin(an, 8), partition_round_robin(bn, 8)
    for m in (JoinMethod.BROADCAST_HASH, JoinMethod.SHUFFLE_HASH,
              JoinMethod.SHUFFLE_SORT):
        emit(f"joins/{m.value}_50k_x_2k",
             _time(lambda m=m: run_equi_join(m, A, B, "k", "k")[0]
                   .valid.block_until_ready(), reps=2),
             "eager_engine_cpu")


if __name__ == "__main__":
    run()
