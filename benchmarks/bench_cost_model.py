"""Cost-model validation (§3): for each physical method, compare the
paper's modeled phase workloads (Eqs. 1, 5) against the engine's *measured*
exchange bytes, and verify the Eq. 13 crossover on a controlled size sweep.
"""

from __future__ import annotations

import numpy as np

from repro.core.cost_model import (CostParams, JoinMethod,
                                   broadcast_workload, k0_threshold,
                                   shuffle_workload)
from repro.joins import from_numpy, partition_round_robin, run_equi_join

from .common import emit


def _tables(na, nb, p, seed=0):
    rng = np.random.default_rng(seed)
    b = from_numpy({"k": np.arange(nb, dtype=np.int32),
                    "pay": rng.integers(0, 99, nb).astype(np.int32)})
    a = from_numpy({"k": rng.integers(0, nb, na).astype(np.int32),
                    "v": rng.uniform(size=na).astype(np.float32)})
    return (partition_round_robin(a, p), partition_round_robin(b, p),
            a, b)


def run(p: int = 8):
    params = CostParams(p=p, w=1.0)
    A, B, a, b = _tables(20_000, 1_000, p)

    # Eq. 1: broadcast network workload == (p-1)|B| exactly.
    _, rep = run_equi_join(JoinMethod.BROADCAST_HASH, A, B, "k", "k")
    model = broadcast_workload(b.count() * b.row_bytes, params)
    meas = rep.exchanges[0].network_bytes
    emit("cost_model/broadcast_eq1", 0.0,
         f"model={model:.0f};measured={meas:.0f};"
         f"rel_err={abs(model - meas) / model:.4f}")

    # Eq. 5: shuffle network workload ~ ((p-1)/p)(|A|+|B|).
    _, rep = run_equi_join(JoinMethod.SHUFFLE_HASH, A, B, "k", "k")
    model = shuffle_workload(a.count() * a.row_bytes,
                             b.count() * b.row_bytes, params)
    meas = sum(e.network_bytes for e in rep.exchanges)
    emit("cost_model/shuffle_eq5", 0.0,
         f"model={model:.0f};measured={meas:.0f};"
         f"rel_err={abs(model - meas) / model:.4f}")

    # Eq. 13 crossover: sweep k and confirm the cheaper *measured total
    # workload* flips sides at k0.
    k0 = k0_threshold(params)
    flips = []
    for k in (2, 8, int(k0), int(2 * k0), int(8 * k0)):
        na = 1_000 * k
        A, B, a, b = _tables(na, 1_000, p, seed=k)
        _, rb = run_equi_join(JoinMethod.BROADCAST_HASH, A, B, "k", "k")
        _, rs = run_equi_join(JoinMethod.SHUFFLE_HASH, A, B, "k", "k")

        def total(rep):
            return (sum(e.network_bytes for e in rep.exchanges)
                    + rep.local_bytes)
        winner = ("broadcast" if total(rb) < total(rs) else "shuffle")
        flips.append((k, winner))
        emit(f"cost_model/crossover_k={k}", 0.0,
             f"k0={k0:.0f};winner={winner};"
             f"bcast={total(rb):.0f};shuf={total(rs):.0f}")
    return flips


if __name__ == "__main__":
    run()
