"""Skew benchmark: straggler (max-partition) bytes and suite time of
SkewAwareStrategy vs RelJoinStrategy on the skewed queries (q16-q18) across
a Zipf-exponent sweep.

Reported per (query, zipf):
  * straggler bytes (sum over joins of the hottest destination partition's
    landed exchange bytes — the skew-sensitive wall-clock bound),
  * total network bytes and wall time,
  * whether SALTED_SHUFFLE_HASH was selected, and result equality
    (identical up to float summation order across physical plans).

Claim checks: at Zipf >= 1.2 every query selects the salted method at least
once and lands strictly fewer straggler bytes than RelJoin; at skew 0 the
two strategies make byte-for-byte identical selections."""

from __future__ import annotations

from repro.core.cost_model import JoinMethod
from repro.joins.ref import rows_as_set, rows_close
from repro.sql import (Executor, RelJoinStrategy, SkewAwareStrategy,
                       generate, skewed_queries)

from .common import emit


def run(scale: float = 0.2, p: int = 8, w: float = 1.0,
        zipfs=(0.0, 0.8, 1.2, 1.4)):
    rows = []
    for z in zipfs:
        catalog = generate(scale=scale, p=p, seed=0, skew=z)
        for qname, plan in skewed_queries().items():
            base = Executor(catalog, RelJoinStrategy(w=w)).execute(plan)
            skew = Executor(catalog, SkewAwareStrategy(w=w)).execute(plan)
            same = rows_close(rows_as_set(skew.table.to_numpy()),
                              rows_as_set(base.table.to_numpy()))
            salted = JoinMethod.SALTED_SHUFFLE_HASH in skew.methods()
            rows.append((z, qname, base, skew, salted, same))
            emit(f"skew/measured/{qname}/zipf={z:g}",
                 skew.wall_time_s * 1e6,
                 f"straggler_KB={base.straggler_bytes / 1024:.1f}"
                 f"->{skew.straggler_bytes / 1024:.1f};"
                 f"net_KB={base.network_bytes / 1024:.1f}"
                 f"->{skew.network_bytes / 1024:.1f};"
                 f"salted={int(salted)};same={int(same)}")

    # -- claim checks -------------------------------------------------------
    for z, qname, base, skew, salted, same in rows:
        if z == 0.0:
            ok = skew.methods() == base.methods()
            emit(f"skew/claim/parity_at_zero/{qname}", 0.0,
                 f"identical_selections={int(ok)};expect=1")
        if z >= 1.2:
            ratio = skew.straggler_bytes / max(base.straggler_bytes, 1.0)
            emit(f"skew/claim/zipf={z:g}/{qname}", 0.0,
                 f"salted={int(salted)};straggler_ratio={ratio:.3f};"
                 f"same={int(same)};expect=salted&ratio<1&same")
    hot = [r for r in rows if r[0] >= 1.2]
    if hot:
        strag_base = sum(r[2].straggler_bytes for r in hot)
        strag_skew = sum(r[3].straggler_bytes for r in hot)
        emit("skew/claim/suite_straggler_total", 0.0,
             f"ratio={strag_skew / max(strag_base, 1):.3f};expect<1")
    return rows


if __name__ == "__main__":
    run()
